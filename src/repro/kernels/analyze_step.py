"""Fused analyze→route device programs.

The decision hot path used to be two device programs with a host
round-trip in the middle: the analyzer forward synced its logits to
host, Python built one ``TaskSignature`` per row, the host rebuilt task
vectors and filter indices, and only then re-entered the fused
``route_step``.  This module collapses the whole path — token ids →
analyzer encoder → softmax heads / complexity clamp / confidence →
task-vector construction → feedback-bias gather → kNN/bias/bandit/load
blend → model choice — into ONE jitted program:

* ``analyze_step_jit`` — the analyzer half alone (encoder + heads +
  in-program argmax/confidence), for callers that still need staged
  ``TaskSignature`` batches.  The softmax→argmax→min-of-maxes epilogue
  runs on device, so the host only ever sees four small (B,) arrays
  instead of full logit matrices.
* ``analyze_route_step_jit`` — the full fusion: the analyzer epilogue
  feeds the confidence-thresholded filter-row indices, the
  complexity-clamped task vectors, and the per-cluster feedback-bias
  rows directly into ``route_step._route_step_body``, so no
  intermediate ever touches the host.

Both are raw shape-specialized entries; go through the bucketed
``ops.analyze_step`` / ``ops.analyze_route_step`` dispatchers.

``analyzer_forward`` (and its ``_ln`` / ``_maybe_deq`` helpers) moved
here from ``core/analyzer.py`` so the kernel layer owns the traced
encoder; ``core.analyzer`` re-exports them for existing callers.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.data.tokenizer import PAD_ID
from repro.kernels.route_step import _route_step_body


def _ln(x, g, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g


def _maybe_deq(w):
    """Transparent int8 dequant: w is either f32 or (int8, scale)."""
    if isinstance(w, tuple):
        q, s = w
        return q.astype(jnp.float32) * s
    return w


def analyzer_forward(params: Dict, cfg, tokens: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """tokens (B, L) int32 -> (tt_logits, dm_logits, complexity (B,))."""
    B, L = tokens.shape
    mask = tokens != PAD_ID                                 # (B, L)
    emb = _maybe_deq(params["embed"])
    x = emb[tokens] + _maybe_deq(params["pos"])[None, :L]
    H, hd = cfg.n_heads, cfg.head_dim
    neg = jnp.where(mask, 0.0, -1e30)[:, None, None, :]     # key mask

    for p in params["layers"]:
        h = _ln(x, p["ln1"])
        q = (h @ _maybe_deq(p["wq"])).reshape(B, L, H, hd)
        k = (h @ _maybe_deq(p["wk"])).reshape(B, L, H, hd)
        v = (h @ _maybe_deq(p["wv"])).reshape(B, L, H, hd)
        s = jnp.einsum("blhd,bmhd->bhlm", q, k) / math.sqrt(hd) + neg
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhlm,bmhd->blhd", a, v).reshape(B, L, -1)
        x = x + o @ _maybe_deq(p["wo"])
        h = _ln(x, p["ln2"])
        x = x + jax.nn.gelu(h @ _maybe_deq(p["wi"])) @ _maybe_deq(p["wp"])

    x = _ln(x, params["ln_f"])
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1)
    pooled = jnp.sum(x * mask[..., None], axis=1) / denom   # (B, d)
    tt = pooled @ _maybe_deq(params["head_tt"])
    dm = pooled @ _maybe_deq(params["head_dm"])
    cx = jax.nn.sigmoid(pooled @ _maybe_deq(params["head_cx"]))[:, 0]
    return tt, dm, cx


def _analyze_heads(params, cfg, tokens):
    """Encoder + the staged host epilogue, traced: softmax heads,
    first-occurrence argmax over the PROBABILITIES (exactly what the
    host ``np.argmax`` did), complexity clamp, min-of-maxes confidence.
    All-PAD bucket-padding rows pool to zeros → uniform probs → low
    confidence; they cost nothing extra and are sliced off by ops."""
    tt, dm, cx = analyzer_forward(params, cfg, tokens)
    tt_p = jax.nn.softmax(tt, axis=-1)
    dm_p = jax.nn.softmax(dm, axis=-1)
    return (jnp.argmax(tt_p, axis=1).astype(jnp.int32),
            jnp.argmax(dm_p, axis=1).astype(jnp.int32),
            jnp.clip(cx, 0.0, 1.0),
            jnp.minimum(tt_p.max(axis=1), dm_p.max(axis=1)))


@functools.partial(jax.jit, static_argnames=("cfg",))
def analyze_step_jit(params, tokens, *, cfg):
    """Analyzer half of the fused path over a bucket-padded batch.

    tokens (Qp, L) int32; ``cfg`` is the hashable ``AnalyzerConfig``
    (static — one executable per config).  Returns (Qp,) arrays:
    ``tt_idx``/``dm_idx`` (raw head argmax), ``cx`` (clipped [0, 1]),
    ``conf`` (min of the two softmax maxima).
    """
    tt_idx, dm_idx, cx, conf = _analyze_heads(params, cfg, tokens)
    return {"tt_idx": tt_idx, "dm_idx": dm_idx, "cx": cx, "conf": conf}


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "acc_col", "use_complexity", "fb_buckets",
                     "k", "r", "n_tt", "n_dm", "has_fb", "has_ad",
                     "has_load", "use_pallas", "blk_q", "blk_n",
                     "interpret", "quant"))
def analyze_route_step_jit(params, tokens, W, ascalars, fb_table,
                           e2, e2s, masks_table, counts_table,
                           theta, ainv_flat, lpen, rparams, *,
                           cfg, acc_col: int, use_complexity: bool,
                           fb_buckets: int, k: int, r: int,
                           n_tt: int, n_dm: int, has_fb: bool,
                           has_ad: bool, has_load: bool,
                           use_pallas: bool, blk_q: int, blk_n: int,
                           interpret: bool, quant: bool = False):
    """ONE program from token ids to model choice.

    tokens (Qp, L) int32 bucket-padded queries; W (Qp, M) preference
    weight rows; ascalars (1,) f32 ``[confidence_threshold]`` (traced:
    threshold changes must not recompile); fb_table
    (n_tt_raw * n_dm_raw * fb_buckets, Np) dense per-cluster feedback
    bias table (dummy when ``has_fb`` False) — the traced epilogue
    gathers each query's row from its RAW predicted (tt, dm, complexity
    bucket), matching ``feedback.cluster_of`` which clusters on the
    predicted signature regardless of confidence.  The remaining
    operands and statics are ``route_step_jit``'s, with ``n_tt``/
    ``n_dm`` counting the trailing ANY rows (so the raw head widths are
    ``n_tt - 1`` / ``n_dm - 1``); ``acc_col``/``use_complexity``
    replicate the staged task-vector build ``T[:, acc] =
    max(W[:, acc], cx)``.

    Returns ``route_step_jit``'s dict plus the analyzer outputs
    (``tt_idx``/``dm_idx``/``cx``/``conf``) and the in-program task
    vectors (``task_vectors`` (Qp, M)) for lazy ``TaskSignature`` /
    observation accessors.
    """
    tt_idx, dm_idx, cx, conf = _analyze_heads(params, cfg, tokens)
    confident = conf >= ascalars[0]
    ti = jnp.where(confident, tt_idx, n_tt - 1).astype(jnp.int32)
    di = jnp.where(confident, dm_idx, n_dm - 1).astype(jnp.int32)
    T = W
    if use_complexity:
        T = W.at[:, acc_col].set(jnp.maximum(W[:, acc_col], cx))
    fb = fb_table
    if has_fb:
        cb = jnp.clip((cx * fb_buckets).astype(jnp.int32),
                      0, fb_buckets - 1)
        fb = fb_table[(tt_idx * (n_dm - 1) + dm_idx) * fb_buckets + cb]
    out = _route_step_body(
        e2, e2s, masks_table, counts_table, T, W, ti, di, fb,
        theta, ainv_flat, lpen, rparams, k=k, r=r, n_tt=n_tt,
        n_dm=n_dm, has_fb=has_fb, has_ad=has_ad, has_load=has_load,
        use_pallas=use_pallas, blk_q=blk_q, blk_n=blk_n,
        interpret=interpret, quant=quant)
    out.update(tt_idx=tt_idx, dm_idx=dm_idx, cx=cx, conf=conf,
               task_vectors=T)
    return out
