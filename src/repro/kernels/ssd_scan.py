"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

The SSD recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T,
y_t = C_t . h_t  is evaluated chunk-by-chunk: within a chunk the output
is a (masked, decay-weighted) quadratic form C B^T — dense matmuls the
MXU likes — and the carried state advances once per chunk.  The (P, N)
state lives in VMEM scratch across the sequential chunk axis:

  grid = (B, H, L/CHUNK)                     (chunk axis sequential)
  per chunk: la      = cumsum(dt * A)
             y_inter = exp(la) * (C @ h^T)
             y_intra = ((C @ B^T) * causal-decay * dt) @ x
             h       = exp(la_last) h + (x * contrib)^T @ B

B/C are group-shared over heads (groups=1) so their blocks are indexed
by (batch, chunk) only — no head replication materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hf_ref, state_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)               # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                # (c,)
    a = a_ref[0].astype(jnp.float32)                        # scalar
    bm = b_ref[0].astype(jnp.float32)                       # (c, N)
    cm = c_ref[0].astype(jnp.float32)                       # (c, N)
    h = state_ref[...]                                      # (P, N)

    la = jnp.cumsum(dt * a)                                 # (c,) log-decay <= 0
    # inter-chunk: y_i += exp(la_i) * C_i . h
    y_inter = jnp.exp(la)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (c, P)
    # intra-chunk: masked decay-weighted quadratic form
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ii >= jj
    # mask the exponent before exp (non-causal args are positive, overflow)
    dec = jnp.exp(jnp.where(causal, la[:, None] - la[None, :], 0.0))
    w = jnp.where(causal, dec, 0.0) * dt[None, :]
    y_intra = jax.lax.dot(cb * w, x, preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: h' = exp(la_last) h + (x * contrib)^T @ B
    contrib = jnp.exp(la[-1] - la) * dt                     # (c,)
    state_ref[...] = h * jnp.exp(la[-1]) + jax.lax.dot_general(
        x * contrib[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (P, N)

    @pl.when(ic == nc - 1)
    def _emit():
        hf_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, h0=None, *, chunk: int = 128,
                    interpret: bool = True):
    """SSD scan. Shapes per kernels/ref.py::ssd_scan.

    x (Bb, L, H, P); dt (Bb, L, H); A (H,); B/C (Bb, L, N);
    h0 (Bb, H, P, N) or None.  L is padded to a chunk multiple with
    dt = 0 (unit decay, zero input) so the final state is exact.
    Returns (y (Bb, L, H, P) f32, h_final (Bb, H, P, N) f32).
    """
    Bb, L, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, max(L, 8))
    Lp = -(-L // chunk) * chunk
    pad = ((0, 0), (0, Lp - L))
    xp = jnp.pad(x, pad + ((0, 0), (0, 0)))
    dtp = jnp.pad(dt, pad + ((0, 0),))
    Bp = jnp.pad(B, pad + ((0, 0),))
    Cp = jnp.pad(C, pad + ((0, 0),))
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    grid = (Bb, H, Lp // chunk)
    y, hf = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, Lp, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, dtp, A, Bp, Cp, h0)
    return y[:, :L], hf
