"""Pallas TPU kernel: fused MoE gate (softmax + top-k + renormalize).

One VMEM-resident pass per token block: row softmax, k-pass argmax
selection (k static, unrolled — TPU-friendly, no sort network), top-k
renormalization, plus per-block partial sums of probs / assignments so
the wrapper can form the load-balance aux loss without a second pass.

  grid = (T/BLK_T,)  all parallel
  outs: gate_vals (T, k), gate_idx (T, k),
        probs_sum (nblk, E), assign_sum (nblk, E)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float("-inf")


def _gate_kernel(logits_ref, vals_ref, idx_ref, psum_ref, asum_ref, *,
                 k: int, blk_t: int, t_total: int):
    it = pl.program_id(0)
    logits = logits_ref[...].astype(jnp.float32)            # (BLK_T, E)
    E = logits.shape[1]
    row = it * blk_t + jax.lax.broadcasted_iota(jnp.int32, (blk_t, 1), 0)
    live = row < t_total                                     # (BLK_T, 1)

    m = jnp.max(logits, axis=1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / jnp.sum(p, axis=1, keepdims=True)           # (BLK_T, E)

    rem = probs
    vs, ids = [], []
    assign = jnp.zeros_like(probs)
    for _ in range(k):
        am = jnp.argmax(rem, axis=1)                        # (BLK_T,)
        onehot = jax.nn.one_hot(am, E, dtype=jnp.float32)
        vs.append(jnp.sum(rem * onehot, axis=1))
        ids.append(am.astype(jnp.int32))
        assign = assign + onehot
        rem = jnp.where(onehot > 0, NEG_INF, rem)
    vals = jnp.stack(vs, axis=1)                            # (BLK_T, k)
    vals_ref[...] = vals / (jnp.sum(vals, axis=1, keepdims=True) + 1e-9)
    idx_ref[...] = jnp.stack(ids, axis=1)

    livef = live.astype(jnp.float32)
    psum_ref[0] = jnp.sum(probs * livef, axis=0)
    asum_ref[0] = jnp.sum(assign * livef, axis=0)


@functools.partial(jax.jit, static_argnames=("k", "blk_t", "interpret"))
def moe_gating_pallas(logits: jnp.ndarray, k: int, *, blk_t: int = 256,
                      interpret: bool = True):
    """logits (T, E). Returns (vals (T, k) f32, idx (T, k) i32, aux f32)."""
    T, E = logits.shape
    blk_t = min(blk_t, max(T, 8))
    Tp = -(-T // blk_t) * blk_t
    lp = jnp.pad(logits, ((0, Tp - T), (0, 0)))
    nblk = Tp // blk_t

    vals, idx, psum, asum = pl.pallas_call(
        functools.partial(_gate_kernel, k=k, blk_t=blk_t, t_total=T),
        grid=(nblk,),
        in_specs=[pl.BlockSpec((blk_t, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((blk_t, k), lambda i: (i, 0)),
            pl.BlockSpec((blk_t, k), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, k), jnp.float32),
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
            jax.ShapeDtypeStruct((nblk, E), jnp.float32),
            jax.ShapeDtypeStruct((nblk, E), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(lp)
    me = jnp.sum(psum, axis=0) / T
    ce = jnp.sum(asum, axis=0) / T
    aux = jnp.sum(me * ce) * E
    return vals[:T], idx[:T], aux
