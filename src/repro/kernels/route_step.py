"""Fused single-dispatch routing step: the whole per-batch hot path of
``RoutingEngine.route_many`` as ONE jitted device program.

The staged path costs several device/numpy passes per batch — kNN
top-k, candidate gathers, feedback/bandit/load blends, argsort, plus
host-side per-row fallback retries.  ``route_step`` collapses all of it
into a single program (one device dispatch per routed batch):

  1. mask lookup        — the catalog's hierarchical-filter structure
     is pre-flattened by ``ops.py`` into ONE stacked mask table
     (task-type x domain combinations, then the fallback rungs:
     task-type-only rows, the generalist row, the live-catalog row)
     with a per-row population-count table.  Per-query masks and every
     ladder count are O(B) gathers — no (B, N) boolean reductions;
  2. score blend        — ONE (B, N) blend of user-weighted metric
     scores + feedback bias + LinUCB bandit estimates (mean + alpha *
     sqrt(x^T Ainv x), both as matmuls over the flattened rank-1
     layout) - load penalty;
  3. fused top-k        — primary rows rank the mask-fused COSINE
     similarities (the kNN), rows whose filter count is zero rank the
     BLEND under their first non-empty fallback rung instead: both
     live in one per-row-selected matrix, so a single ``top_k`` serves
     the kNN and the whole fallback ladder (masked re-scores inside
     the program, not host-side retries);
  4. candidate argmax   — primary candidates gather their blended
     scores from (2) and re-rank in-program (``top_k`` over k lanes),
     so the winner, its score and the ranked candidate list come out
     as arrays.

On TPU (``use_pallas``) the kNN stage runs the Pallas ``router_topk``
kernel (blocked MXU matmul + the shared ``block_topk``/``merge_topk``
carry update) and the fallback re-score is its own ``top_k`` — the
structure XLA:TPU prefers; the single-matrix form above is the
XLA:CPU-friendly lowering the test suite exercises.

``jax.lax.optimization_barrier`` pins the big (B, N) intermediates:
without it XLA:CPU duplicates cheap producers (mask gathers, where
chains) into every consumer and the program slows ~20x.

All shapes are static per (Q bucket, padded catalog) pair — ``ops.py``
pads Q up to power-of-two buckets and N to the catalog's 128-aligned
capacity, so steady-state serving re-dispatches one cached executable
regardless of batch size.  Padded query rows compute garbage and are
sliced off; padded catalog columns are False in every mask row.

Mega-catalog extensions (100k–1M entries, same single dispatch):

  * ``quant=True``    — the catalog block arrives int8 row-quantized
    (per-row scales in ``e2s``); the O(N) scan matmul accumulates in
    int32 on the int8 operands and rescales to fp32 ONCE at the top-k
    boundary.  4x fewer catalog bytes; on a memory-bandwidth-bound
    scan that is the speedup (benchmarks/roofline.py).  All integer
    dots are exact, so quantized results are bitwise-reproducible
    across the jnp, Pallas and oracle paths.
  * ``route_step_ivf_jit``     — two-level IVF-pruned search over a
    cell-packed catalog layout: coarse centroid scores select the
    top-``nprobe`` cells per query IN-PROGRAM, only those cells'
    blocks are gathered and scanned (O(nprobe * cell) instead of
    O(N)), and rows whose probed cells miss every filter match escape
    to the exact widened-kNN rung via ``lax.cond``.
  * ``route_step_sharded_jit`` — ``shard_map`` over a 1-D device mesh
    with the catalog axis sharded: each shard runs the SAME fused
    local scan + top-R, emits a sorted (B, R) carry with global
    indices and per-lane blend/cosine payloads, and an allreduce-style
    pairwise tree of the bitonic ``merge_topk`` (``tree_merge_topk``)
    reduces the carries — ties fold toward the lowest shard, so the
    result is bit-identical to the single-device program.

The pure-jnp semantic ground truth lives in ``kernels/ref.py``
(``ref.route_step`` incl. ``quant``/``allowed``, ``ref.route_step_ivf``);
parity is pinned by tests against both the oracle and the staged
numpy path in ``core/routing.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.ref import quantize_rows
from repro.kernels.router_topk import (router_topk_pallas,
                                       router_topk_q8_pallas,
                                       tree_merge_topk)

NEG_INF = float("-inf")


def _hier_topk(z, kk: int, chunk: int = 32):
    """Exact top-kk of (B, Np) via chunk-max pruning.

    XLA:CPU's TopK emitter costs ~O(elements) at a poor rate, while a
    plain max reduction is fast.  So: per-chunk maxima (one cheap
    reduce), keep the kk chunks with the largest maxima — any true
    top-kk element must live in one of them, since each excluded
    chunk's max is dominated by kk other chunks' maxima — gather those
    chunks, and run the expensive TopK over kk*chunk columns instead
    of Np.  Values are exact; index tie-breaks can differ from
    ``lax.top_k`` when equal values straddle chunk boundaries (same
    contract as the Pallas kernel's block merge).
    """
    B, Np = z.shape
    C = Np // chunk
    if kk > chunk or C <= kk or Np % chunk:
        return jax.lax.top_k(z, kk)
    m3 = z.reshape(B, C, chunk)
    mx = m3.max(axis=2)                                   # (B, C)
    _, cj = jax.lax.top_k(mx, kk)                         # (B, kk)
    sub = m3[jnp.arange(B)[:, None], cj]                  # (B, kk, chunk)
    v, p = jax.lax.top_k(sub.reshape(B, kk * chunk), kk)
    gi = jnp.take_along_axis(cj, p // chunk, axis=1) * chunk \
        + p % chunk
    return v, gi


def _knn_pallas(qn, embn, m1, k, blk_q, blk_n, interpret):
    """Mask-fused kNN through the Pallas kernel (TPU path).

    Shapes arrive bucket-padded (Q % blk_q == 0, N % blk_n == 0); only
    the feature axis still needs its 128-lane pad here.
    """
    Q, D = qn.shape
    N = embn.shape[0]
    dpad = (-D) % 128
    qnp = jnp.pad(qn, ((0, 0), (0, dpad)))
    ewp = jnp.pad(embn, ((0, 0), (0, dpad)))
    bias = jnp.zeros((1, N), jnp.float32)
    return router_topk_pallas(qnp, ewp, m1.astype(jnp.float32), bias, k,
                              blk_q=blk_q, blk_n=blk_n,
                              interpret=interpret)


def _knn_pallas_q8(q8, qs, e8, es, m1, k, blk_q, blk_n, interpret):
    """int8 mask-fused kNN through the quantized Pallas kernel.

    Zero-padding the int8 feature axis is exact (zero columns add
    nothing to the int32 dot), so the scales pass through unchanged.
    """
    Q, D = q8.shape
    N = e8.shape[0]
    dpad = (-D) % 128
    q8p = jnp.pad(q8, ((0, 0), (0, dpad)))
    e8p = jnp.pad(e8, ((0, 0), (0, dpad)))
    bias = jnp.zeros((1, N), jnp.float32)
    return router_topk_q8_pallas(q8p, e8p, qs, es[None, :], m1.astype(
        jnp.float32), bias, k, blk_q=blk_q, blk_n=blk_n,
        interpret=interpret)


# ----------------------------------------------------------------------
# shared program pieces (dense / IVF / sharded variants)
# ----------------------------------------------------------------------

def _ladder(counts_table, ti, di, n_tt: int, n_dm: int):
    """Per-query mask rows and ladder counts: O(B) table gathers.

    Returns (ci combined-mask row, c_wide, has_primary, fi first
    non-empty fallback row, stage_f its FALLBACK_LADDER stage).
    """
    n_combo = n_tt * n_dm
    ci = ti * n_dm + di                                   # combined row
    c_wide = counts_table[ci]
    has_primary = c_wide > 0
    c_tt = counts_table[n_combo + ti]
    c_gen = counts_table[n_combo + n_tt]
    # first non-empty fallback rung (widened-kNN == the fused mask, so
    # it is empty for every fallback row by construction): task-type-
    # only -> generalist -> any(live)
    fi = jnp.where(c_tt > 0, n_combo + ti,
                   jnp.where(c_gen > 0, n_combo + n_tt,
                             n_combo + n_tt + 1))
    stage_f = jnp.where(c_tt > 0, 2,
                        jnp.where(c_gen > 0, 3, 4)).astype(jnp.int32)
    return ci, c_wide, has_primary, fi, stage_f


def _extras_matrix(T, fb, theta, ainv_flat, lpen, params, B, Np, *,
                   has_fb: bool, has_ad: bool, has_load: bool):
    """(B, Np) extra blend terms (feedback / bandit / load), or None.

    One matrix when any term is active; None costs nothing.  The same
    per-element formulas serve the dense program over the full
    catalog, the sharded program over each shard's local columns, and
    the IVF fallback branch over the packed layout.
    """
    extras = None
    if has_fb:
        extras = params[0] * fb
    if has_ad:
        ctx = jnp.concatenate(
            [T, jnp.ones((B, 1), jnp.float32)], axis=1)   # (B, Dc)
        mean = ctx @ theta.T                              # (B, Np)
        xx = (ctx[:, :, None] * ctx[:, None, :]).reshape(B, -1)
        var = xx @ ainv_flat.T                            # (B, Np)
        ucb = params[1] * (
            mean + params[2] * jnp.sqrt(jnp.maximum(var, 0.0)))
        extras = ucb if extras is None else extras + ucb
    if has_load:
        lrow = jnp.broadcast_to(-lpen[None, :], (B, Np))
        extras = lrow if extras is None else extras - lpen[None, :]
    if extras is not None:
        extras = jax.lax.optimization_barrier(extras)
    return extras


def _q8_cscore(w8, ws, e8e_rows, ese_rows):
    """Per-candidate quantized blend scores: exact int32 einsum at the
    <=R gathered columns, fp32 rescale — bitwise equal to gathering
    from the full quantized blend matrix."""
    acc = jnp.einsum("bm,brm->br", w8.astype(jnp.int32),
                     e8e_rows.astype(jnp.int32))
    return acc.astype(jnp.float32) * (ws * ese_rows)


def _quant_operands(e2, e2s, M: int):
    """Split the packed quantized catalog block into halves:
    (e8n, esn) unit-row half for the kNN, (e8e, ese) raw-metric half
    for the blend — scales as (Np,) columns of ``e2s``."""
    return (e2[:, :M], e2s[:, 0], e2[:, M:], e2s[:, 1])


# ----------------------------------------------------------------------
# dense single-device program
# ----------------------------------------------------------------------

def _route_step_body(e2, e2s, masks_table, counts_table, T, W, ti, di, fb,
                     theta, ainv_flat, lpen, params, *, k: int, r: int,
                     n_tt: int, n_dm: int, has_fb: bool,
                     has_ad: bool, has_load: bool, use_pallas: bool,
                     blk_q: int, blk_n: int, interpret: bool,
                     quant: bool = False):
    """Traced body of ``route_step_jit`` (same signature, un-jitted) —
    split out so ``analyze_step.analyze_route_step_jit`` can inline the
    whole routing step after the analyzer encoder inside ONE program
    instead of paying a second dispatch.

    The live catalog size is deliberately NOT a parameter: liveness is
    fully encoded in the mask table (padded columns are False in every
    row, including the live-catalog rung) and the zeroed e2 pad rows,
    so catalog growth within one 128-padded capacity bucket reuses the
    cached executable without recompiling.

    e2 (Np, 2M) catalog block ``[embn | emb]`` — unit-normalized rows
    for the cosine kNN next to the raw normalized-metric rows for the
    score blend, precomputed once per catalog by ``ops.py`` (zero rows
    beyond the live count).  With ``quant=True`` e2 is the int8
    row-quantized block and e2s (Np, 2) carries the per-row scales
    (col 0 = unit half, col 1 = raw half); the scan matmul then runs
    dequant-free on int8 with an int32 accumulator and ONE fp32
    rescale at the top-k boundary (e2s is a (1, 2) dummy otherwise).
    masks_table (n_tt*n_dm + n_tt + 2, Np) stacked
    boolean mask rows — every task-type x domain combination, then the
    fallback rungs (task-type-only rows, the generalist row, the
    live-catalog row); counts_table (rows,) i32 per-row population
    counts; T (Qp, M) kNN task vectors; W (Qp, M) scoring weights;
    ti/di (Qp,) per-query filter row indices; fb (Qp, Np) feedback
    bias (dummy when ``has_fb`` False); theta (Np, Dc) / ainv_flat
    (Np, Dc*Dc) bandit posterior (LinUCB; dummies when ``has_ad``
    False); lpen (Np,) pre-scaled load penalty (dummy when
    ``has_load`` False); params (3,) f32 traced scalars
    [feedback_weight, adaptive_weight, alpha].

    Returns a dict of (Qp,)/(Qp, R) arrays with R = max(k, r):
    ``model_idx``, ``score``, ``stage`` (0 = primary, 1.. = fallback
    ladder rung), ``similarity``, ``cand_idx``/``cand_score`` (ranked,
    -1/-inf padded), ``n_filtered``, ``n_candidates``.
    """
    bar = jax.lax.optimization_barrier
    Np, M2 = e2.shape
    M = M2 // 2
    B = T.shape[0]
    R = max(k, r)

    qn = T / (jnp.linalg.norm(T, axis=1, keepdims=True) + 1e-9)
    ci, c_wide, has_primary, fi, stage_f = _ladder(
        counts_table, ti, di, n_tt, n_dm)
    extras = _extras_matrix(T, fb, theta, ainv_flat, lpen, params, B,
                            Np, has_fb=has_fb, has_ad=has_ad,
                            has_load=has_load)
    if quant:
        e8n, esn, e8e, ese = _quant_operands(e2, e2s, M)
        q8, qs = quantize_rows(qn)
        w8, ws = quantize_rows(W)
    else:
        embn = e2[:, :M]
        emb = e2[:, M:]

    hp = has_primary[:, None]
    kmask = (jnp.arange(R) < k)[None, :]
    if use_pallas:
        # TPU structure: Pallas kernel for the kNN, one jnp top_k for
        # the fallback re-score (primary rows masked out of it)
        m1 = bar(masks_table[ci])
        if quant:
            vals, idx = _knn_pallas_q8(q8, qs, e8n, esn, m1, k, blk_q,
                                       blk_n, interpret)
        else:
            vals, idx = _knn_pallas(qn, embn, m1, k, blk_q, blk_n,
                                    interpret)
        finite = vals > NEG_INF
        idx_safe = jnp.where(finite, idx, 0)
        if quant:
            cscore = _q8_cscore(w8, ws, e8e[idx_safe], ese[idx_safe])
        else:
            cscore = jnp.einsum("bm,brm->br", W, emb[idx_safe])
        if extras is not None:
            cscore = cscore + jnp.take_along_axis(extras, idx_safe,
                                                  axis=1)
        cscore = jnp.where(finite, cscore, NEG_INF)
        cs, pos = jax.lax.top_k(cscore, k)
        cidx = jnp.take_along_axis(idx_safe, pos, axis=1)
        sim_p = jnp.take_along_axis(vals, pos[:, :1], axis=1)[:, 0]
        if R > k:
            cs = jnp.pad(cs, ((0, 0), (0, R - k)),
                         constant_values=NEG_INF)
            cidx = jnp.pad(cidx, ((0, 0), (0, R - k)))
        msel = masks_table[fi]
        if quant:
            acc_f = jax.lax.dot_general(
                w8, e8e, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)
            blend_f = acc_f.astype(jnp.float32) * (ws * ese[None, :])
        else:
            blend_f = W @ emb.T
        if extras is not None:
            blend_f = blend_f + extras
        zf = jnp.where(hp, NEG_INF,
                       jnp.where(msel, blend_f, NEG_INF))
        fv, fidx = jax.lax.top_k(zf, R)
        fidx_safe = jnp.where(fv > NEG_INF, fidx, 0)
        if quant:
            f0 = fidx_safe[:, 0]
            sim_f = (qn * e8n[f0].astype(jnp.float32)).sum(axis=1) \
                * esn[f0]
        else:
            sim_f = (qn * embn[fidx_safe[:, 0]]).sum(axis=1)
        cand_score = jnp.where(hp, cs, fv)
        cand_idx = jnp.where(hp, cidx, fidx_safe).astype(jnp.int32)
    else:
        # XLA:CPU structure: primary rows rank masked COSINE (the
        # kNN), fallback rows rank their rung-masked BLEND — the two
        # matrices are disjoint per row, so ONE block-diagonal matmul
        # ([qn | 0] or [0 | W] against [embn | emb]) and ONE top_k
        # serve the kNN and the whole fallback ladder together
        zi = jnp.where(has_primary, ci, fi)
        zmask = bar(masks_table[zi])                      # (B, Np)
        if quant:
            xsel = jnp.concatenate(
                [jnp.where(hp, q8, 0), jnp.where(hp, 0, w8)], axis=1)
            acc = jax.lax.dot_general(
                xsel, e2, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)         # (B, Np)
            rscale = jnp.where(hp, qs, ws)                # (B, 1)
            cscale = jnp.where(hp, esn[None, :], ese[None, :])
            zsrc = acc.astype(jnp.float32) * (rscale * cscale)
        else:
            xsel = jnp.concatenate(
                [jnp.where(hp, qn, 0.0), jnp.where(hp, 0.0, W)], axis=1)
            zsrc = xsel @ e2.T                            # (B, Np)
        if extras is not None:      # blend terms join fallback rows
            zsrc = zsrc + jnp.where(hp, 0.0, 1.0) * extras
        z = bar(jnp.where(zmask, zsrc, NEG_INF))
        vals, idx = bar(_hier_topk(z, R))
        finite = vals > NEG_INF
        idx_safe = jnp.where(finite, idx, 0)
        # primary candidates = the first k cosine-ranked positions;
        # their blended scores (computed at the k columns only, like
        # the staged gather) re-rank them in-program
        if quant:
            cscore = _q8_cscore(w8, ws, e8e[idx_safe], ese[idx_safe])
        else:
            cscore = jnp.einsum("bm,brm->br", W, emb[idx_safe])
        if extras is not None:
            cscore = cscore + jnp.take_along_axis(extras, idx_safe,
                                                  axis=1)
        cscore = jnp.where(finite & kmask, cscore, NEG_INF)
        cs, pos = jax.lax.top_k(cscore, R)
        cidx = jnp.take_along_axis(idx_safe, pos, axis=1)
        sim_p = jnp.take_along_axis(vals, pos[:, :1], axis=1)[:, 0]
        if quant:
            f0 = idx_safe[:, 0]
            sim_f = (qn * e8n[f0].astype(jnp.float32)).sum(axis=1) \
                * esn[f0]
        else:
            sim_f = (qn * embn[idx_safe[:, 0]]).sum(axis=1)
        cand_score = jnp.where(hp, cs, vals)
        cand_idx = jnp.where(hp, cidx, idx_safe).astype(jnp.int32)

    cand_idx = jnp.where(jnp.isfinite(cand_score), cand_idx, -1)
    nf = jnp.minimum(c_wide, k).astype(jnp.int32)
    return {
        "model_idx": cand_idx[:, 0],
        "score": cand_score[:, 0],
        "stage": jnp.where(has_primary, 0, stage_f).astype(jnp.int32),
        "similarity": jnp.where(has_primary, sim_p, sim_f),
        "cand_idx": cand_idx,
        "cand_score": cand_score,
        "n_filtered": jnp.where(has_primary, nf, 0).astype(jnp.int32),
        "n_candidates": jnp.where(has_primary, nf,
                                  counts_table[fi]).astype(jnp.int32),
    }


route_step_jit = jax.jit(
    _route_step_body,
    static_argnames=("k", "r", "n_tt", "n_dm", "has_fb",
                     "has_ad", "has_load", "use_pallas", "blk_q",
                     "blk_n", "interpret", "quant"))


# ----------------------------------------------------------------------
# IVF-pruned program: coarse centroid probe + packed-cell fine scan
# ----------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "r", "n_tt", "n_dm", "nprobe", "cap",
                     "has_fb", "has_ad", "has_load", "quant"))
def route_step_ivf_jit(e2, e2s, masks_table, counts_table, orig, cent,
                       T, W, ti, di, fb, theta, ainv_flat, lpen,
                       params, *, k: int, r: int, n_tt: int, n_dm: int,
                       nprobe: int, cap: int, has_fb: bool,
                       has_ad: bool, has_load: bool,
                       quant: bool = False):
    """IVF-pruned fused routing step over a CELL-PACKED catalog.

    ``ops.py`` permutes the catalog into contiguous equal-capacity
    cell blocks (``cap`` slots per cell, dead slots marked by
    ``orig < 0``); every catalog-shaped operand (e2/e2s, mask table
    columns, fb/theta/ainv/lpen) arrives in PACKED order, while
    ``counts_table`` keeps the TRUE full-catalog counts so the ladder
    semantics are untouched.  ``orig`` (Npk,) maps packed slots back
    to original catalog rows for the outputs; ``cent`` (C, M) is the
    unit-row centroid table.

    In-program, per query: rank all C centroids against the unit task
    vector, take the top-``nprobe`` cells, gather ONLY those cells'
    ``nprobe * cap`` packed slots, and run the mask-fused kNN + blend
    re-rank on the gathered sub-catalog — O(nprobe * cap) scan work
    instead of O(N).  Two escape hatches keep the ladder total:
    rows with an empty filter mask walk the usual fallback rungs, and
    rows whose PROBED cells miss every filter match re-score the
    exact full-mask blend (the widened-kNN rung, stage 1) — both
    inside one ``lax.cond`` whose full-catalog branch only executes
    when some row needs it.  Recall@k versus the exhaustive program
    is the ``nprobe`` knob; ``nprobe >= C`` is exhaustive.
    """
    B = T.shape[0]
    Npk = orig.shape[0]
    M = T.shape[1]
    C = cent.shape[0]
    Pn = min(nprobe, C)
    R = max(k, r)
    J = Pn * cap

    qn = T / (jnp.linalg.norm(T, axis=1, keepdims=True) + 1e-9)
    ci, c_wide, has_primary, fi, stage_f = _ladder(
        counts_table, ti, di, n_tt, n_dm)
    if quant:
        e8n, esn, e8e, ese = _quant_operands(e2, e2s, M)
        q8, qs = quantize_rows(qn)
        w8, ws = quantize_rows(W)

    # ---- coarse: rank centroids, select cells, gather their slots
    _, cells = jax.lax.top_k(qn @ cent.T, Pn)             # (B, Pn)
    gidx = (cells[:, :, None] * cap
            + jnp.arange(cap)[None, None, :]).reshape(B, J)
    valid = orig[gidx] >= 0                               # (B, J)
    mrow = masks_table[ci[:, None], gidx]                 # (B, J)

    # ---- fine: mask-fused kNN over the gathered sub-catalog only
    if quant:
        acc = jnp.einsum("bm,bjm->bj", q8.astype(jnp.int32),
                         e8n[gidx].astype(jnp.int32))
        sims = acc.astype(jnp.float32) * (qs * esn[gidx])
    else:
        sims = jnp.einsum("bm,bjm->bj", qn, e2[:, :M][gidx])
    z1 = jnp.where(mrow & valid, sims, NEG_INF)
    if J < k:
        z1 = jnp.pad(z1, ((0, 0), (0, k - J)), constant_values=NEG_INF)
        gidx = jnp.pad(gidx, ((0, 0), (0, k - J)))
    vals, pos = jax.lax.top_k(z1, k)                      # (B, k)
    finite = vals > NEG_INF
    pidx = jnp.take_along_axis(gidx, pos, axis=1)         # packed rows
    pidx_safe = jnp.where(finite, pidx, 0)
    has_knn = finite.any(axis=1)
    nf = finite.sum(axis=1).astype(jnp.int32)

    # ---- candidate re-rank at the k columns (gather-style extras)
    if quant:
        cscore = _q8_cscore(w8, ws, e8e[pidx_safe], ese[pidx_safe])
    else:
        cscore = jnp.einsum("bm,bkm->bk", W, e2[:, M:][pidx_safe])
    if has_fb:
        cscore = cscore + params[0] * jnp.take_along_axis(
            fb, pidx_safe, axis=1)
    if has_ad:
        ctx = jnp.concatenate([T, jnp.ones((B, 1), jnp.float32)],
                              axis=1)
        mean = jnp.einsum("bd,bkd->bk", ctx, theta[pidx_safe])
        xx = (ctx[:, :, None] * ctx[:, None, :]).reshape(B, -1)
        var = jnp.einsum("bd,bkd->bk", xx, ainv_flat[pidx_safe])
        cscore = cscore + params[1] * (
            mean + params[2] * jnp.sqrt(jnp.maximum(var, 0.0)))
    if has_load:
        cscore = cscore - lpen[pidx_safe]
    cscore = jnp.where(finite, cscore, NEG_INF)
    cs, cpos = jax.lax.top_k(cscore, k)
    cidx_pk = jnp.take_along_axis(pidx_safe, cpos, axis=1)
    sim_p = jnp.take_along_axis(vals, cpos[:, :1], axis=1)[:, 0]
    if R > k:
        cs = jnp.pad(cs, ((0, 0), (0, R - k)), constant_values=NEG_INF)
        cidx_pk = jnp.pad(cidx_pk, ((0, 0), (0, R - k)))

    # ---- escape hatch: count-0 ladder rows AND pruned-missed rows
    # (non-empty filter, no probed hit -> exact widened-kNN re-score).
    # One cond: the O(B, Npk) branch only runs when some row needs it.
    fsel = jnp.where(has_primary, ci, fi)
    fstage = jnp.where(has_primary, 1, stage_f).astype(jnp.int32)
    need = ~has_knn

    def _fallback(_):
        if quant:
            acc_f = jax.lax.dot_general(
                w8, e8e, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)
            blend = acc_f.astype(jnp.float32) * (ws * ese[None, :])
        else:
            blend = W @ e2[:, M:].T
        extras = _extras_matrix(T, fb, theta, ainv_flat, lpen, params,
                                B, Npk, has_fb=has_fb, has_ad=has_ad,
                                has_load=has_load)
        if extras is not None:
            blend = blend + extras
        msel = masks_table[fsel]
        zf = jnp.where(need[:, None] & msel & (orig >= 0)[None, :],
                       blend, NEG_INF)
        fv, fpi = jax.lax.top_k(zf, R)
        fpi_safe = jnp.where(fv > NEG_INF, fpi, 0)
        if quant:
            f0 = fpi_safe[:, 0]
            fcos = (qn * e8n[f0].astype(jnp.float32)).sum(axis=1) \
                * esn[f0]
        else:
            fcos = (qn * e2[fpi_safe[:, 0], :M]).sum(axis=1)
        return fv, fpi_safe, fcos

    def _no_fallback(_):
        return (jnp.full((B, R), NEG_INF, jnp.float32),
                jnp.zeros((B, R), jnp.int32),
                jnp.zeros((B,), jnp.float32))

    fv, fpi, fcos = jax.lax.cond(need.any(), _fallback, _no_fallback,
                                 operand=None)

    hk = has_knn[:, None]
    cand_score = jnp.where(hk, cs, fv)
    cand_pk = jnp.where(hk, cidx_pk, fpi)
    cand_idx = jnp.where(jnp.isfinite(cand_score), orig[cand_pk],
                         -1).astype(jnp.int32)
    return {
        "model_idx": cand_idx[:, 0],
        "score": cand_score[:, 0],
        "stage": jnp.where(has_knn, 0, fstage).astype(jnp.int32),
        "similarity": jnp.where(has_knn, sim_p, fcos),
        "cand_idx": cand_idx,
        "cand_score": cand_score,
        "n_filtered": jnp.where(has_knn, nf, 0).astype(jnp.int32),
        "n_candidates": jnp.where(has_knn, nf,
                                  counts_table[fsel]).astype(jnp.int32),
    }


# ----------------------------------------------------------------------
# sharded program: shard_map over the catalog axis + merge_topk tree
# ----------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "k", "r", "n_tt", "n_dm",
                     "has_fb", "has_ad", "has_load", "quant"))
def route_step_sharded_jit(e2, e2s, masks_table, counts_table, T, W,
                           ti, di, fb, theta, ainv_flat, lpen, params,
                           *, mesh, axis: str, k: int, r: int,
                           n_tt: int, n_dm: int, has_fb: bool,
                           has_ad: bool, has_load: bool,
                           quant: bool = False):
    """Cross-device fused routing step: the catalog axis of every
    (.., N) operand is sharded over ``mesh[axis]``; the batch axis is
    replicated.  STILL one dispatch per routed batch — the collective
    lives inside the one jitted program.

    Per shard (``shard_map`` body): the SAME block-diagonal local scan
    as the dense jnp program (quantized when ``quant``) over the
    shard's n_loc columns, a local exact top-R, then per-lane payloads
    computed LOCALLY while the shard still owns its catalog columns —
    global index (shard offset + local position), the candidate blend
    score, and the lane's cosine.  An ``all_gather`` of the sorted
    (B, R) carries feeds ``tree_merge_topk`` — PR 5's bitonic
    ``merge_topk`` applied as an allreduce-style pairwise tree, ties
    folding toward the lowest shard — so the merged lanes are exactly
    the single-device program's lanes, and the replicated finalize
    (candidate re-rank, fallback select, output masks) never touches
    catalog-sharded data again.  fp32 results are bit-identical to
    ``route_step_jit`` on untied scores; quantized results are
    bitwise-reproducible outright (exact integer dots).

    Shapes: identical to ``route_step_jit`` with Np divisible by
    ``mesh.shape[axis] * 128`` (``ops.n_bucket_sharded``).
    """
    Np = e2.shape[0]
    M = T.shape[1]
    B = T.shape[0]
    R = max(k, r)
    bar = jax.lax.optimization_barrier

    qn = T / (jnp.linalg.norm(T, axis=1, keepdims=True) + 1e-9)
    ci, c_wide, has_primary, fi, stage_f = _ladder(
        counts_table, ti, di, n_tt, n_dm)
    hp = has_primary[:, None]
    zi = jnp.where(has_primary, ci, fi)

    def _shard(e2_l, e2s_l, masks_l, fb_l, th_l, ai_l, lp_l, T, qn,
               W, zi, hpv, params):
        n_loc = e2_l.shape[0]
        hp = hpv[:, None]
        off = (jax.lax.axis_index(axis) * n_loc).astype(jnp.int32)
        extras = _extras_matrix(T, fb_l, th_l, ai_l, lp_l, params, B,
                                n_loc, has_fb=has_fb, has_ad=has_ad,
                                has_load=has_load)
        zmask = bar(masks_l[zi])                          # (B, n_loc)
        if quant:
            e8n, esn, e8e, ese = _quant_operands(e2_l, e2s_l, M)
            q8, qs = quantize_rows(qn)
            w8, ws = quantize_rows(W)
            xsel = jnp.concatenate(
                [jnp.where(hp, q8, 0), jnp.where(hp, 0, w8)], axis=1)
            acc = jax.lax.dot_general(
                xsel, e2_l, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)
            rscale = jnp.where(hp, qs, ws)
            cscale = jnp.where(hp, esn[None, :], ese[None, :])
            zsrc = acc.astype(jnp.float32) * (rscale * cscale)
        else:
            embn_l = e2_l[:, :M]
            emb_l = e2_l[:, M:]
            xsel = jnp.concatenate(
                [jnp.where(hp, qn, 0.0), jnp.where(hp, 0.0, W)],
                axis=1)
            zsrc = xsel @ e2_l.T
        if extras is not None:
            zsrc = zsrc + jnp.where(hp, 0.0, 1.0) * extras
        z = bar(jnp.where(zmask, zsrc, NEG_INF))
        # NOTE: no barrier around the top_k here — XLA:CPU's
        # TopkDecomposer aborts on an opt-barrier between a TopK and
        # its users inside an SPMD-partitioned computation
        vals, pos = _hier_topk(z, R)                      # local top-R
        finite = vals > NEG_INF
        pos_safe = jnp.where(finite, pos, 0)
        gidx = jnp.where(finite, off + pos, -1)
        # per-lane payloads, computed while the columns are local:
        # candidate blend score + lane cosine (the finalize gathers
        # are impossible post-merge — no shard owns the whole catalog)
        if quant:
            csc = _q8_cscore(w8, ws, e8e[pos_safe], ese[pos_safe])
            cos = (qn[:, None, :] * e8n[pos_safe].astype(jnp.float32)
                   ).sum(axis=-1) * esn[pos_safe]
        else:
            csc = jnp.einsum("bm,brm->br", W, emb_l[pos_safe])
            cos = (qn[:, None, :] * embn_l[pos_safe]).sum(axis=-1)
        if extras is not None:
            csc = csc + jnp.take_along_axis(extras, pos_safe, axis=1)
        # ---- cross-shard reduction: pairwise merge_topk tree over
        # the gathered sorted carries (ties -> lowest shard, matching
        # the single-device top_k contract)
        g = jax.lax.all_gather((vals, gidx, csc, cos), axis)
        mv, (mi, mc, ms) = tree_merge_topk(g[0], (g[1], g[2], g[3]))
        return mv, mi, mc, ms

    vals, idx, csc, cos = shard_map(
        _shard, mesh=mesh,
        in_specs=(P(axis, None),
                  P(axis, None) if quant else P(None, None),
                  P(None, axis),
                  P(None, axis) if has_fb else P(None, None),
                  P(axis, None) if has_ad else P(None, None),
                  P(axis, None) if has_ad else P(None, None),
                  P(axis) if has_load else P(None),
                  P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P()),
        check_rep=False,
    )(e2, e2s, masks_table, fb, theta, ainv_flat, lpen,
      T, qn, W, zi, has_primary, params)

    # ---- replicated finalize: identical to the dense jnp tail
    kmask = (jnp.arange(R) < k)[None, :]
    finite = vals > NEG_INF
    idx_safe = jnp.where(finite, idx, 0)
    cscore = jnp.where(finite & kmask, csc, NEG_INF)
    cs, pos = jax.lax.top_k(cscore, R)
    cidx = jnp.take_along_axis(idx_safe, pos, axis=1)
    sim_p = jnp.take_along_axis(vals, pos[:, :1], axis=1)[:, 0]
    sim_f = cos[:, 0]
    cand_score = jnp.where(hp, cs, vals)
    cand_idx = jnp.where(hp, cidx, idx_safe).astype(jnp.int32)

    cand_idx = jnp.where(jnp.isfinite(cand_score), cand_idx, -1)
    nf = jnp.minimum(c_wide, k).astype(jnp.int32)
    return {
        "model_idx": cand_idx[:, 0],
        "score": cand_score[:, 0],
        "stage": jnp.where(has_primary, 0, stage_f).astype(jnp.int32),
        "similarity": jnp.where(has_primary, sim_p, sim_f),
        "cand_idx": cand_idx,
        "cand_score": cand_score,
        "n_filtered": jnp.where(has_primary, nf, 0).astype(jnp.int32),
        "n_candidates": jnp.where(has_primary, nf,
                                  counts_table[fi]).astype(jnp.int32),
    }
