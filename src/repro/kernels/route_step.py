"""Fused single-dispatch routing step: the whole per-batch hot path of
``RoutingEngine.route_many`` as ONE jitted device program.

The staged path costs several device/numpy passes per batch — kNN
top-k, candidate gathers, feedback/bandit/load blends, argsort, plus
host-side per-row fallback retries.  ``route_step`` collapses all of it
into a single program (one device dispatch per routed batch):

  1. mask lookup        — the catalog's hierarchical-filter structure
     is pre-flattened by ``ops.py`` into ONE stacked mask table
     (task-type x domain combinations, then the fallback rungs:
     task-type-only rows, the generalist row, the live-catalog row)
     with a per-row population-count table.  Per-query masks and every
     ladder count are O(B) gathers — no (B, N) boolean reductions;
  2. score blend        — ONE (B, N) blend of user-weighted metric
     scores + feedback bias + LinUCB bandit estimates (mean + alpha *
     sqrt(x^T Ainv x), both as matmuls over the flattened rank-1
     layout) - load penalty;
  3. fused top-k        — primary rows rank the mask-fused COSINE
     similarities (the kNN), rows whose filter count is zero rank the
     BLEND under their first non-empty fallback rung instead: both
     live in one per-row-selected matrix, so a single ``top_k`` serves
     the kNN and the whole fallback ladder (masked re-scores inside
     the program, not host-side retries);
  4. candidate argmax   — primary candidates gather their blended
     scores from (2) and re-rank in-program (``top_k`` over k lanes),
     so the winner, its score and the ranked candidate list come out
     as arrays.

On TPU (``use_pallas``) the kNN stage runs the Pallas ``router_topk``
kernel (blocked MXU matmul + the shared ``block_topk``/``merge_topk``
carry update) and the fallback re-score is its own ``top_k`` — the
structure XLA:TPU prefers; the single-matrix form above is the
XLA:CPU-friendly lowering the test suite exercises.

``jax.lax.optimization_barrier`` pins the big (B, N) intermediates:
without it XLA:CPU duplicates cheap producers (mask gathers, where
chains) into every consumer and the program slows ~20x.

All shapes are static per (Q bucket, padded catalog) pair — ``ops.py``
pads Q up to power-of-two buckets and N to the catalog's 128-aligned
capacity, so steady-state serving re-dispatches one cached executable
regardless of batch size.  Padded query rows compute garbage and are
sliced off; padded catalog columns are False in every mask row.

The pure-jnp semantic ground truth lives in ``kernels/ref.py``
(``ref.route_step``); parity is pinned by tests against both the
oracle and the staged numpy path in ``core/routing.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.router_topk import router_topk_pallas

NEG_INF = float("-inf")


def _hier_topk(z, kk: int, chunk: int = 32):
    """Exact top-kk of (B, Np) via chunk-max pruning.

    XLA:CPU's TopK emitter costs ~O(elements) at a poor rate, while a
    plain max reduction is fast.  So: per-chunk maxima (one cheap
    reduce), keep the kk chunks with the largest maxima — any true
    top-kk element must live in one of them, since each excluded
    chunk's max is dominated by kk other chunks' maxima — gather those
    chunks, and run the expensive TopK over kk*chunk columns instead
    of Np.  Values are exact; index tie-breaks can differ from
    ``lax.top_k`` when equal values straddle chunk boundaries (same
    contract as the Pallas kernel's block merge).
    """
    B, Np = z.shape
    C = Np // chunk
    if kk > chunk or C <= kk or Np % chunk:
        return jax.lax.top_k(z, kk)
    m3 = z.reshape(B, C, chunk)
    mx = m3.max(axis=2)                                   # (B, C)
    _, cj = jax.lax.top_k(mx, kk)                         # (B, kk)
    sub = m3[jnp.arange(B)[:, None], cj]                  # (B, kk, chunk)
    v, p = jax.lax.top_k(sub.reshape(B, kk * chunk), kk)
    gi = jnp.take_along_axis(cj, p // chunk, axis=1) * chunk \
        + p % chunk
    return v, gi


def _knn_pallas(qn, embn, m1, k, blk_q, blk_n, interpret):
    """Mask-fused kNN through the Pallas kernel (TPU path).

    Shapes arrive bucket-padded (Q % blk_q == 0, N % blk_n == 0); only
    the feature axis still needs its 128-lane pad here.
    """
    Q, D = qn.shape
    N = embn.shape[0]
    dpad = (-D) % 128
    qnp = jnp.pad(qn, ((0, 0), (0, dpad)))
    ewp = jnp.pad(embn, ((0, 0), (0, dpad)))
    bias = jnp.zeros((1, N), jnp.float32)
    return router_topk_pallas(qnp, ewp, m1.astype(jnp.float32), bias, k,
                              blk_q=blk_q, blk_n=blk_n,
                              interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("k", "r", "n_tt", "n_dm", "has_fb",
                     "has_ad", "has_load", "use_pallas", "blk_q",
                     "blk_n", "interpret"))
def route_step_jit(e2, masks_table, counts_table, T, W, ti, di, fb,
                   theta, ainv_flat, lpen, params, *, k: int, r: int,
                   n_tt: int, n_dm: int, has_fb: bool,
                   has_ad: bool, has_load: bool, use_pallas: bool,
                   blk_q: int, blk_n: int, interpret: bool):
    """One fused routing step over a bucket-padded batch.

    The live catalog size is deliberately NOT a parameter: liveness is
    fully encoded in the mask table (padded columns are False in every
    row, including the live-catalog rung) and the zeroed e2 pad rows,
    so catalog growth within one 128-padded capacity bucket reuses the
    cached executable without recompiling.

    e2 (Np, 2M) catalog block ``[embn | emb]`` — unit-normalized rows
    for the cosine kNN next to the raw normalized-metric rows for the
    score blend, precomputed once per catalog by ``ops.py`` (zero rows
    beyond the live count); masks_table (n_tt*n_dm + n_tt + 2, Np) stacked
    boolean mask rows — every task-type x domain combination, then the
    fallback rungs (task-type-only rows, the generalist row, the
    live-catalog row); counts_table (rows,) i32 per-row population
    counts; T (Qp, M) kNN task vectors; W (Qp, M) scoring weights;
    ti/di (Qp,) per-query filter row indices; fb (Qp, Np) feedback
    bias (dummy when ``has_fb`` False); theta (Np, Dc) / ainv_flat
    (Np, Dc*Dc) bandit posterior (LinUCB; dummies when ``has_ad``
    False); lpen (Np,) pre-scaled load penalty (dummy when
    ``has_load`` False); params (3,) f32 traced scalars
    [feedback_weight, adaptive_weight, alpha].

    Returns a dict of (Qp,)/(Qp, R) arrays with R = max(k, r):
    ``model_idx``, ``score``, ``stage`` (0 = primary, 1.. = fallback
    ladder rung), ``similarity``, ``cand_idx``/``cand_score`` (ranked,
    -1/-inf padded), ``n_filtered``, ``n_candidates``.
    """
    bar = jax.lax.optimization_barrier
    Np, M2 = e2.shape
    M = M2 // 2
    embn = e2[:, :M]
    emb = e2[:, M:]
    B = T.shape[0]
    n_combo = n_tt * n_dm
    R = max(k, r)

    qn = T / (jnp.linalg.norm(T, axis=1, keepdims=True) + 1e-9)

    # per-query mask rows and ladder counts: O(B) table gathers
    ci = ti * n_dm + di                                   # combined row
    c_wide = counts_table[ci]
    has_primary = c_wide > 0
    c_tt = counts_table[n_combo + ti]
    c_gen = counts_table[n_combo + n_tt]
    # first non-empty fallback rung (widened-kNN == the fused mask, so
    # it is empty for every fallback row by construction): task-type-
    # only -> generalist -> any(live)
    fi = jnp.where(c_tt > 0, n_combo + ti,
                   jnp.where(c_gen > 0, n_combo + n_tt,
                             n_combo + n_tt + 1))
    stage_f = jnp.where(c_tt > 0, 2,
                        jnp.where(c_gen > 0, 3, 4)).astype(jnp.int32)

    # ---- extra blend terms (feedback / bandit / load), one (B, N)
    # matrix when any is active; None costs nothing ----
    extras = None
    if has_fb:
        extras = params[0] * fb
    if has_ad:
        ctx = jnp.concatenate(
            [T, jnp.ones((B, 1), jnp.float32)], axis=1)   # (B, Dc)
        mean = ctx @ theta.T                              # (B, Np)
        xx = (ctx[:, :, None] * ctx[:, None, :]).reshape(B, -1)
        var = xx @ ainv_flat.T                            # (B, Np)
        ucb = params[1] * (
            mean + params[2] * jnp.sqrt(jnp.maximum(var, 0.0)))
        extras = ucb if extras is None else extras + ucb
    if has_load:
        lrow = jnp.broadcast_to(-lpen[None, :], (B, Np))
        extras = lrow if extras is None else extras - lpen[None, :]
    if extras is not None:
        extras = bar(extras)

    hp = has_primary[:, None]
    kmask = (jnp.arange(R) < k)[None, :]
    if use_pallas:
        # TPU structure: Pallas kernel for the kNN, one jnp top_k for
        # the fallback re-score (primary rows masked out of it)
        m1 = bar(masks_table[ci])
        vals, idx = _knn_pallas(qn, embn, m1, k, blk_q, blk_n,
                                interpret)
        finite = vals > NEG_INF
        idx_safe = jnp.where(finite, idx, 0)
        cscore = jnp.einsum("bm,brm->br", W, emb[idx_safe])
        if extras is not None:
            cscore = cscore + jnp.take_along_axis(extras, idx_safe,
                                                  axis=1)
        cscore = jnp.where(finite, cscore, NEG_INF)
        cs, pos = jax.lax.top_k(cscore, k)
        cidx = jnp.take_along_axis(idx_safe, pos, axis=1)
        sim_p = jnp.take_along_axis(vals, pos[:, :1], axis=1)[:, 0]
        if R > k:
            cs = jnp.pad(cs, ((0, 0), (0, R - k)),
                         constant_values=NEG_INF)
            cidx = jnp.pad(cidx, ((0, 0), (0, R - k)))
        msel = masks_table[fi]
        blend_f = W @ emb.T
        if extras is not None:
            blend_f = blend_f + extras
        zf = jnp.where(hp, NEG_INF,
                       jnp.where(msel, blend_f, NEG_INF))
        fv, fidx = jax.lax.top_k(zf, R)
        fidx_safe = jnp.where(fv > NEG_INF, fidx, 0)
        sim_f = (qn * embn[fidx_safe[:, 0]]).sum(axis=1)
        cand_score = jnp.where(hp, cs, fv)
        cand_idx = jnp.where(hp, cidx, fidx_safe).astype(jnp.int32)
    else:
        # XLA:CPU structure: primary rows rank masked COSINE (the
        # kNN), fallback rows rank their rung-masked BLEND — the two
        # matrices are disjoint per row, so ONE block-diagonal matmul
        # ([qn | 0] or [0 | W] against [embn | emb]) and ONE top_k
        # serve the kNN and the whole fallback ladder together
        zi = jnp.where(has_primary, ci, fi)
        zmask = bar(masks_table[zi])                      # (B, Np)
        xsel = jnp.concatenate(
            [jnp.where(hp, qn, 0.0), jnp.where(hp, 0.0, W)], axis=1)
        zsrc = xsel @ e2.T                                # (B, Np)
        if extras is not None:      # blend terms join fallback rows
            zsrc = zsrc + jnp.where(hp, 0.0, 1.0) * extras
        z = bar(jnp.where(zmask, zsrc, NEG_INF))
        vals, idx = bar(_hier_topk(z, R))
        finite = vals > NEG_INF
        idx_safe = jnp.where(finite, idx, 0)
        # primary candidates = the first k cosine-ranked positions;
        # their blended scores (computed at the k columns only, like
        # the staged gather) re-rank them in-program
        cscore = jnp.einsum("bm,brm->br", W, emb[idx_safe])
        if extras is not None:
            cscore = cscore + jnp.take_along_axis(extras, idx_safe,
                                                  axis=1)
        cscore = jnp.where(finite & kmask, cscore, NEG_INF)
        cs, pos = jax.lax.top_k(cscore, R)
        cidx = jnp.take_along_axis(idx_safe, pos, axis=1)
        sim_p = jnp.take_along_axis(vals, pos[:, :1], axis=1)[:, 0]
        sim_f = (qn * embn[idx_safe[:, 0]]).sum(axis=1)
        cand_score = jnp.where(hp, cs, vals)
        cand_idx = jnp.where(hp, cidx, idx_safe).astype(jnp.int32)

    cand_idx = jnp.where(jnp.isfinite(cand_score), cand_idx, -1)
    nf = jnp.minimum(c_wide, k).astype(jnp.int32)
    return {
        "model_idx": cand_idx[:, 0],
        "score": cand_score[:, 0],
        "stage": jnp.where(has_primary, 0, stage_f).astype(jnp.int32),
        "similarity": jnp.where(has_primary, sim_p, sim_f),
        "cand_idx": cand_idx,
        "cand_score": cand_score,
        "n_filtered": jnp.where(has_primary, nf, 0).astype(jnp.int32),
        "n_candidates": jnp.where(has_primary, nf,
                                  counts_table[fi]).astype(jnp.int32),
    }
