"""Pallas TPU kernel: fused routing-score top-k over the MRES catalog.

The paper's hot loop is "approximate kNN in an in-memory vector DB".
On TPU we recast it (DESIGN.md §3) as a dense blocked matmul with the
hierarchical-filter mask fused in-register and a running top-k carried
in VMEM scratch across catalog blocks:

  grid = (Q/BLK_Q, N/BLK_N), catalog axis innermost (sequential)
  per step:  scores = q_blk @ emb_blk^T            (MXU, 128-aligned)
             scores = where(mask_blk, scores, -inf) (VPU)
             block top-k, then a sorted pairwise merge with the
             running (vals, idx) carry

The carry update is a per-block ``jax.lax.top_k`` followed by a
bitonic merge of two sorted (Q, k) carries — O(k log k) per grid step
on top of the block top-k, replacing the earlier k-pass argmax +
one-hot scatter over a concatenated (Q, k + BLK_N) buffer
(O(k * (k + BLK_N)) per step).  ``merge_topk``/``block_topk`` are
shared with the fused ``route_step`` kernel.

Dense blocked scan beats ANN graph traversal on TPU because pointer
chasing is hostile to the systolic pipeline while a 100k x 128 catalog
tile stream is a few MB of sequential VMEM traffic.

Inputs are pre-normalized by ops.py (rows scaled to unit norm, weights
folded into the catalog matrix) so the kernel is a pure
score-mask-select loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float("-inf")


def _pow2_ge(x: int) -> int:
    """Smallest power of two >= x (x >= 1)."""
    return 1 << max(x - 1, 1).bit_length() if x > 1 else 1


def block_topk(scores, col_idx, k: int):
    """Top-k of one (Q, M) score block, descending, padded out to k.

    ``col_idx`` (Q, M) carries the global catalog column of each score.
    When the block is narrower than k (k > BLK_N) the tail pads with
    (-inf, -1).  Returns (vals (Q, k), idx (Q, k)) sorted descending.
    """
    m = scores.shape[1]
    kk = min(k, m)
    v, p = jax.lax.top_k(scores, kk)
    i = jnp.take_along_axis(col_idx, p, axis=1)
    if kk < k:
        v = jnp.pad(v, ((0, 0), (0, k - kk)), constant_values=NEG_INF)
        i = jnp.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
    return v, i


def _pad_const(p):
    """Pad filler per payload dtype: -1 for integer lanes (index
    semantics), 0 for float side-payloads (masked by -inf values)."""
    return -1 if jnp.issubdtype(p.dtype, jnp.integer) else 0


def merge_topk_multi(av, bv, a_payloads, b_payloads):
    """Top-k union of two sorted-descending (Q, k) carries, with any
    number of payload columns riding along every compare-exchange.

    The values follow the same bitonic structure as ``merge_topk``
    (one reversal exchange keeps the k largest of the 2k, then
    log2(k) merge stages sort descending); each payload in
    ``a_payloads``/``b_payloads`` (tuples of (Q, k) arrays — indices,
    per-lane blend scores, cosines, ...) takes the exact same keep
    mask as the values, so lanes never mix payloads.  Ties keep the
    ``a`` element — chaining merges in shard order therefore resolves
    cross-shard ties toward the LOWEST shard, matching ``lax.top_k``'s
    lowest-index-first contract on a concatenated catalog.  Returns
    (vals (Q, k), tuple of merged payloads).
    """
    assert len(a_payloads) == len(b_payloads)
    k = av.shape[1]
    kp = _pow2_ge(k)
    a_pl, b_pl = list(a_payloads), list(b_payloads)
    if kp != k:
        pad = ((0, 0), (0, kp - k))
        av = jnp.pad(av, pad, constant_values=NEG_INF)
        bv = jnp.pad(bv, pad, constant_values=NEG_INF)
        a_pl = [jnp.pad(p, pad, constant_values=_pad_const(p))
                for p in a_pl]
        b_pl = [jnp.pad(p, pad, constant_values=_pad_const(p))
                for p in b_pl]
    rv = bv[:, ::-1]
    r_pl = [p[:, ::-1] for p in b_pl]
    keep_a = av >= rv
    v = jnp.where(keep_a, av, rv)
    pl = [jnp.where(keep_a, pa, pr) for pa, pr in zip(a_pl, r_pl)]
    # v is bitonic; sort descending with a standard bitonic merger
    s = kp // 2
    while s >= 1:
        pos = jnp.arange(kp)
        pv = v[:, pos ^ s]
        first = ((pos & s) == 0)[None, :]       # lower index of each pair
        keep = jnp.where(first, v >= pv, v <= pv)
        pl = [jnp.where(keep, p, p[:, pos ^ s]) for p in pl]
        v = jnp.where(keep, v, pv)
        s //= 2
    return v[:, :k], tuple(p[:, :k] for p in pl)


def merge_topk(av, ai, bv, bi):
    """Top-k of the union of two sorted-descending (Q, k) carries.

    One bitonic compare-exchange of ``a`` against ``b`` reversed keeps
    the k largest of the 2k (a bitonic sequence), then log2(k) merge
    stages sort it descending — O(k log k) total, vs O(k^2 + k*BLK_N)
    for re-running a k-pass argmax over the concatenation.  Indices
    ride along through every exchange; ties keep the ``a`` (carry)
    element, and within the sort both sides of an equal pair keep
    their own payload, so no element is ever duplicated or dropped.
    Inputs need not be power-of-two wide (padded internally).
    One-payload wrapper over ``merge_topk_multi`` (shared with the
    cross-shard tree reduction in ``route_step``).
    """
    v, (i,) = merge_topk_multi(av, bv, (ai,), (bi,))
    return v, i


def tree_merge_topk(vals, payloads):
    """Pairwise-tree reduction of S sorted-descending per-shard
    carries into ONE global (Q, k) top-k — the cross-shard step of the
    sharded ``route_step``.

    vals (S, Q, k) stacked per-shard top-k values (shard-major, e.g.
    from ``lax.all_gather``); payloads: tuple of (S, Q, k) arrays.
    Merges adjacent pairs per level (log2(S) levels of
    ``merge_topk_multi``), always folding the HIGHER shard into the
    lower so ties resolve toward the lowest shard — the same winner a
    single-device ``top_k`` over the concatenated catalog picks.
    Returns (vals (Q, k), tuple of payloads (Q, k)).
    """
    S = vals.shape[0]
    parts = [(vals[s], tuple(p[s] for p in payloads)) for s in range(S)]
    while len(parts) > 1:
        nxt = []
        for i in range(0, len(parts) - 1, 2):
            (av, apl), (bv, bpl) = parts[i], parts[i + 1]
            nxt.append(merge_topk_multi(av, bv, apl, bpl))
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


def _router_topk_kernel(q_ref, emb_ref, mask_ref, bias_ref, vals_ref,
                        idx_ref, sv_ref, si_ref, *, k: int, blk_n: int,
                        min_score: float):
    jn = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(jn == 0)
    def _init():
        sv_ref[...] = jnp.full_like(sv_ref, NEG_INF)
        si_ref[...] = jnp.full_like(si_ref, -1)

    q = q_ref[...].astype(jnp.float32)                      # (BLK_Q, D)
    emb = emb_ref[...].astype(jnp.float32)                  # (BLK_N, D)
    mask = mask_ref[...]                                    # (BLK_Q, BLK_N)
    bias = bias_ref[...]                                    # (1, BLK_N)
    scores = jax.lax.dot_general(
        q, emb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (BLK_Q, BLK_N)
    # bias joins valid rows only: a heavy load penalty must stay
    # distinguishable from a failed hierarchical filter (-inf)
    scores = jnp.where(mask > 0, scores + bias, NEG_INF)
    if min_score != NEG_INF:
        # fused admission threshold (the semantic cache's similarity
        # floor): sub-threshold rows drop out in-register, so callers
        # never see a "best" match that is not a usable one
        scores = jnp.where(scores >= min_score, scores, NEG_INF)

    col0 = jn * blk_n
    col_idx = col0 + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    bv, bi = block_topk(scores, col_idx, k)
    new_v, new_i = merge_topk(sv_ref[...], si_ref[...], bv, bi)
    sv_ref[...] = new_v
    si_ref[...] = new_i

    @pl.when(jn == nn - 1)
    def _emit():
        vals_ref[...] = sv_ref[...]
        idx_ref[...] = si_ref[...]


@functools.partial(jax.jit, static_argnames=("k", "blk_q", "blk_n",
                                             "min_score", "interpret"))
def router_topk_pallas(qn: jnp.ndarray, embn: jnp.ndarray, mask: jnp.ndarray,
                       bias: jnp.ndarray, k: int, *, blk_q: int = 8,
                       blk_n: int = 512, min_score: float = NEG_INF,
                       interpret: bool = True):
    """qn (Q, D) unit rows; embn (N, D) unit(+weighted) rows;
    mask (Q, N) f32 — per-query hierarchical filter mask (ops.py
    broadcasts a shared (N,) mask to all queries); bias (1, N) f32 —
    additive per-catalog-row score term (zeros when unused), applied
    to mask-valid rows in-register right after the scoring matmul;
    min_score — static score floor fused after mask+bias (rows below
    it surface as -inf; -inf disables the threshold).

    Q % blk_q == 0, N % blk_n == 0, D padded to 128 (done by ops.py).
    Returns (vals (Q, k) f32, idx (Q, k) i32).
    """
    Q, D = qn.shape
    N = embn.shape[0]
    assert Q % blk_q == 0 and N % blk_n == 0, (Q, N, blk_q, blk_n)
    assert mask.shape == (Q, N), (mask.shape, Q, N)
    assert bias.shape == (1, N), (bias.shape, N)
    grid = (Q // blk_q, N // blk_n)

    kernel = functools.partial(_router_topk_kernel, k=k, blk_n=blk_n,
                               min_score=min_score)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q, D), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_n, D), lambda i, j: (j, 0)),
            pl.BlockSpec((blk_q, blk_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, blk_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((blk_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q, k), jnp.float32),
            pltpu.VMEM((blk_q, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qn, embn, mask, bias)
    return vals, idx


# ----------------------------------------------------------------------
# int8 variant: dequant-free int32 accumulate, fp32 rescale at the
# top-k boundary
# ----------------------------------------------------------------------

def _router_topk_q8_kernel(q_ref, emb_ref, qs_ref, es_ref, mask_ref,
                           bias_ref, vals_ref, idx_ref, sv_ref, si_ref,
                           *, k: int, blk_n: int, min_score: float):
    jn = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(jn == 0)
    def _init():
        sv_ref[...] = jnp.full_like(sv_ref, NEG_INF)
        si_ref[...] = jnp.full_like(si_ref, -1)

    q8 = q_ref[...]                                         # (BLK_Q, D) i8
    e8 = emb_ref[...]                                       # (BLK_N, D) i8
    # the scan matmul accumulates in int32 — no dequantized fp32 copy
    # of the catalog block ever materializes; the only fp32 work per
    # (BLK_Q, BLK_N) tile is ONE elementwise rescale by the per-row
    # scale outer product, right at the top-k boundary
    acc = jax.lax.dot_general(
        q8, e8, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)                   # (BLK_Q, BLK_N)
    scores = acc.astype(jnp.float32) * (qs_ref[...] * es_ref[...])
    scores = jnp.where(mask_ref[...] > 0, scores + bias_ref[...], NEG_INF)
    if min_score != NEG_INF:
        scores = jnp.where(scores >= min_score, scores, NEG_INF)

    col0 = jn * blk_n
    col_idx = col0 + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    bv, bi = block_topk(scores, col_idx, k)
    new_v, new_i = merge_topk(sv_ref[...], si_ref[...], bv, bi)
    sv_ref[...] = new_v
    si_ref[...] = new_i

    @pl.when(jn == nn - 1)
    def _emit():
        vals_ref[...] = sv_ref[...]
        idx_ref[...] = si_ref[...]


@functools.partial(jax.jit, static_argnames=("k", "blk_q", "blk_n",
                                             "min_score", "interpret"))
def router_topk_q8_pallas(q8: jnp.ndarray, e8: jnp.ndarray,
                          qscale: jnp.ndarray, escale: jnp.ndarray,
                          mask: jnp.ndarray, bias: jnp.ndarray, k: int,
                          *, blk_q: int = 8, blk_n: int = 512,
                          min_score: float = NEG_INF,
                          interpret: bool = True):
    """int8-quantized ``router_topk_pallas``.

    q8 (Q, D) / e8 (N, D) int8 rows quantized symmetrically per row;
    qscale (Q, 1) / escale (1, N) f32 per-row scales such that the
    fp32 score of (q, n) is ``(q8[q] . e8[n]) * qscale[q] * escale[n]``.
    The per-block matmul runs on the int8 operands with an int32
    accumulator (``preferred_element_type``) — the catalog stream is
    1/4 the bytes of the fp32 kernel, and on a memory-bandwidth-bound
    scan that is the speedup (see benchmarks/roofline.py) — and the
    fp32 rescale happens once per tile at the top-k boundary.

    NOTE on tiling: the TPU int8 minimum tile is (32, 128); compiled
    (non-interpret) runs should use blk_q % 32 == 0.  The interpret
    path (CPU CI) accepts the fp32 default blk_q=8.

    Same shape contract and returns as ``router_topk_pallas``.
    """
    Q, D = q8.shape
    N = e8.shape[0]
    assert q8.dtype == jnp.int8 and e8.dtype == jnp.int8, (q8.dtype,
                                                          e8.dtype)
    assert Q % blk_q == 0 and N % blk_n == 0, (Q, N, blk_q, blk_n)
    assert qscale.shape == (Q, 1) and escale.shape == (1, N), (
        qscale.shape, escale.shape)
    assert mask.shape == (Q, N) and bias.shape == (1, N)
    grid = (Q // blk_q, N // blk_n)

    kernel = functools.partial(_router_topk_q8_kernel, k=k, blk_n=blk_n,
                               min_score=min_score)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q, D), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_n, D), lambda i, j: (j, 0)),
            pl.BlockSpec((blk_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, blk_n), lambda i, j: (0, j)),
            pl.BlockSpec((blk_q, blk_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, blk_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((blk_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q, k), jnp.float32),
            pltpu.VMEM((blk_q, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q8, e8, qscale, escale, mask, bias)
    return vals, idx
