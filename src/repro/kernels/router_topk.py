"""Pallas TPU kernel: fused routing-score top-k over the MRES catalog.

The paper's hot loop is "approximate kNN in an in-memory vector DB".
On TPU we recast it (DESIGN.md §3) as a dense blocked matmul with the
hierarchical-filter mask fused in-register and a running top-k carried
in VMEM scratch across catalog blocks:

  grid = (Q/BLK_Q, N/BLK_N), catalog axis innermost (sequential)
  per step:  scores = q_blk @ emb_blk^T            (MXU, 128-aligned)
             scores = where(mask_blk, scores, -inf) (VPU)
             merge into running (vals, idx) top-k   (k-pass argmax)

Dense blocked scan beats ANN graph traversal on TPU because pointer
chasing is hostile to the systolic pipeline while a 100k x 128 catalog
tile stream is a few MB of sequential VMEM traffic.

Inputs are pre-normalized by ops.py (rows scaled to unit norm, weights
folded into the catalog matrix) so the kernel is a pure
score-mask-select loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float("-inf")


def _select_topk(vals, idx, k):
    """k-pass argmax top-k along axis 1. vals (Q, M) f32, idx (Q, M) i32."""
    out_v = []
    out_i = []
    for _ in range(k):
        am = jnp.argmax(vals, axis=1)                       # (Q,)
        rows = jnp.arange(vals.shape[0])
        out_v.append(vals[rows, am])
        out_i.append(idx[rows, am])
        onehot = jax.nn.one_hot(am, vals.shape[1], dtype=jnp.bool_)
        vals = jnp.where(onehot, NEG_INF, vals)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1)


def _router_topk_kernel(q_ref, emb_ref, mask_ref, bias_ref, vals_ref,
                        idx_ref, sv_ref, si_ref, *, k: int, blk_n: int,
                        min_score: float):
    jn = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(jn == 0)
    def _init():
        sv_ref[...] = jnp.full_like(sv_ref, NEG_INF)
        si_ref[...] = jnp.full_like(si_ref, -1)

    q = q_ref[...].astype(jnp.float32)                      # (BLK_Q, D)
    emb = emb_ref[...].astype(jnp.float32)                  # (BLK_N, D)
    mask = mask_ref[...]                                    # (BLK_Q, BLK_N)
    bias = bias_ref[...]                                    # (1, BLK_N)
    scores = jax.lax.dot_general(
        q, emb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (BLK_Q, BLK_N)
    # bias joins valid rows only: a heavy load penalty must stay
    # distinguishable from a failed hierarchical filter (-inf)
    scores = jnp.where(mask > 0, scores + bias, NEG_INF)
    if min_score != NEG_INF:
        # fused admission threshold (the semantic cache's similarity
        # floor): sub-threshold rows drop out in-register, so callers
        # never see a "best" match that is not a usable one
        scores = jnp.where(scores >= min_score, scores, NEG_INF)

    col0 = jn * blk_n
    col_idx = col0 + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    comb_v = jnp.concatenate([sv_ref[...], scores], axis=1)
    comb_i = jnp.concatenate([si_ref[...], col_idx], axis=1)
    new_v, new_i = _select_topk(comb_v, comb_i, k)
    sv_ref[...] = new_v
    si_ref[...] = new_i

    @pl.when(jn == nn - 1)
    def _emit():
        vals_ref[...] = sv_ref[...]
        idx_ref[...] = si_ref[...]


@functools.partial(jax.jit, static_argnames=("k", "blk_q", "blk_n",
                                             "min_score", "interpret"))
def router_topk_pallas(qn: jnp.ndarray, embn: jnp.ndarray, mask: jnp.ndarray,
                       bias: jnp.ndarray, k: int, *, blk_q: int = 8,
                       blk_n: int = 512, min_score: float = NEG_INF,
                       interpret: bool = True):
    """qn (Q, D) unit rows; embn (N, D) unit(+weighted) rows;
    mask (Q, N) f32 — per-query hierarchical filter mask (ops.py
    broadcasts a shared (N,) mask to all queries); bias (1, N) f32 —
    additive per-catalog-row score term (zeros when unused), applied
    to mask-valid rows in-register right after the scoring matmul;
    min_score — static score floor fused after mask+bias (rows below
    it surface as -inf; -inf disables the threshold).

    Q % blk_q == 0, N % blk_n == 0, D padded to 128 (done by ops.py).
    Returns (vals (Q, k) f32, idx (Q, k) i32).
    """
    Q, D = qn.shape
    N = embn.shape[0]
    assert Q % blk_q == 0 and N % blk_n == 0, (Q, N, blk_q, blk_n)
    assert mask.shape == (Q, N), (mask.shape, Q, N)
    assert bias.shape == (1, N), (bias.shape, N)
    grid = (Q // blk_q, N // blk_n)

    kernel = functools.partial(_router_topk_kernel, k=k, blk_n=blk_n,
                               min_score=min_score)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q, D), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_n, D), lambda i, j: (j, 0)),
            pl.BlockSpec((blk_q, blk_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, blk_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((blk_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q, k), jnp.float32),
            pltpu.VMEM((blk_q, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qn, embn, mask, bias)
    return vals, idx
