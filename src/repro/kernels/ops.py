"""Public jit'd wrappers around the Pallas kernels.

Each op pads/normalizes inputs to kernel-friendly (128-aligned) shapes,
invokes the kernel, and slices back.  ``interpret`` defaults to True off
TPU (the kernels execute under the Pallas interpreter on CPU — that is
how this repo validates them); on a real TPU backend it defaults to
compiled mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.analyze_step import (analyze_route_step_jit,
                                        analyze_step_jit)
from repro.kernels.bandit_update import bandit_update_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gating import moe_gating_pallas
from repro.kernels.route_step import (route_step_ivf_jit, route_step_jit,
                                      route_step_sharded_jit)
from repro.kernels.router_topk import (router_topk_pallas,
                                       router_topk_q8_pallas)
from repro.kernels.ssd_scan import ssd_scan_pallas

LANE = 128


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult: int, axis: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _clamp_blk_n(blk_n: int, n: int) -> int:
    """Shrink a catalog block size toward n (rounded up to a power of
    two, floored at one 128 lane) so tiny catalogs are one block."""
    return min(blk_n, max(1 << max(n - 1, 1).bit_length(), 128))


# ----------------------------------------------------------------------
# shape buckets: recompile-free serving-time dispatch
# ----------------------------------------------------------------------
# A jitted program compiles once per input-shape tuple, and a serving
# stream carries every batch size between 1 and the engine's cap.  The
# bucket policy trades a bounded amount of padded compute for a
# bounded, quickly-warmed set of executables:
#   * query axis  -> power-of-two buckets (floor 8): log2(Bmax) shapes
#     cover every batch size, and the pad waste is < 2x;
#   * catalog axis -> the catalog's 128-lane-aligned capacity: batch
#     size never touches it, so it only recompiles when the catalog
#     itself grows (model registration / merging).
# Bucket-padded rows/columns are masked out of every stage, never
# selected, and sliced off the outputs.

def q_bucket(q: int) -> int:
    """Power-of-two query-axis bucket (floor 8)."""
    return max(8, 1 << max(q - 1, 1).bit_length())


def n_bucket(n: int) -> int:
    """128-lane-aligned catalog-axis capacity (floor 128)."""
    return max(128, -(-n // LANE) * LANE)


def n_bucket_sharded(n: int, ndev: int) -> int:
    """Catalog capacity for the mesh-sharded path: every shard gets an
    equal 128-lane-aligned slice, so the bucket is the next multiple
    of ``ndev * 128``."""
    step = ndev * LANE
    return max(step, -(-n // step) * step)


# dispatch/compile counters for the bucketed serving-path ops —
# ``route_step`` also reports each call's (1 dispatch, compile delta)
# straight to an attached Telemetry, so concurrent routing threads
# never misattribute each other's activity.  A "dispatch" counts one
# fused-op invocation (each issues exactly one jitted call); the
# compile counter is the real recompilation guard.
from repro.analysis.sanitize import make_lock as _make_lock

_STATS = {"route_step_dispatches": 0, "route_step_compiles": 0,
          "topk_dispatches": 0, "topk_compiles": 0,
          "analyze_step_dispatches": 0, "analyze_step_compiles": 0}
_STATS_LOCK = _make_lock("ops.stats")


def route_step_stats() -> dict:
    """Copy of the bucketed-dispatch counters."""
    with _STATS_LOCK:
        return dict(_STATS)


def reset_route_step_stats() -> None:
    with _STATS_LOCK:
        for k in _STATS:
            _STATS[k] = 0


def _bump(kind: str, compiles: int) -> None:
    with _STATS_LOCK:
        _STATS[f"{kind}_dispatches"] += 1
        _STATS[f"{kind}_compiles"] += compiles


# optional device-cost profiler hook (obs.profile.DeviceCostProfiler):
# when attached, route_step hands it each shape bucket's bound jitted
# call once so it can read compiled.cost_analysis() — one extra compile
# per NEW bucket while attached, zero steady-state cost when detached
_COST_PROFILER = None


def set_cost_profiler(profiler) -> None:
    """Attach (or detach with ``None``) a per-bucket cost profiler."""
    global _COST_PROFILER
    _COST_PROFILER = profiler


# optional recompile hook (analysis.sanitize.RecompileSentinel): when
# attached, route_step reports every dispatch's shape-bucket signature
# and jit cache-miss delta so the sentinel can fail tests that
# recompile an already-warm bucket.  Same shape as the cost-profiler
# hook: module global, None when detached, zero hot-path cost.
_RECOMPILE_HOOK = None


def set_recompile_hook(hook) -> None:
    """Attach (or detach with ``None``) a per-dispatch recompile hook.

    The hook is called as ``hook(event)`` with ``event = {"path",
    "q_bucket", "n_bucket", "quant", "shards", "compiles"}`` after
    every ``route_step`` dispatch, and likewise after every
    ``analyze_step`` dispatch (``path="analyze"``, ``n_bucket`` = the
    token axis, ``quant`` = analyzer int8) and every fused
    ``analyze_route_step`` dispatch (``path="fused"``, ``quant`` =
    ``(catalog_int8, analyzer_int8)`` — both axes change the compiled
    program, so both belong to the shape-bucket signature)."""
    global _RECOMPILE_HOOK
    _RECOMPILE_HOOK = hook


_DUMMIES = None


def _dummies():
    """Cached device-resident placeholders for inactive blend terms
    ((1, 1) matrix, (1,) vector) — rebuilding + re-transferring them
    per dispatch is measurable on the serving hot path."""
    global _DUMMIES
    if _DUMMIES is None:
        _DUMMIES = (jnp.zeros((1, 1), jnp.float32),
                    jnp.zeros((1,), jnp.float32))
    return _DUMMIES


_WARNED_NO_CACHE_SIZE = False


def _count_compiles(jit_fn, call):
    """Run ``call()`` and return (result, new jit-cache entries).

    Compile detection reads the jit function's private ``_cache_size``
    — on a JAX build without it, warn ONCE that the recompile counters
    (and every zero-recompile guard built on them) are blind, instead
    of letting them read as a vacuous flat 0.
    """
    global _WARNED_NO_CACHE_SIZE
    try:
        before = jit_fn._cache_size()
    except AttributeError:              # pragma: no cover - older jax
        before = None
        if not _WARNED_NO_CACHE_SIZE:
            _WARNED_NO_CACHE_SIZE = True
            import warnings
            warnings.warn(
                "jit._cache_size() unavailable on this JAX version — "
                "route_step compile counters (and zero-recompile "
                "guards) cannot observe recompilation",
                RuntimeWarning, stacklevel=2)
    out = call()
    delta = 0
    if before is not None:
        try:
            delta = max(0, jit_fn._cache_size() - before)
        except AttributeError:          # pragma: no cover
            pass
    return out, delta


# the padded catalog constants are identical across every batch routed
# against one MRES snapshot; cache them keyed on the snapshot's
# embedding-array identity.  Entries hold the source array by WEAK
# reference: when the catalog grows, MRES rebuilds its embedding
# matrix, the old one dies, and the stale multi-MB padded copies are
# evicted on the next pack call instead of pinning one near-identical
# padded bucket per historical catalog size (at 1M entries each copy
# is ~GB).  The weakref also makes id-reuse safe: a dead entry whose
# id() is recycled by a NEW array can never be returned, because its
# referent is gone before the id can repeat.
import weakref as _weakref

_CATALOG_CACHE: "list" = []             # [(key, weakref(emb), packed)]
_CATALOG_CACHE_MAX = 4


def catalog_cache_info() -> dict:
    """Live-entry view of the padded-constant cache (tests/debug):
    ``entries`` live packs, ``keys`` their (id, variant...) keys."""
    with _STATS_LOCK:
        live = [(k2, wr) for (k2, wr, _) in _CATALOG_CACHE
                if wr() is not None]
    return {"entries": len(live), "keys": [k2 for k2, _ in live]}


def reset_catalog_cache() -> None:
    with _STATS_LOCK:
        _CATALOG_CACHE.clear()


def _cache_lookup(key):
    """Return the cached pack for ``key`` (and drop dead entries)."""
    with _STATS_LOCK:
        _CATALOG_CACHE[:] = [e for e in _CATALOG_CACHE
                             if e[1]() is not None]
        for k2, _, packed in _CATALOG_CACHE:
            if k2 == key:
                return packed
    return None


def _cache_put(key, emb, packed):
    with _STATS_LOCK:
        _CATALOG_CACHE.append((key, _weakref.ref(emb), packed))
        while len(_CATALOG_CACHE) > _CATALOG_CACHE_MAX:
            _CATALOG_CACHE.pop(0)


def _quantize_rows_np(x: np.ndarray):
    """numpy twin of ``ref.quantize_rows`` (same per-row symmetric
    int8 contract, round-half-even): q int8, s (rows, 1) f32 with
    x ~= q * s.  Bitwise-identical to the jnp version on equal f32
    input — both divide by the same f32 scale and round half-even —
    so host-packed catalogs and in-program query quantization agree.
    """
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=1, keepdims=True)
    s = np.where(amax > 0, amax / np.float32(127.0),
                 np.float32(1.0)).astype(np.float32)
    q = np.clip(np.rint(x / s), -127, 127).astype(np.int8)
    return q, s


def _mask_table(tt, dm, gmask, n: int, np_pad: int):
    """The stacked hierarchical-filter table at width ``np_pad``:
    task-type x domain combinations, task-type-only rows, generalist
    row, live-catalog row.  Padded columns are False in every row."""
    pad = np_pad - n
    ttp = np.pad(np.asarray(tt, bool), ((0, 0), (0, pad)))
    dmp = np.pad(np.asarray(dm, bool), ((0, 0), (0, pad)))
    combo = (ttp[:, None, :] & dmp[None, :, :]).reshape(-1, np_pad)
    live = np.zeros(np_pad, bool)
    live[:n] = True
    return np.vstack([combo, ttp,
                      np.pad(np.asarray(gmask, bool), (0, pad))[None],
                      live[None]])


def _catalog_blocks(emb: np.ndarray, np_pad: int, quant: bool):
    """(e2, e2s) numpy blocks: ``[embn | emb]`` f32, or the int8
    row-quantized pair when ``quant`` (e2s (Np, 2) per-row scales,
    col 0 = unit half, col 1 = raw half; dummy (1, 2) otherwise)."""
    n = emb.shape[0]
    pad = np_pad - n
    embf = emb.astype(np.float32)
    embn = embf / (np.linalg.norm(embf, axis=1, keepdims=True) + 1e-9)
    if not quant:
        e2 = np.pad(np.concatenate([embn, embf], axis=1),
                    ((0, pad), (0, 0)))
        return e2, np.zeros((1, 2), np.float32)
    q8n, sn = _quantize_rows_np(embn)
    q8e, se = _quantize_rows_np(embf)
    e2 = np.pad(np.concatenate([q8n, q8e], axis=1), ((0, pad), (0, 0)))
    e2s = np.pad(np.concatenate([sn, se], axis=1), ((0, pad), (0, 0)))
    return e2, e2s


def _catalog_pack(emb: np.ndarray, tt: np.ndarray, dm: np.ndarray,
                  gmask: np.ndarray, np_pad: int, *,
                  quant: bool = False, mesh=None, axis: str = ""):
    """Padded device constants for ``route_step``:
    (e2, e2s, masks_table, counts_table).

    The hierarchical-filter structure is flattened into ONE stacked
    boolean table plus per-row population counts, so the device
    program resolves per-query masks AND every ladder count as O(B)
    row gathers instead of (B, N) boolean algebra (see
    ``_mask_table``).  The catalog block pairs the unit-normalized
    rows (cosine kNN) with the raw normalized-metric rows (score
    blend) so the per-batch program does no catalog-side
    normalization work; with ``quant`` both halves are int8
    row-quantized with their scales in e2s.  With ``mesh`` the
    catalog-axis operands are device_put under their PartitionSpecs
    (e2/e2s row-sharded, mask table column-sharded) so the sharded
    program never re-lays them out per batch.
    """
    key = (id(emb), np_pad, bool(quant),
           id(mesh) if mesh is not None else None)
    packed = _cache_lookup(key)
    if packed is not None:
        return packed
    n = emb.shape[0]
    table = _mask_table(tt, dm, gmask, n, np_pad)
    e2, e2s = _catalog_blocks(emb, np_pad, quant)
    counts = table.sum(axis=1).astype(np.int32)
    if mesh is None:
        packed = (jnp.asarray(e2), jnp.asarray(e2s),
                  jnp.asarray(table), jnp.asarray(counts))
    else:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as _P
        from repro.sharding.rules import route_step_specs
        specs = route_step_specs(mesh)
        put = jax.device_put
        packed = (
            put(e2, NamedSharding(mesh, specs["e2"])),
            put(e2s, NamedSharding(mesh, specs["e2s"] if quant
                                   else _P(None, None))),
            put(table, NamedSharding(mesh, specs["masks_table"])),
            put(counts, NamedSharding(mesh, specs["counts_table"])),
        )
    _cache_put(key, emb, packed)
    return packed


def _catalog_pack_ivf(emb: np.ndarray, tt: np.ndarray, dm: np.ndarray,
                      gmask: np.ndarray, cent: np.ndarray,
                      cell_of: np.ndarray, *, quant: bool = False):
    """Cell-packed catalog constants for ``route_step_ivf_jit``:
    (e2, e2s, masks_table, counts_table, orig, cent_d, orig_np, cap).

    Permutes the catalog into contiguous equal-capacity cell blocks
    (``cap`` = max cell size rounded up to 8 slots; dead slots carry
    ``orig == -1``, zero embedding rows, and all-False mask columns)
    so the device program turns "scan the top-nprobe cells" into ONE
    contiguous-stride gather of ``nprobe * cap`` slots.  The counts
    table keeps the TRUE full-catalog populations — ladder semantics
    must not see packing artifacts.
    """
    key = (id(emb), "ivf", id(cent), bool(quant))
    packed = _cache_lookup(key)
    if packed is not None:
        return packed
    n, m = emb.shape
    C = cent.shape[0]
    cell_of = np.asarray(cell_of, np.int64)
    sizes = np.bincount(cell_of, minlength=C)
    cap = max(8, int(-(-int(sizes.max()) // 8) * 8))
    npk = C * cap
    order = np.argsort(cell_of, kind="stable")
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    pos_in_cell = np.arange(n) - starts[cell_of[order]]
    orig = np.full(npk, -1, np.int64)
    orig[cell_of[order] * cap + pos_in_cell] = order
    valid = orig >= 0
    osafe = np.where(valid, orig, 0)

    table = _mask_table(tt, dm, gmask, n, n)
    counts = table.sum(axis=1).astype(np.int32)
    tablepk = table[:, osafe] & valid[None, :]
    e2, e2s = _catalog_blocks(emb, n, quant)
    e2pk = e2[osafe] * valid[:, None].astype(e2.dtype)
    e2spk = e2s[osafe] * valid[:, None] if quant else e2s
    packed = (jnp.asarray(e2pk), jnp.asarray(e2spk),
              jnp.asarray(tablepk), jnp.asarray(counts),
              jnp.asarray(orig.astype(np.int32)),
              jnp.asarray(np.asarray(cent, np.float32)),
              orig.astype(np.int32), cap)
    _cache_put(key, emb, packed)
    return packed


# ----------------------------------------------------------------------
# router_topk
# ----------------------------------------------------------------------

def router_topk(emb, queries, k: int,
                mask: Optional[jnp.ndarray] = None,
                weights: Optional[jnp.ndarray] = None,
                row_bias: Optional[jnp.ndarray] = None,
                min_score: Optional[float] = None, *,
                blk_q: int = 8, blk_n: int = 512,
                quant: bool = False,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted-cosine top-k over the catalog (see kernels/ref.py).

    emb (N, D); queries (Q, D); mask (N,) or (Q, N) bool — a 2-D mask
    gives every query its own hierarchical-filter row (the batched
    routing path fuses task-type & domain masks here); weights (D,);
    row_bias (N,) f32 — additive per-catalog-row score term fused into
    the scoring matmul, applied to mask-valid rows only; min_score —
    static score floor fused after mask + bias (the semantic cache's
    similarity threshold): rows below it surface as -inf.
    ``quant`` routes through the int8 kernel: catalog and query rows
    are symmetrically row-quantized (``ref.quantize_rows``) and the
    scoring matmul accumulates int8 x int8 in int32, rescaling to
    fp32 once at the top-k boundary — 4x fewer catalog bytes moved.
    Returns (vals (Q, k) f32, idx (Q, k) i32).  Masked / padded /
    sub-threshold rows surface as vals == -inf, as does the tail when
    k > N.
    """
    emb = jnp.asarray(emb, jnp.float32)
    queries = jnp.asarray(queries, jnp.float32)
    N, D = emb.shape
    Q = queries.shape[0]
    interp = default_interpret() if interpret is None else interpret
    blk_n = _clamp_blk_n(blk_n, N)

    # fold weights + row norms into the catalog; unit-normalize queries
    en = jnp.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
    ew = emb * (jnp.asarray(weights, jnp.float32)[None, :]
                if weights is not None else 1.0) / en
    qn = queries / (jnp.linalg.norm(queries, axis=1, keepdims=True) + 1e-9)

    maskf = (jnp.asarray(mask, jnp.float32) if mask is not None
             else jnp.ones((N,), jnp.float32))
    maskf = jnp.broadcast_to(maskf, (Q, N)) if maskf.ndim == 1 else maskf
    biasf = (jnp.asarray(row_bias, jnp.float32)[None, :]
             if row_bias is not None else jnp.zeros((1, N), jnp.float32))
    maskp = _pad_to(_pad_to(maskf, blk_n, 1), blk_q, 0)      # pad -> 0 -> -inf
    biasp = _pad_to(biasf, blk_n, 1)
    ms = float("-inf") if min_score is None else float(min_score)

    if quant:
        from repro.kernels.ref import quantize_rows
        e8, es = quantize_rows(ew)
        q8, qs = quantize_rows(qn)
        e8p = _pad_to(_pad_to(e8, LANE, 1), blk_n, 0)
        q8p = _pad_to(_pad_to(q8, LANE, 1), blk_q, 0)
        esp = _pad_to(es, blk_n, 0).T                        # (1, Np)
        qsp = _pad_to(qs, blk_q, 0)                          # (Qp, 1)
        vals, idx = router_topk_q8_pallas(
            q8p, e8p, qsp, esp, maskp, biasp, k, blk_q=blk_q,
            blk_n=blk_n, min_score=ms, interpret=interp)
        return vals[:Q], idx[:Q]

    ewp = _pad_to(_pad_to(ew, LANE, 1), blk_n, 0)
    qnp = _pad_to(_pad_to(qn, LANE, 1), blk_q, 0)
    vals, idx = router_topk_pallas(
        qnp, ewp, maskp, biasp, k, blk_q=blk_q, blk_n=blk_n,
        min_score=ms, interpret=interp)
    return vals[:Q], idx[:Q]


def router_topk_bucketed(emb, queries, k: int,
                         mask: Optional[np.ndarray] = None,
                         min_score: Optional[float] = None, *,
                         quant: bool = False,
                         interpret: Optional[bool] = None
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """``router_topk`` behind the serving-time shape buckets.

    Pads the query axis up to its power-of-two bucket (a 2-D mask pads
    with all-False rows, so bucket rows surface as -inf and are sliced
    off) before dispatching, so a stream of varying batch sizes against
    a fixed store (e.g. the semantic cache's packed capacity) re-uses
    one compiled executable per bucket instead of recompiling per
    batch size.  Counts land in ``route_step_stats`` under ``topk_*``.
    """
    queries = np.asarray(queries, np.float32)
    Q = queries.shape[0]
    qp = q_bucket(Q)
    if qp != Q:
        queries = np.pad(queries, ((0, qp - Q), (0, 0)))
        if mask is not None and np.ndim(mask) == 2:
            mask = np.pad(np.asarray(mask), ((0, qp - Q), (0, 0)))
    jit_fn = router_topk_q8_pallas if quant else router_topk_pallas
    (vals, idx), compiles = _count_compiles(
        jit_fn,
        lambda: router_topk(emb, queries, k, mask=mask,
                            min_score=min_score, quant=quant,
                            interpret=interpret))
    _bump("topk", compiles)
    return vals[:Q], idx[:Q]


# ----------------------------------------------------------------------
# route_step: the fused single-dispatch routing hot path
# ----------------------------------------------------------------------

def route_step(emb, tt_matrix, dm_matrix, gmask, T, W, ti, di, *,
               k: int, r: int,
               fb: Optional[np.ndarray] = None, fb_weight: float = 0.0,
               theta: Optional[np.ndarray] = None,
               ainv: Optional[np.ndarray] = None, alpha: float = 0.0,
               ad_weight: float = 0.0,
               lpen: Optional[np.ndarray] = None,
               use_pallas: bool = False,
               interpret: Optional[bool] = None,
               quant: bool = False, mesh=None,
               ivf=None, nprobe: int = 8,
               telemetry=None, tracer=None) -> dict:
    """One fused routing step per batch (see ``kernels/route_step.py``).

    Pads the batch to its power-of-two Q bucket and the catalog to its
    128-aligned capacity (``q_bucket``/``n_bucket``), dispatches ONE
    jitted device program, and slices the (B,)/(B, R) outputs back.
    ``fb``/``theta``+``ainv``/``lpen`` are optional blend terms —
    absent terms cost nothing on device (their presence is a static
    flag, so toggling one recompiles once and then stays cached).
    Dispatch/compile counts land in ``route_step_stats``; an attached
    ``telemetry`` additionally receives THIS call's (1 dispatch,
    compile delta) directly, so concurrent callers never read each
    other's deltas out of the shared counters.  ``tracer`` (an
    ``obs.trace.Tracer``) wraps the dispatch in a ``route_step`` span
    carrying the selected path, shape bucket, quantization mode, shard
    count and compile delta; an attached cost profiler (see
    ``set_cost_profiler``) gets each NEW shape bucket's bound call to
    read ``compiled.cost_analysis()`` from.

    Mega-catalog knobs (all still ONE dispatch per batch):

    * ``quant``  — serve from the int8 row-quantized catalog block
      (int32 accumulate, one fp32 rescale at the top-k boundary).
    * ``mesh``   — a 1-D device mesh with a ``catalog`` axis
      (``launch.make_routing_mesh``): the catalog axis of every (.., N)
      operand is sharded across it and the cross-shard top-k merge
      tree runs inside the program.  fp32 results are bit-identical to
      the single-device program.
    * ``ivf``    — ``(centroids, cell_of)`` from ``MRES.ivf_index()``:
      two-level pruned search scanning only the top-``nprobe`` cells
      per query (recall@k knob; ``nprobe >= n_cells`` is exhaustive).
      Not yet composed with ``mesh``.
    """
    emb = np.asarray(emb, np.float32)
    T = np.asarray(T, np.float32)
    W = np.asarray(W, np.float32)
    n, m = emb.shape
    B = T.shape[0]
    assert 1 <= k <= n and 1 <= r <= n, (k, r, n)
    qp = q_bucket(B)
    interp = default_interpret() if interpret is None else interpret
    n_tt = np.asarray(tt_matrix).shape[0]
    n_dm = np.asarray(dm_matrix).shape[0]

    qpad = qp - B
    ti = np.asarray(ti, np.int32)
    di = np.asarray(di, np.int32)
    Tp, Wp, tip, dip = T, W, ti, di
    if qpad:
        Tp = np.pad(T, ((0, qpad), (0, 0)))
        Wp = np.pad(W, ((0, qpad), (0, 0)))
        # bucket rows get the trailing all-True mask rows: they compute
        # a harmless top-k over live columns and are sliced off below
        tip = np.pad(ti, (0, qpad), constant_values=n_tt - 1)
        dip = np.pad(di, (0, qpad), constant_values=n_dm - 1)

    dummy1 = _dummies()
    has_fb = fb is not None
    has_ad = theta is not None
    has_load = lpen is not None
    if has_ad:
        th = np.asarray(theta, np.float32)[:n]
        ai = np.asarray(ainv, np.float32)[:n].reshape(n, -1)
    params = np.array([fb_weight, ad_weight, alpha], np.float32)

    if ivf is not None:
        assert mesh is None, "IVF + mesh sharding is not composed yet"
        cent, cell_of = ivf
        (e2_d, e2s_d, masks_d, counts_d, orig_d, cent_d, orig_np,
         cap) = _catalog_pack_ivf(
            emb, tt_matrix, dm_matrix, gmask,
            np.asarray(cent, np.float32), cell_of, quant=quant)
        valid = orig_np >= 0
        osafe = np.where(valid, orig_np, 0)
        if has_fb:
            fbp = np.asarray(fb, np.float32)[:, osafe] * valid[None, :]
            if qpad:
                fbp = np.pad(fbp, ((0, qpad), (0, 0)))
        else:
            fbp = dummy1[0]
        thp = th[osafe] * valid[:, None] if has_ad else dummy1[0]
        aip = ai[osafe] * valid[:, None] if has_ad else dummy1[0]
        lpp = (np.asarray(lpen, np.float32)[:n][osafe] * valid) \
            if has_load else dummy1[1]
        jit_fn = route_step_ivf_jit
        call = functools.partial(
            route_step_ivf_jit,
            e2_d, e2s_d, masks_d, counts_d, orig_d, cent_d,
            Tp, Wp, tip, dip, fbp, thp, aip, lpp, params,
            k=k, r=r, n_tt=n_tt, n_dm=n_dm, nprobe=int(nprobe),
            cap=cap, has_fb=has_fb, has_ad=has_ad,
            has_load=has_load, quant=quant)
        path, n_pad, shards = "ivf", cap, 1
    elif mesh is not None:
        from repro.sharding.rules import CATALOG_AXIS
        ndev = mesh.shape[CATALOG_AXIS]
        np_pad = n_bucket_sharded(n, ndev)
        npad = np_pad - n
        e2_d, e2s_d, masks_d, counts_d = _catalog_pack(
            emb, tt_matrix, dm_matrix, gmask, np_pad, quant=quant,
            mesh=mesh, axis=CATALOG_AXIS)
        fbp = np.pad(np.asarray(fb, np.float32),
                     ((0, qpad), (0, npad))) if has_fb else dummy1[0]
        if has_ad:
            thp = np.pad(th, ((0, npad), (0, 0)))
            aip = np.pad(ai, ((0, npad), (0, 0)))
        else:
            thp = aip = dummy1[0]
        lpp = np.pad(np.asarray(lpen, np.float32)[:n], (0, npad)) \
            if has_load else dummy1[1]
        jit_fn = route_step_sharded_jit
        call = functools.partial(
            route_step_sharded_jit,
            e2_d, e2s_d, masks_d, counts_d, Tp, Wp, tip, dip,
            fbp, thp, aip, lpp, params, mesh=mesh,
            axis=CATALOG_AXIS, k=k, r=r, n_tt=n_tt, n_dm=n_dm,
            has_fb=has_fb, has_ad=has_ad, has_load=has_load,
            quant=quant)
        path, n_pad, shards = "sharded", np_pad, ndev
    else:
        np_pad = n_bucket(n)
        npad = np_pad - n
        blk_n = 512 if np_pad % 512 == 0 else LANE
        e2_d, e2s_d, masks_d, counts_d = _catalog_pack(
            emb, tt_matrix, dm_matrix, gmask, np_pad, quant=quant)
        fbp = np.pad(np.asarray(fb, np.float32),
                     ((0, qpad), (0, npad))) if has_fb else dummy1[0]
        if has_ad:
            thp = np.pad(th, ((0, npad), (0, 0)))
            aip = np.pad(ai, ((0, npad), (0, 0)))
        else:
            thp = aip = dummy1[0]
        lpp = np.pad(np.asarray(lpen, np.float32)[:n], (0, npad)) \
            if has_load else dummy1[1]
        jit_fn = route_step_jit
        call = functools.partial(
            route_step_jit,
            e2_d, e2s_d, masks_d, counts_d, Tp, Wp, tip, dip, fbp,
            thp, aip, lpp, params, k=k, r=r, n_tt=n_tt, n_dm=n_dm,
            has_fb=has_fb, has_ad=has_ad, has_load=has_load,
            use_pallas=use_pallas, blk_q=8, blk_n=blk_n,
            interpret=interp, quant=quant)
        path, n_pad, shards = "dense", np_pad, 1
    prof = _COST_PROFILER
    if prof is not None:
        prof.capture((path, qp, n_pad, quant, shards), jit_fn, call)
    if tracer is not None:
        with tracer.span("route_step", path=path, batch=B,
                         q_bucket=qp, n_bucket=n_pad, catalog_n=n,
                         quant=quant, shards=shards) as sp:
            out, compiles = _count_compiles(jit_fn, call)
            sp.set(compiles=compiles)
    else:
        out, compiles = _count_compiles(jit_fn, call)
    _bump("route_step", compiles)
    hook = _RECOMPILE_HOOK
    if hook is not None:
        hook({"path": path, "q_bucket": qp, "n_bucket": n_pad,
              "quant": quant, "shards": shards, "compiles": compiles})
    if telemetry is not None:
        telemetry.record_route_step(dispatches=1, compiles=compiles)
    out = jax.device_get(out)           # ONE host transfer for all
    return {key: v[:B] for key, v in out.items()}


# ----------------------------------------------------------------------
# analyze_step / analyze_route_step: the fused tokens->decision path
# ----------------------------------------------------------------------

def analyzer_quantized(params) -> bool:
    """True when the analyzer params pytree is int8-quantized —
    ``core.analyzer.quantize_int8`` turns every 2-D leaf into an
    ``(int8, scale)`` pair, ``embed`` always among them."""
    return isinstance(params.get("embed"), tuple)


def _fb_table_pack(fb_table, np_pad: int):
    """Device copy of the dense per-cluster feedback-bias table with
    its catalog axis padded to the capacity bucket — cached on the
    table's identity (``FeedbackStore.bias_table`` memoizes per store
    version, so the id is stable until feedback actually changes)."""
    key = (id(fb_table), "fbt", np_pad)
    packed = _cache_lookup(key)
    if packed is not None:
        return packed
    t = np.asarray(fb_table, np.float32)
    packed = jnp.asarray(np.pad(t, ((0, 0), (0, np_pad - t.shape[1]))))
    _cache_put(key, fb_table, packed)
    return packed


def analyze_step(params, cfg, tokens, *, telemetry=None,
                 tracer=None) -> dict:
    """Bucketed analyzer dispatch: ONE jitted program per (Q bucket,
    token length, config, params structure).

    tokens (B, L) int32, B >= 1 — padded up to the power-of-two query
    bucket with all-PAD rows (uniform heads, never read back).  Emits
    the same stats/hook/profiler/telemetry plumbing as ``route_step``
    under the ``analyze_step_*`` counters, with ``path="analyze"`` and
    the token axis as the bucket signature's ``n_bucket``.  Returns
    host numpy ``{tt_idx, dm_idx, cx, conf}`` arrays of length B.
    """
    tokens = np.asarray(tokens, np.int32)
    B, L = tokens.shape
    assert B >= 1, "analyze_step requires a non-empty batch"
    qp = q_bucket(B)
    if qp != B:
        tokens = np.pad(tokens, ((0, qp - B), (0, 0)))
    quant = analyzer_quantized(params)
    call = functools.partial(analyze_step_jit, params,
                             jnp.asarray(tokens), cfg=cfg)
    prof = _COST_PROFILER
    if prof is not None:
        prof.capture(("analyze", qp, L, quant, 1), analyze_step_jit,
                     call)
    if tracer is not None:
        with tracer.span("analyze_step", path="analyze", batch=B,
                         q_bucket=qp, n_bucket=L, quant=quant,
                         shards=1) as sp:
            out, compiles = _count_compiles(analyze_step_jit, call)
            sp.set(compiles=compiles)
    else:
        out, compiles = _count_compiles(analyze_step_jit, call)
    _bump("analyze_step", compiles)
    hook = _RECOMPILE_HOOK
    if hook is not None:
        hook({"path": "analyze", "q_bucket": qp, "n_bucket": L,
              "quant": quant, "shards": 1, "compiles": compiles})
    if telemetry is not None:
        telemetry.record_analyze_step(dispatches=1, compiles=compiles)
    out = jax.device_get(out)           # ONE host transfer for all
    return {key: v[:B] for key, v in out.items()}


def analyze_route_step(params, cfg, tokens, emb, tt_matrix, dm_matrix,
                       gmask, W, *, k: int, r: int, threshold: float,
                       acc_col: int, use_complexity: bool = True,
                       fb_table=None, fb_buckets: int = 4,
                       fb_weight: float = 0.0,
                       theta: Optional[np.ndarray] = None,
                       ainv: Optional[np.ndarray] = None,
                       alpha: float = 0.0, ad_weight: float = 0.0,
                       lpen: Optional[np.ndarray] = None,
                       use_pallas: bool = False,
                       interpret: Optional[bool] = None,
                       quant: bool = False,
                       telemetry=None, tracer=None) -> dict:
    """ONE device dispatch from token ids to model choice per batch
    (see ``kernels/analyze_step.analyze_route_step_jit``).

    The analyzer operands ride ``route_step``'s dense-path recipe:
    tokens (B, L) pad to the power-of-two Q bucket with all-PAD rows,
    W (B, M) preference rows pad with zero rows, the catalog packs
    through the same padded-constant cache, and the confidence
    ``threshold`` ships as a traced scalar so tuning it never
    recompiles.  ``fb_table`` is ``FeedbackStore.bias_table(names)``
    ((n_tt * n_dm * fb_buckets, N) dense clusters); its padded device
    copy is cached on table identity.  Dense single-device only — the
    sharded/IVF mega-catalog paths keep the staged analyze.

    One dispatch feeds BOTH counter families (``route_step_*`` and
    ``analyze_step_*``), one ``path="fused"`` hook event whose
    ``quant`` field is ``(catalog_int8, analyzer_int8)``, and one
    ``route_step`` tracer span with an ``analyzer_quant`` attr.
    Returns host numpy ``route_step`` outputs plus ``tt_idx`` /
    ``dm_idx`` / ``cx`` / ``conf`` / ``task_vectors`` sliced to B.
    """
    tokens = np.asarray(tokens, np.int32)
    emb = np.asarray(emb, np.float32)
    W = np.asarray(W, np.float32)
    n, m = emb.shape
    B, L = tokens.shape
    assert B >= 1, "analyze_route_step requires a non-empty batch"
    assert 1 <= k <= n and 1 <= r <= n, (k, r, n)
    qp = q_bucket(B)
    interp = default_interpret() if interpret is None else interpret
    n_tt = np.asarray(tt_matrix).shape[0]
    n_dm = np.asarray(dm_matrix).shape[0]

    qpad = qp - B
    toksp, Wp = tokens, W
    if qpad:
        toksp = np.pad(tokens, ((0, qpad), (0, 0)))
        Wp = np.pad(W, ((0, qpad), (0, 0)))

    dummy1 = _dummies()
    has_fb = fb_table is not None
    has_ad = theta is not None
    has_load = lpen is not None
    np_pad = n_bucket(n)
    npad = np_pad - n
    blk_n = 512 if np_pad % 512 == 0 else LANE
    e2_d, e2s_d, masks_d, counts_d = _catalog_pack(
        emb, tt_matrix, dm_matrix, gmask, np_pad, quant=quant)
    fbt = _fb_table_pack(fb_table, np_pad) if has_fb else dummy1[0]
    if has_ad:
        thp = np.pad(np.asarray(theta, np.float32)[:n],
                     ((0, npad), (0, 0)))
        aip = np.pad(np.asarray(ainv, np.float32)[:n].reshape(n, -1),
                     ((0, npad), (0, 0)))
    else:
        thp = aip = dummy1[0]
    lpp = np.pad(np.asarray(lpen, np.float32)[:n], (0, npad)) \
        if has_load else dummy1[1]
    ascalars = np.array([threshold], np.float32)
    rparams = np.array([fb_weight, ad_weight, alpha], np.float32)
    aquant = analyzer_quantized(params)
    call = functools.partial(
        analyze_route_step_jit, params, jnp.asarray(toksp), Wp,
        ascalars, fbt, e2_d, e2s_d, masks_d, counts_d, thp, aip, lpp,
        rparams, cfg=cfg, acc_col=int(acc_col),
        use_complexity=bool(use_complexity),
        fb_buckets=int(fb_buckets), k=k, r=r, n_tt=n_tt, n_dm=n_dm,
        has_fb=has_fb, has_ad=has_ad, has_load=has_load,
        use_pallas=use_pallas, blk_q=8, blk_n=blk_n,
        interpret=interp, quant=quant)
    prof = _COST_PROFILER
    if prof is not None:
        prof.capture(("fused", qp, np_pad, (quant, aquant), 1),
                     analyze_route_step_jit, call)
    if tracer is not None:
        with tracer.span("route_step", path="fused", batch=B,
                         q_bucket=qp, n_bucket=np_pad, catalog_n=n,
                         quant=quant, analyzer_quant=aquant,
                         shards=1) as sp:
            out, compiles = _count_compiles(analyze_route_step_jit,
                                            call)
            sp.set(compiles=compiles)
    else:
        out, compiles = _count_compiles(analyze_route_step_jit, call)
    _bump("route_step", compiles)
    _bump("analyze_step", compiles)
    hook = _RECOMPILE_HOOK
    if hook is not None:
        hook({"path": "fused", "q_bucket": qp, "n_bucket": np_pad,
              "quant": (quant, aquant), "shards": 1,
              "compiles": compiles})
    if telemetry is not None:
        telemetry.record_route_step(dispatches=1, compiles=compiles)
        telemetry.record_analyze_step(dispatches=1, compiles=compiles)
    out = jax.device_get(out)           # ONE host transfer for all
    return {key: v[:B] for key, v in out.items()}


# ----------------------------------------------------------------------
# bandit_update
# ----------------------------------------------------------------------

def bandit_update(x_up, w, r, x_score, theta, ainv, alpha: float, *,
                  blk_n: int = 128, interpret: Optional[bool] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused bandit posterior delta + LinUCB scores (see kernels/ref.py).

    x_up (Bu, D) outcome contexts; w (Bu, N) choice mask; r (Bu,)
    rewards; x_score (Bs, D) incoming contexts; theta (N, D); ainv
    (N, D, D); alpha >= 0 exploration scale.  Returns
    (dA (N, D, D), db (N, D), ucb (Bs, N)) f32.

    Flattens the rank-1 structure into pure matmuls: outer products
    become (B, D^2) rows, alpha^2 is folded into Ainv, and everything is
    lane/sublane padded before ONE ``bandit_update_pallas`` call.
    """
    assert alpha >= 0.0, alpha
    x_up = jnp.asarray(x_up, jnp.float32)
    x_score = jnp.asarray(x_score, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    r = jnp.asarray(r, jnp.float32)
    theta = jnp.asarray(theta, jnp.float32)
    ainv = jnp.asarray(ainv, jnp.float32)
    N, D = theta.shape
    Bu, Bs = x_up.shape[0], x_score.shape[0]
    if Bu == 0:                       # empty outcome batch: zero deltas
        x_up = jnp.zeros((1, D), jnp.float32)
        w = jnp.zeros((1, N), jnp.float32)
        r = jnp.zeros((1,), jnp.float32)
    interp = default_interpret() if interpret is None else interpret
    blk_n = _clamp_blk_n(blk_n, N)

    xx_up = (x_up[:, :, None] * x_up[:, None, :]).reshape(x_up.shape[0], -1)
    xxs = (x_score[:, :, None] * x_score[:, None, :]).reshape(Bs, -1)
    xr = x_up * r[:, None]
    ainv2 = (alpha * alpha) * ainv.reshape(N, D * D)

    sub = 8                                              # f32 sublane
    wp = _pad_to(_pad_to(w, blk_n, 1), sub, 0)
    xxup_p = _pad_to(_pad_to(xx_up, LANE, 1), sub, 0)
    xr_p = _pad_to(_pad_to(xr, LANE, 1), sub, 0)
    xs_p = _pad_to(_pad_to(x_score, LANE, 1), sub, 0)
    xxs_p = _pad_to(_pad_to(xxs, LANE, 1), sub, 0)
    theta_p = _pad_to(_pad_to(theta, LANE, 1), blk_n, 0)
    ainv2_p = _pad_to(_pad_to(ainv2, LANE, 1), blk_n, 0)

    da, db, ucb = bandit_update_pallas(
        wp, xxup_p, xr_p, xs_p, xxs_p, theta_p, ainv2_p,
        blk_n=blk_n, interpret=interp)
    return (da[:N, :D * D].reshape(N, D, D), db[:N, :D], ucb[:Bs, :N])


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

def flash_attention(q, k, v, kv_valid=None, *, causal: bool = True,
                    window: int = 0,
                    softcap: float = 0.0, blk_q: int = 128,
                    blk_k: int = 128, interpret: Optional[bool] = None):
    """q (B, Lq, Hq, hd); k, v (B, Lk, Hkv, hd) — layer layout (L, H, hd).

    kv_valid (B,) int32: per-sequence live key count (decode mode).
    Pads hd to a 128 lane multiple (zero columns are exact for q.k^T and
    are sliced off the value output), transposes to kernel layout, runs
    the blocked flash kernel.  Returns (B, Lq, Hq, hd) in q.dtype.
    """
    interp = default_interpret() if interpret is None else interpret
    hd = q.shape[-1]
    qt = _pad_to(jnp.swapaxes(q, 1, 2), LANE, 3)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), LANE, 3)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), LANE, 3)
    # scale must use the true head_dim, not the padded one
    import math as _m
    scale_fix = _m.sqrt(qt.shape[-1] / hd)
    qt = qt * scale_fix  # kernel divides by sqrt(hd_padded); re-scale
    out = flash_attention_pallas(qt, kt, vt, kv_valid, causal=causal,
                                 window=window,
                                 softcap=softcap, blk_q=blk_q, blk_k=blk_k,
                                 interpret=interp)
    return jnp.swapaxes(out[..., :hd], 1, 2)


def flash_decode(q, k_cache, v_cache, pos, *, softcap: float = 0.0,
                 blk_k: int = 128, interpret: Optional[bool] = None):
    """Flash-decode: one query token against a partially-filled cache.

    q (B, 1, Hq, hd); k_cache/v_cache (B, C, Hkv, hd); pos (B,) int32 —
    the current token index (keys at slots <= pos are live, matching
    models/layers.attention_decode).  Returns (B, 1, Hq, hd).
    """
    return flash_attention(q, k_cache, v_cache, pos + 1, causal=False,
                           softcap=softcap, blk_k=blk_k,
                           interpret=interpret)


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, h0=None, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Chunked SSD scan (see kernels/ref.py::ssd_scan for semantics)."""
    interp = default_interpret() if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, B, C, h0, chunk=chunk,
                           interpret=interp)


# ----------------------------------------------------------------------
# MoE gating
# ----------------------------------------------------------------------

def moe_gating(logits, k: int, *, blk_t: int = 256,
               interpret: Optional[bool] = None):
    """Fused softmax top-k gate. logits (T, E) or (..., E) (flattened)."""
    interp = default_interpret() if interpret is None else interpret
    shape = logits.shape
    flat = logits.reshape(-1, shape[-1])
    vals, idx, aux = moe_gating_pallas(flat, k, blk_t=blk_t,
                                       interpret=interp)
    return (vals.reshape(shape[:-1] + (k,)),
            idx.reshape(shape[:-1] + (k,)), aux)
