"""Public jit'd wrappers around the Pallas kernels.

Each op pads/normalizes inputs to kernel-friendly (128-aligned) shapes,
invokes the kernel, and slices back.  ``interpret`` defaults to True off
TPU (the kernels execute under the Pallas interpreter on CPU — that is
how this repo validates them); on a real TPU backend it defaults to
compiled mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bandit_update import bandit_update_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gating import moe_gating_pallas
from repro.kernels.router_topk import router_topk_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

LANE = 128


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult: int, axis: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _clamp_blk_n(blk_n: int, n: int) -> int:
    """Shrink a catalog block size toward n (rounded up to a power of
    two, floored at one 128 lane) so tiny catalogs are one block."""
    return min(blk_n, max(1 << max(n - 1, 1).bit_length(), 128))


# ----------------------------------------------------------------------
# router_topk
# ----------------------------------------------------------------------

def router_topk(emb, queries, k: int,
                mask: Optional[jnp.ndarray] = None,
                weights: Optional[jnp.ndarray] = None,
                row_bias: Optional[jnp.ndarray] = None,
                min_score: Optional[float] = None, *,
                blk_q: int = 8, blk_n: int = 512,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted-cosine top-k over the catalog (see kernels/ref.py).

    emb (N, D); queries (Q, D); mask (N,) or (Q, N) bool — a 2-D mask
    gives every query its own hierarchical-filter row (the batched
    routing path fuses task-type & domain masks here); weights (D,);
    row_bias (N,) f32 — additive per-catalog-row score term fused into
    the scoring matmul, applied to mask-valid rows only; min_score —
    static score floor fused after mask + bias (the semantic cache's
    similarity threshold): rows below it surface as -inf.
    Returns (vals (Q, k) f32, idx (Q, k) i32).  Masked / padded /
    sub-threshold rows surface as vals == -inf, as does the tail when
    k > N.
    """
    emb = jnp.asarray(emb, jnp.float32)
    queries = jnp.asarray(queries, jnp.float32)
    N, D = emb.shape
    Q = queries.shape[0]
    interp = default_interpret() if interpret is None else interpret
    blk_n = _clamp_blk_n(blk_n, N)

    # fold weights + row norms into the catalog; unit-normalize queries
    en = jnp.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
    ew = emb * (jnp.asarray(weights, jnp.float32)[None, :]
                if weights is not None else 1.0) / en
    qn = queries / (jnp.linalg.norm(queries, axis=1, keepdims=True) + 1e-9)

    maskf = (jnp.asarray(mask, jnp.float32) if mask is not None
             else jnp.ones((N,), jnp.float32))
    maskf = jnp.broadcast_to(maskf, (Q, N)) if maskf.ndim == 1 else maskf
    biasf = (jnp.asarray(row_bias, jnp.float32)[None, :]
             if row_bias is not None else jnp.zeros((1, N), jnp.float32))
    ewp = _pad_to(_pad_to(ew, LANE, 1), blk_n, 0)
    qnp = _pad_to(_pad_to(qn, LANE, 1), blk_q, 0)
    maskp = _pad_to(_pad_to(maskf, blk_n, 1), blk_q, 0)      # pad -> 0 -> -inf
    biasp = _pad_to(biasf, blk_n, 1)

    vals, idx = router_topk_pallas(
        qnp, ewp, maskp, biasp, k, blk_q=blk_q, blk_n=blk_n,
        min_score=float("-inf") if min_score is None else float(min_score),
        interpret=interp)
    return vals[:Q], idx[:Q]


# ----------------------------------------------------------------------
# bandit_update
# ----------------------------------------------------------------------

def bandit_update(x_up, w, r, x_score, theta, ainv, alpha: float, *,
                  blk_n: int = 128, interpret: Optional[bool] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused bandit posterior delta + LinUCB scores (see kernels/ref.py).

    x_up (Bu, D) outcome contexts; w (Bu, N) choice mask; r (Bu,)
    rewards; x_score (Bs, D) incoming contexts; theta (N, D); ainv
    (N, D, D); alpha >= 0 exploration scale.  Returns
    (dA (N, D, D), db (N, D), ucb (Bs, N)) f32.

    Flattens the rank-1 structure into pure matmuls: outer products
    become (B, D^2) rows, alpha^2 is folded into Ainv, and everything is
    lane/sublane padded before ONE ``bandit_update_pallas`` call.
    """
    assert alpha >= 0.0, alpha
    x_up = jnp.asarray(x_up, jnp.float32)
    x_score = jnp.asarray(x_score, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    r = jnp.asarray(r, jnp.float32)
    theta = jnp.asarray(theta, jnp.float32)
    ainv = jnp.asarray(ainv, jnp.float32)
    N, D = theta.shape
    Bu, Bs = x_up.shape[0], x_score.shape[0]
    if Bu == 0:                       # empty outcome batch: zero deltas
        x_up = jnp.zeros((1, D), jnp.float32)
        w = jnp.zeros((1, N), jnp.float32)
        r = jnp.zeros((1,), jnp.float32)
    interp = default_interpret() if interpret is None else interpret
    blk_n = _clamp_blk_n(blk_n, N)

    xx_up = (x_up[:, :, None] * x_up[:, None, :]).reshape(x_up.shape[0], -1)
    xxs = (x_score[:, :, None] * x_score[:, None, :]).reshape(Bs, -1)
    xr = x_up * r[:, None]
    ainv2 = (alpha * alpha) * ainv.reshape(N, D * D)

    sub = 8                                              # f32 sublane
    wp = _pad_to(_pad_to(w, blk_n, 1), sub, 0)
    xxup_p = _pad_to(_pad_to(xx_up, LANE, 1), sub, 0)
    xr_p = _pad_to(_pad_to(xr, LANE, 1), sub, 0)
    xs_p = _pad_to(_pad_to(x_score, LANE, 1), sub, 0)
    xxs_p = _pad_to(_pad_to(xxs, LANE, 1), sub, 0)
    theta_p = _pad_to(_pad_to(theta, LANE, 1), blk_n, 0)
    ainv2_p = _pad_to(_pad_to(ainv2, LANE, 1), blk_n, 0)

    da, db, ucb = bandit_update_pallas(
        wp, xxup_p, xr_p, xs_p, xxs_p, theta_p, ainv2_p,
        blk_n=blk_n, interpret=interp)
    return (da[:N, :D * D].reshape(N, D, D), db[:N, :D], ucb[:Bs, :N])


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

def flash_attention(q, k, v, kv_valid=None, *, causal: bool = True,
                    window: int = 0,
                    softcap: float = 0.0, blk_q: int = 128,
                    blk_k: int = 128, interpret: Optional[bool] = None):
    """q (B, Lq, Hq, hd); k, v (B, Lk, Hkv, hd) — layer layout (L, H, hd).

    kv_valid (B,) int32: per-sequence live key count (decode mode).
    Pads hd to a 128 lane multiple (zero columns are exact for q.k^T and
    are sliced off the value output), transposes to kernel layout, runs
    the blocked flash kernel.  Returns (B, Lq, Hq, hd) in q.dtype.
    """
    interp = default_interpret() if interpret is None else interpret
    hd = q.shape[-1]
    qt = _pad_to(jnp.swapaxes(q, 1, 2), LANE, 3)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), LANE, 3)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), LANE, 3)
    # scale must use the true head_dim, not the padded one
    import math as _m
    scale_fix = _m.sqrt(qt.shape[-1] / hd)
    qt = qt * scale_fix  # kernel divides by sqrt(hd_padded); re-scale
    out = flash_attention_pallas(qt, kt, vt, kv_valid, causal=causal,
                                 window=window,
                                 softcap=softcap, blk_q=blk_q, blk_k=blk_k,
                                 interpret=interp)
    return jnp.swapaxes(out[..., :hd], 1, 2)


def flash_decode(q, k_cache, v_cache, pos, *, softcap: float = 0.0,
                 blk_k: int = 128, interpret: Optional[bool] = None):
    """Flash-decode: one query token against a partially-filled cache.

    q (B, 1, Hq, hd); k_cache/v_cache (B, C, Hkv, hd); pos (B,) int32 —
    the current token index (keys at slots <= pos are live, matching
    models/layers.attention_decode).  Returns (B, 1, Hq, hd).
    """
    return flash_attention(q, k_cache, v_cache, pos + 1, causal=False,
                           softcap=softcap, blk_k=blk_k,
                           interpret=interpret)


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, h0=None, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Chunked SSD scan (see kernels/ref.py::ssd_scan for semantics)."""
    interp = default_interpret() if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, B, C, h0, chunk=chunk,
                           interpret=interp)


# ----------------------------------------------------------------------
# MoE gating
# ----------------------------------------------------------------------

def moe_gating(logits, k: int, *, blk_t: int = 256,
               interpret: Optional[bool] = None):
    """Fused softmax top-k gate. logits (T, E) or (..., E) (flattened)."""
    interp = default_interpret() if interpret is None else interpret
    shape = logits.shape
    flat = logits.reshape(-1, shape[-1])
    vals, idx, aux = moe_gating_pallas(flat, k, blk_t=blk_t,
                                       interpret=interp)
    return (vals.reshape(shape[:-1] + (k,)),
            idx.reshape(shape[:-1] + (k,)), aux)
