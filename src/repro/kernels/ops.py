"""Public jit'd wrappers around the Pallas kernels.

Each op pads/normalizes inputs to kernel-friendly (128-aligned) shapes,
invokes the kernel, and slices back.  ``interpret`` defaults to True off
TPU (the kernels execute under the Pallas interpreter on CPU — that is
how this repo validates them); on a real TPU backend it defaults to
compiled mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gating import moe_gating_pallas
from repro.kernels.router_topk import router_topk_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

LANE = 128


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult: int, axis: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ----------------------------------------------------------------------
# router_topk
# ----------------------------------------------------------------------

def router_topk(emb, queries, k: int,
                mask: Optional[jnp.ndarray] = None,
                weights: Optional[jnp.ndarray] = None, *,
                blk_q: int = 8, blk_n: int = 512,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted-cosine top-k over the catalog (see kernels/ref.py).

    emb (N, D); queries (Q, D); mask (N,) or (Q, N) bool — a 2-D mask
    gives every query its own hierarchical-filter row (the batched
    routing path fuses task-type & domain masks here); weights (D,).
    Returns (vals (Q, k) f32, idx (Q, k) i32).  Masked / padded rows
    surface as vals == -inf.
    """
    emb = jnp.asarray(emb, jnp.float32)
    queries = jnp.asarray(queries, jnp.float32)
    N, D = emb.shape
    Q = queries.shape[0]
    interp = default_interpret() if interpret is None else interpret
    blk_n = min(blk_n, max(1 << max(N - 1, 1).bit_length(), 128))

    # fold weights + row norms into the catalog; unit-normalize queries
    en = jnp.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
    ew = emb * (jnp.asarray(weights, jnp.float32)[None, :]
                if weights is not None else 1.0) / en
    qn = queries / (jnp.linalg.norm(queries, axis=1, keepdims=True) + 1e-9)

    maskf = (jnp.asarray(mask, jnp.float32) if mask is not None
             else jnp.ones((N,), jnp.float32))
    maskf = jnp.broadcast_to(maskf, (Q, N)) if maskf.ndim == 1 else maskf
    ewp = _pad_to(_pad_to(ew, LANE, 1), blk_n, 0)
    qnp = _pad_to(_pad_to(qn, LANE, 1), blk_q, 0)
    maskp = _pad_to(_pad_to(maskf, blk_n, 1), blk_q, 0)      # pad -> 0 -> -inf

    vals, idx = router_topk_pallas(qnp, ewp, maskp, k, blk_q=blk_q,
                                   blk_n=blk_n, interpret=interp)
    return vals[:Q], idx[:Q]


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

def flash_attention(q, k, v, kv_valid=None, *, causal: bool = True,
                    window: int = 0,
                    softcap: float = 0.0, blk_q: int = 128,
                    blk_k: int = 128, interpret: Optional[bool] = None):
    """q (B, Lq, Hq, hd); k, v (B, Lk, Hkv, hd) — layer layout (L, H, hd).

    kv_valid (B,) int32: per-sequence live key count (decode mode).
    Pads hd to a 128 lane multiple (zero columns are exact for q.k^T and
    are sliced off the value output), transposes to kernel layout, runs
    the blocked flash kernel.  Returns (B, Lq, Hq, hd) in q.dtype.
    """
    interp = default_interpret() if interpret is None else interpret
    hd = q.shape[-1]
    qt = _pad_to(jnp.swapaxes(q, 1, 2), LANE, 3)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), LANE, 3)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), LANE, 3)
    # scale must use the true head_dim, not the padded one
    import math as _m
    scale_fix = _m.sqrt(qt.shape[-1] / hd)
    qt = qt * scale_fix  # kernel divides by sqrt(hd_padded); re-scale
    out = flash_attention_pallas(qt, kt, vt, kv_valid, causal=causal,
                                 window=window,
                                 softcap=softcap, blk_q=blk_q, blk_k=blk_k,
                                 interpret=interp)
    return jnp.swapaxes(out[..., :hd], 1, 2)


def flash_decode(q, k_cache, v_cache, pos, *, softcap: float = 0.0,
                 blk_k: int = 128, interpret: Optional[bool] = None):
    """Flash-decode: one query token against a partially-filled cache.

    q (B, 1, Hq, hd); k_cache/v_cache (B, C, Hkv, hd); pos (B,) int32 —
    the current token index (keys at slots <= pos are live, matching
    models/layers.attention_decode).  Returns (B, 1, Hq, hd).
    """
    return flash_attention(q, k_cache, v_cache, pos + 1, causal=False,
                           softcap=softcap, blk_k=blk_k,
                           interpret=interpret)


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, h0=None, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Chunked SSD scan (see kernels/ref.py::ssd_scan for semantics)."""
    interp = default_interpret() if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, B, C, h0, chunk=chunk,
                           interpret=interp)


# ----------------------------------------------------------------------
# MoE gating
# ----------------------------------------------------------------------

def moe_gating(logits, k: int, *, blk_t: int = 256,
               interpret: Optional[bool] = None):
    """Fused softmax top-k gate. logits (T, E) or (..., E) (flattened)."""
    interp = default_interpret() if interpret is None else interpret
    shape = logits.shape
    flat = logits.reshape(-1, shape[-1])
    vals, idx, aux = moe_gating_pallas(flat, k, blk_t=blk_t,
                                       interpret=interp)
    return (vals.reshape(shape[:-1] + (k,)),
            idx.reshape(shape[:-1] + (k,)), aux)
