"""Pallas TPU kernel: fused contextual-bandit posterior update + LinUCB
scoring over packed per-model sufficient statistics.

The adaptive routing layer (``repro.adaptive``) keeps one linear-bandit
posterior (A_n, b_n) per catalog model as packed arrays.  Its serving
cadence is: score the incoming batch under the current posterior, route,
observe rewards, fold the outcome batch back in.  Both halves are pure
matmuls once the rank-1 structure is flattened:

  dA = W^T @ XX        W  (Bu, N) choice mask, XX (Bu, D^2) flattened
                       outer products x x^T — sum of rank-1 updates per
                       model as ONE (N, Bu) x (Bu, D^2) matmul
  db = W^T @ (r * X)   reward-weighted context sums
  ucb = Xs @ theta^T + sqrt(max(XXs @ (alpha^2 Ainv)^T, 0))
                       LinUCB mean + exploration width, the variance
                       x^T Ainv x recast as a (Bs, D^2) x (D^2, N)
                       matmul over the same flattened layout

so the whole learning step stays on the MXU at serving throughput:

  grid = (N/BLK_N,), one independent model block per step
  per step:  dA_blk  = w_blk^T @ xx_up          (MXU)
             db_blk  = w_blk^T @ xr             (MXU)
             ucb_blk = xs @ theta_blk^T
                       + sqrt(relu(xxs @ ainv_blk^T))   (MXU + VPU)

Inputs are pre-flattened/padded by ops.py (D^2 and D lane-padded to 128,
alpha^2 folded into Ainv); the host applies dA/db to the packed stats
and refreshes the tiny (N, D, D) inverses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _bandit_update_kernel(w_ref, xx_ref, xr_ref, xs_ref, xxs_ref,
                          theta_ref, ainv_ref, da_ref, db_ref, ucb_ref):
    w = w_ref[...].astype(jnp.float32)                  # (Bu, BLK_N)
    xx = xx_ref[...].astype(jnp.float32)                # (Bu, P2)
    xr = xr_ref[...].astype(jnp.float32)                # (Bu, Dp)
    da_ref[...] = jax.lax.dot_general(
        w, xx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (BLK_N, P2)
    db_ref[...] = jax.lax.dot_general(
        w, xr, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (BLK_N, Dp)

    xs = xs_ref[...].astype(jnp.float32)                # (Bs, Dp)
    xxs = xxs_ref[...].astype(jnp.float32)              # (Bs, P2)
    mean = jax.lax.dot_general(
        xs, theta_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (Bs, BLK_N)
    var = jax.lax.dot_general(
        xxs, ainv_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (Bs, BLK_N)
    ucb_ref[...] = mean + jnp.sqrt(jnp.maximum(var, 0.0))


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def bandit_update_pallas(w: jnp.ndarray, xx_up: jnp.ndarray,
                         xr: jnp.ndarray, xs: jnp.ndarray,
                         xxs: jnp.ndarray, theta: jnp.ndarray,
                         ainv2: jnp.ndarray, *, blk_n: int = 128,
                         interpret: bool = True):
    """w (Bu, N) choice mask; xx_up (Bu, P2) flattened outer products;
    xr (Bu, Dp) reward-weighted contexts; xs (Bs, Dp) scoring contexts;
    xxs (Bs, P2) their outer products; theta (N, Dp); ainv2 (N, P2) —
    alpha^2 * Ainv flattened (exploration scale folded in by ops.py).

    N % blk_n == 0; Dp, P2 are 128-lane multiples; Bu, Bs sublane-
    aligned (done by ops.py).  Returns (dA (N, P2), db (N, Dp),
    ucb (Bs, N)), all f32.
    """
    Bu, N = w.shape
    P2 = xx_up.shape[1]
    Dp = xr.shape[1]
    Bs = xs.shape[0]
    assert N % blk_n == 0, (N, blk_n)
    assert theta.shape == (N, Dp) and ainv2.shape == (N, P2)
    grid = (N // blk_n,)

    da, db, ucb = pl.pallas_call(
        _bandit_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bu, blk_n), lambda j: (0, j)),
            pl.BlockSpec((Bu, P2), lambda j: (0, 0)),
            pl.BlockSpec((Bu, Dp), lambda j: (0, 0)),
            pl.BlockSpec((Bs, Dp), lambda j: (0, 0)),
            pl.BlockSpec((Bs, P2), lambda j: (0, 0)),
            pl.BlockSpec((blk_n, Dp), lambda j: (j, 0)),
            pl.BlockSpec((blk_n, P2), lambda j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_n, P2), lambda j: (j, 0)),
            pl.BlockSpec((blk_n, Dp), lambda j: (j, 0)),
            pl.BlockSpec((Bs, blk_n), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, P2), jnp.float32),
            jax.ShapeDtypeStruct((N, Dp), jnp.float32),
            jax.ShapeDtypeStruct((Bs, N), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w, xx_up, xr, xs, xxs, theta, ainv2)
    return da, db, ucb
