"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests sweep shapes/dtypes and assert_allclose kernel vs ref).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# int8 row quantization (shared contract of every quantized path)
# ----------------------------------------------------------------------

def quantize_rows(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric int8 quantization.

    ``q = clip(round(x / s), -127, 127)`` with ``s = max|row| / 127``
    (all-zero rows take s = 1 so they quantize to zeros, not NaNs).
    Returns (q (N, D) int8, s (N, 1) f32).  The fp32 score of two
    quantized rows is ``(q_a . q_b) * s_a * s_b`` with the dot
    accumulated in int32 — EXACT integer arithmetic, so every path
    using this helper (Pallas kernel, fused jnp program, oracle)
    produces bitwise-identical scores.  ``ops._quantize_rows_np`` is
    the numpy twin with the same rounding (round-half-even).
    """
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    s = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s


def _q8_matmul(a8: jnp.ndarray, b8: jnp.ndarray, a_s: jnp.ndarray,
               b_s: jnp.ndarray) -> jnp.ndarray:
    """fp32 scores of quantized rows: int32-accumulated a8 @ b8^T,
    rescaled once at the boundary. a_s (Qa, 1); b_s (Nb, 1)."""
    acc = jax.lax.dot_general(
        a8, b8, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (a_s * b_s[:, 0][None, :])


# ----------------------------------------------------------------------
# router_topk: fused weighted-cosine scoring + filter mask + top-k
# ----------------------------------------------------------------------

def router_topk(emb: jnp.ndarray, queries: jnp.ndarray, k: int,
                mask: Optional[jnp.ndarray] = None,
                weights: Optional[jnp.ndarray] = None,
                row_bias: Optional[jnp.ndarray] = None,
                min_score: Optional[float] = None, *,
                quant: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k catalog rows by (optionally weighted) cosine similarity.

    emb:      (N, D) catalog metric embeddings.
    queries:  (Q, D) task vectors.
    mask:     (N,) or (Q, N) bool — rows excluded by the hierarchical
              filter get score -inf (they can still appear in the idx
              tail when fewer than k rows survive; callers check
              vals > -inf).  A 2-D mask is per-query.
    weights:  (D,) per-axis importance applied INSIDE the dot product
              (weighted cosine: sim = sum_d w_d e_d q_d / (|e||q|)).
    row_bias: (N,) additive per-catalog-row term applied to VALID rows
              only — masked rows stay -inf regardless of bias.
    min_score: score floor applied AFTER mask + bias (the semantic
              cache's similarity threshold): rows scoring below it
              surface as -inf, exactly like masked rows.
    quant:    int8 path — the weight-folded, norm-scaled catalog rows
              and the unit queries are row-quantized (``quantize_rows``)
              and scored via the int32-accumulate matmul; mask / bias /
              min_score semantics are unchanged.
    Returns (vals (Q, k) f32 descending, idx (Q, k) int32).
    k > N is allowed: the tail beyond the catalog surfaces as -inf.
    """
    emb = emb.astype(jnp.float32)
    q = queries.astype(jnp.float32)
    N = emb.shape[0]
    en = jnp.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
    qn = jnp.linalg.norm(q, axis=1, keepdims=True) + 1e-9
    ew = emb * (weights.astype(jnp.float32)[None, :] if weights is not None else 1.0)
    if quant:
        e8, es = quantize_rows(ew / en)
        q8, qs = quantize_rows(q / qn)
        scores = _q8_matmul(q8, e8, qs, es)              # (Q, N)
    else:
        scores = (q / qn) @ (ew / en).T                  # (Q, N)
    if row_bias is not None:
        scores = scores + row_bias.astype(jnp.float32)[None, :]
    if mask is not None:
        mask2 = mask if mask.ndim == 2 else mask[None, :]
        scores = jnp.where(mask2, scores, -jnp.inf)
    if min_score is not None:
        scores = jnp.where(scores >= min_score, scores, -jnp.inf)
    if k > N:                       # pad the catalog axis with -inf rows
        scores = jnp.pad(scores, ((0, 0), (0, k - N)),
                         constant_values=-jnp.inf)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


# ----------------------------------------------------------------------
# route_step: fused kNN + score blend + candidate argmax + fallback
# ----------------------------------------------------------------------

def route_step(emb: jnp.ndarray, tt_matrix: jnp.ndarray,
               dm_matrix: jnp.ndarray, gmask: jnp.ndarray,
               T: jnp.ndarray, W: jnp.ndarray, ti: jnp.ndarray,
               di: jnp.ndarray, k: int, r: int, *,
               fb: Optional[jnp.ndarray] = None,
               fb_weight: float = 0.0,
               theta: Optional[jnp.ndarray] = None,
               ainv: Optional[jnp.ndarray] = None,
               alpha: float = 0.0, ad_weight: float = 0.0,
               lpen: Optional[jnp.ndarray] = None,
               quant: bool = False,
               allowed: Optional[jnp.ndarray] = None) -> dict:
    """Semantic ground truth of the fused routing step (unpadded).

    emb (N, M) normalized metric embeddings; tt_matrix/dm_matrix
    (stages, N) stacked boolean filter masks; gmask (N,) generalist
    mask; T (B, M) kNN task vectors; W (B, M) scoring weights; ti/di
    (B,) per-query mask-row indices; fb (B, N) feedback bias; theta
    (N, Dc) / ainv (N, Dc, Dc) LinUCB posterior over contexts
    [T, 1]; lpen (N,) pre-scaled load penalty.

    The blend is ONE (B, N) score matrix — W @ emb^T + fb_weight * fb
    + ad_weight * (mean + alpha * sqrt(var)) - lpen — shared by the
    candidate scoring and every fallback rung.  Stage 0 picks the best
    blended score among the k mask-fused cosine-kNN candidates; rows
    whose kNN found nothing walk the ladder widened-kNN ->
    task-type-only -> generalist -> any as masked re-scores of the
    same blend.  Returns the dict described in
    ``kernels/route_step.route_step_jit`` with true (B,)/(B, R)
    shapes, R = max(k, r).

    ``quant``: score both matrices on int8 row-quantized operands
    (``quantize_rows``; int32 accumulate, fp32 rescale) — the ground
    truth of every int8 path, bitwise-reproducible because the dot
    products are exact integer sums.

    ``allowed`` (B, N) bool: pruned-search visibility (the IVF oracle
    passes the union of each query's probed cells).  The kNN only
    sees ``m1 & allowed``; a row whose full filter mask is non-empty
    but whose probed cells miss every match falls back to stage 1
    (widened-kNN = the exact full-mask blend scan) — the recall
    escape hatch of the pruned path.
    """
    emb = emb.astype(jnp.float32)
    T = T.astype(jnp.float32)
    W = W.astype(jnp.float32)
    B, N = T.shape[0], emb.shape[0]
    embn = emb / (jnp.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)
    qn = T / (jnp.linalg.norm(T, axis=1, keepdims=True) + 1e-9)
    m_tt = tt_matrix[ti]
    m1 = m_tt & dm_matrix[di]
    if quant:
        e8n, esn = quantize_rows(embn)
        e8e, ese = quantize_rows(emb)
        q8, qs = quantize_rows(qn)
        w8, ws = quantize_rows(W)
        sim_full = _q8_matmul(q8, e8n, qs, esn)
    else:
        sim_full = qn @ embn.T
    m_knn = m1 if allowed is None else (m1 & allowed)

    vals, idx = jax.lax.top_k(
        jnp.where(m_knn, sim_full, -jnp.inf), min(k, N))
    finite = vals > -jnp.inf
    idx_safe = jnp.where(finite, idx, 0)
    has_primary = finite.any(axis=1)
    n_filtered = finite.sum(axis=1).astype(jnp.int32)

    blend = _q8_matmul(w8, e8e, ws, ese) if quant else W @ emb.T
    if fb is not None:
        blend = blend + fb_weight * fb.astype(jnp.float32)
    if theta is not None:
        ctx = jnp.concatenate([T, jnp.ones((B, 1), jnp.float32)], axis=1)
        var = jnp.einsum("qd,nde,qe->qn", ctx,
                         ainv.astype(jnp.float32), ctx)
        ucb = ctx @ theta.astype(jnp.float32).T \
            + alpha * jnp.sqrt(jnp.maximum(var, 0.0))
        blend = blend + ad_weight * ucb
    if lpen is not None:
        blend = blend - lpen.astype(jnp.float32)[None, :]

    R = min(max(k, r), N)
    cscore = jnp.where(finite,
                       jnp.take_along_axis(blend, idx_safe, axis=1),
                       -jnp.inf)
    cs, pos = jax.lax.top_k(cscore, cscore.shape[1])
    cidx = jnp.take_along_axis(idx_safe, pos, axis=1)
    sim_p = jnp.take_along_axis(vals, pos[:, :1], axis=1)[:, 0]
    pad = R - cs.shape[1]
    if pad > 0:
        cs = jnp.pad(cs, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        cidx = jnp.pad(cidx, ((0, 0), (0, pad)))

    m_gen = jnp.broadcast_to(gmask[None, :], (B, N))
    m_any = jnp.ones((B, N), bool)
    counts = jnp.stack([m1.sum(1), m_tt.sum(1), m_gen.sum(1),
                        m_any.sum(1)], axis=1).astype(jnp.int32)
    stage_sel = jnp.argmax(counts > 0, axis=1)
    msel = jnp.where((stage_sel == 0)[:, None], m1,
                     jnp.where((stage_sel == 1)[:, None], m_tt,
                               jnp.where((stage_sel == 2)[:, None],
                                         m_gen, m_any)))
    fv, fidx = jax.lax.top_k(jnp.where(msel, blend, -jnp.inf), R)
    if quant:
        f0 = fidx[:, 0]
        sim_f = (qn * e8n[f0].astype(jnp.float32)).sum(axis=1) \
            * esn[f0, 0]
    else:
        sim_f = (qn * embn[fidx[:, 0]]).sum(axis=1)
    ncand_f = jnp.take_along_axis(counts, stage_sel[:, None], axis=1)[:, 0]

    hp = has_primary[:, None]
    cand_score = jnp.where(hp, cs[:, :R], fv)
    cand_idx = jnp.where(hp, cidx[:, :R], fidx).astype(jnp.int32)
    cand_idx = jnp.where(jnp.isfinite(cand_score), cand_idx, -1)
    return {
        "model_idx": cand_idx[:, 0],
        "score": cand_score[:, 0],
        "stage": jnp.where(has_primary, 0, stage_sel + 1
                           ).astype(jnp.int32),
        "similarity": jnp.where(has_primary, sim_p, sim_f),
        "cand_idx": cand_idx,
        "cand_score": cand_score,
        "n_filtered": jnp.where(has_primary, n_filtered, 0
                                ).astype(jnp.int32),
        "n_candidates": jnp.where(has_primary, n_filtered, ncand_f
                                  ).astype(jnp.int32),
    }


# ----------------------------------------------------------------------
# IVF-pruned route_step: coarse centroid probe -> visibility mask
# ----------------------------------------------------------------------

def ivf_allowed(T: jnp.ndarray, centroids: jnp.ndarray,
                cell_of: jnp.ndarray, nprobe: int) -> jnp.ndarray:
    """(B, N) bool: catalog rows whose cell is among each query's
    top-``nprobe`` centroid cells by cosine against the UNIT task
    vector — the visibility set of the two-level IVF search.
    ``nprobe >= n_cells`` makes every row visible (exact search).
    """
    T = T.astype(jnp.float32)
    qn = T / (jnp.linalg.norm(T, axis=1, keepdims=True) + 1e-9)
    cent = centroids.astype(jnp.float32)
    C = cent.shape[0]
    P = min(int(nprobe), C)
    _, cells = jax.lax.top_k(qn @ cent.T, P)            # (B, P)
    hit = jnp.zeros((T.shape[0], C), bool)
    hit = hit.at[jnp.arange(T.shape[0])[:, None], cells].set(True)
    return hit[:, cell_of]                              # (B, N)


def route_step_ivf(emb, tt_matrix, dm_matrix, gmask, T, W, ti, di,
                   k: int, r: int, centroids, cell_of, nprobe: int,
                   **kwargs) -> dict:
    """Ground truth of the IVF-pruned fused step: ``route_step`` with
    the kNN restricted to the probed cells' rows.  All blend kwargs
    (fb / theta / lpen / quant) pass through; recall versus the
    exhaustive ``route_step`` is the ``nprobe`` knob's contract,
    and ``nprobe >= n_cells`` is exhaustive by construction.
    """
    return route_step(emb, tt_matrix, dm_matrix, gmask, T, W, ti, di,
                      k, r,
                      allowed=ivf_allowed(T, centroids, cell_of, nprobe),
                      **kwargs)


# ----------------------------------------------------------------------
# analyze_step / analyze_route_step: fused tokens -> decision path
# ----------------------------------------------------------------------

def analyze_step(params, cfg, tokens, *, pad_id: int = 0) -> dict:
    """Ground truth of the analyzer half of the fused decision path.

    A pre-LN transformer encoder over hash-token ids with a key-side
    pad mask, masked mean pooling, and three linear heads — then the
    staged host epilogue, traced: softmax per head, first-occurrence
    argmax over the PROBABILITIES, complexity clamped to [0, 1], and
    confidence = min of the two softmax maxima.  Any ``params`` leaf
    may be an ``(int8, scale)`` pair (symmetric per-channel weight
    quantization); it dequantizes transparently.

    params: the ``core.analyzer.init_analyzer`` pytree; cfg: anything
    with ``n_heads``; tokens (B, L) int32 (``pad_id`` =
    ``data.tokenizer.PAD_ID``).  Returns (B,) arrays ``tt_idx`` /
    ``dm_idx`` (int32), ``cx``, ``conf`` (f32).
    """
    def deq(w):
        return w[0].astype(jnp.float32) * w[1] if isinstance(w, tuple) else w

    def ln(h, g):
        mu = h.mean(axis=-1, keepdims=True)
        return (h - mu) * jax.lax.rsqrt(
            h.var(axis=-1, keepdims=True) + 1e-6) * g

    tokens = jnp.asarray(tokens)
    Bq, L = tokens.shape
    live = tokens != pad_id
    x = deq(params["embed"])[tokens] + deq(params["pos"])[None, :L]
    H = cfg.n_heads
    hd = x.shape[-1] // H
    neg = jnp.where(live, 0.0, -1e30)

    for p in params["layers"]:
        h = ln(x, p["ln1"])
        q = (h @ deq(p["wq"])).reshape(Bq, L, H, hd)
        k = (h @ deq(p["wk"])).reshape(Bq, L, H, hd)
        v = (h @ deq(p["wv"])).reshape(Bq, L, H, hd)
        s = jnp.einsum("blhd,bmhd->bhlm", q, k) / math.sqrt(hd)
        o = jnp.einsum("bhlm,bmhd->blhd",
                       jax.nn.softmax(s + neg[:, None, None, :], axis=-1),
                       v)
        x = x + o.reshape(Bq, L, H * hd) @ deq(p["wo"])
        h = ln(x, p["ln2"])
        x = x + jax.nn.gelu(h @ deq(p["wi"])) @ deq(p["wp"])

    x = ln(x, params["ln_f"])
    pooled = (x * live[..., None]).sum(axis=1) \
        / jnp.maximum(live.sum(axis=1, keepdims=True), 1)
    tt_p = jax.nn.softmax(pooled @ deq(params["head_tt"]), axis=-1)
    dm_p = jax.nn.softmax(pooled @ deq(params["head_dm"]), axis=-1)
    cx = jax.nn.sigmoid(pooled @ deq(params["head_cx"]))[:, 0]
    return {
        "tt_idx": jnp.argmax(tt_p, axis=1).astype(jnp.int32),
        "dm_idx": jnp.argmax(dm_p, axis=1).astype(jnp.int32),
        "cx": jnp.clip(cx, 0.0, 1.0),
        "conf": jnp.minimum(tt_p.max(axis=1), dm_p.max(axis=1)),
    }


def analyze_route_step(params, cfg, tokens, emb, tt_matrix, dm_matrix,
                       gmask, W, k: int, r: int, *,
                       threshold: float = 0.3,
                       use_complexity: bool = True, acc_col: int = 0,
                       fb_table: Optional[jnp.ndarray] = None,
                       fb_buckets: int = 4, fb_weight: float = 0.0,
                       theta: Optional[jnp.ndarray] = None,
                       ainv: Optional[jnp.ndarray] = None,
                       alpha: float = 0.0, ad_weight: float = 0.0,
                       lpen: Optional[jnp.ndarray] = None,
                       quant: bool = False, pad_id: int = 0) -> dict:
    """Ground truth of the fully fused tokens→decision step (unpadded).

    ``analyze_step``'s heads feed the staged glue, traced: filter-row
    indices fall back to the trailing ANY rows below ``threshold``;
    task vectors are the preference weights with the accuracy column
    (``acc_col``) floored at predicted complexity (``use_complexity``);
    the per-query feedback-bias row is gathered from ``fb_table``
    ((n_tt_raw * n_dm_raw * fb_buckets, N), layout of
    ``feedback.FeedbackStore.bias_table``) at the RAW predicted cluster
    — matching ``feedback.cluster_of``, which ignores confidence.  The
    rest is ``route_step`` verbatim.  Returns ``route_step``'s dict
    plus ``tt_idx``/``dm_idx``/``cx``/``conf``/``task_vectors``.
    """
    heads = analyze_step(params, cfg, tokens, pad_id=pad_id)
    tt_idx, dm_idx = heads["tt_idx"], heads["dm_idx"]
    cx, conf = heads["cx"], heads["conf"]
    n_tt, n_dm = tt_matrix.shape[0], dm_matrix.shape[0]
    confident = conf >= threshold
    ti = jnp.where(confident, tt_idx, n_tt - 1).astype(jnp.int32)
    di = jnp.where(confident, dm_idx, n_dm - 1).astype(jnp.int32)
    W = jnp.asarray(W, jnp.float32)
    T = W
    if use_complexity:
        T = W.at[:, acc_col].set(jnp.maximum(W[:, acc_col], cx))
    fb = None
    if fb_table is not None:
        cb = jnp.clip((cx * fb_buckets).astype(jnp.int32),
                      0, fb_buckets - 1)
        fb = jnp.asarray(fb_table, jnp.float32)[
            (tt_idx * (n_dm - 1) + dm_idx) * fb_buckets + cb]
    out = route_step(emb, tt_matrix, dm_matrix, gmask, T, W, ti, di,
                     k, r, fb=fb, fb_weight=fb_weight, theta=theta,
                     ainv=ainv, alpha=alpha, ad_weight=ad_weight,
                     lpen=lpen, quant=quant)
    out.update(tt_idx=tt_idx, dm_idx=dm_idx, cx=cx, conf=conf,
               task_vectors=T)
    return out


# ----------------------------------------------------------------------
# bandit_update: batched rank-1 posterior updates + UCB scoring matmul
# ----------------------------------------------------------------------

def bandit_update(x_up: jnp.ndarray, w: jnp.ndarray, r: jnp.ndarray,
                  x_score: jnp.ndarray, theta: jnp.ndarray,
                  ainv: jnp.ndarray, alpha: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One contextual-bandit serving step over packed per-model stats.

    The linear-bandit posterior per model n is (A_n, b_n) with
    theta_n = A_n^{-1} b_n.  Given a finished outcome batch (contexts
    ``x_up``, a (Bu, N) choice mask ``w`` with w[b, n] = 1 where query b
    was served by model n, rewards ``r``) and an incoming batch
    ``x_score``, this computes

      dA[n]     = sum_b w[b, n] * x_up[b] x_up[b]^T     (rank-1 updates)
      db[n]     = sum_b w[b, n] * r[b] * x_up[b]
      ucb[q, n] = x_score[q] . theta[n]
                  + alpha * sqrt(x_score[q]^T Ainv[n] x_score[q])

    i.e. the posterior delta for the finished batch plus LinUCB scores
    for the next batch under the CURRENT posterior (``theta``/``ainv``
    are the pre-update estimates — the one-batch-lagged update cadence
    of a serving loop).

    x_up (Bu, D); w (Bu, N); r (Bu,); x_score (Bs, D); theta (N, D);
    ainv (N, D, D).  Returns (dA (N, D, D), db (N, D), ucb (Bs, N)),
    all f32.
    """
    xu = x_up.astype(jnp.float32)
    xs = x_score.astype(jnp.float32)
    w = w.astype(jnp.float32)
    r = r.astype(jnp.float32)
    dA = jnp.einsum("bn,bd,be->nde", w, xu, xu)
    db = jnp.einsum("bn,b,bd->nd", w, r, xu)
    mean = xs @ theta.astype(jnp.float32).T                        # (Bs, N)
    var = jnp.einsum("qd,nde,qe->qn", xs, ainv.astype(jnp.float32), xs)
    ucb = mean + alpha * jnp.sqrt(jnp.maximum(var, 0.0))
    return dA, db, ucb


# ----------------------------------------------------------------------
# flash_attention: blocked causal/SWA/softcap GQA attention
# ----------------------------------------------------------------------

def mha_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jnp.ndarray:
    """Reference attention.

    q: (B, Lq, Hq, hd); k, v: (B, Lk, Hkv, hd) with Hq % Hkv == 0.
    window: sliding-window size (0 = unlimited); only with causal=True.
    softcap: attention-logit soft cap (gemma2), 0 = off.
    Returns (B, Lq, Hq, hd) in q.dtype.
    """
    B, Lq, Hq, hd = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Lq, Hkv, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("blkgd,bmkd->bkglm", qf, kf) / math.sqrt(hd)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    if causal:
        iq = jnp.arange(Lq)[:, None] + (Lk - Lq)   # align ends (prefill=square)
        ik = jnp.arange(Lk)[None, :]
        mask = ik <= iq
        if window:
            mask &= ik > iq - window
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkglm,bmkd->blkgd", probs, vf)
    return out.reshape(B, Lq, Hq, hd).astype(q.dtype)


# ----------------------------------------------------------------------
# ssd_scan: Mamba2 chunked state-space-duality scan
# ----------------------------------------------------------------------

def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray,
             h0: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential-scan reference of the SSD recurrence.

      h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T
      y_t = C_t . h_t

    x:  (Bb, L, H, P)   per-head inputs
    dt: (Bb, L, H)      positive step sizes (already softplus'd)
    A:  (H,)            negative per-head decay rates
    B:  (Bb, L, N)      input projections  (groups=1, shared over heads)
    C:  (Bb, L, N)      output projections
    h0: (Bb, H, P, N)   initial state (zeros if None)
    Returns (y (Bb, L, H, P) f32, h_final (Bb, H, P, N) f32).
    """
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    B_ = B.astype(jnp.float32)
    C_ = C.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bb, L, H, P = x.shape
    N = B_.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(h, inp):
        xt, bt, ct, dtt = inp                 # (Bb,H,P) (Bb,N) (Bb,N) (Bb,H)
        decay = jnp.exp(dtt * A[None, :])     # (Bb, H)
        h = h * decay[:, :, None, None] + jnp.einsum(
            "bn,bhp,bh->bhpn", bt, xt, dtt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(B_, 1, 0),
          jnp.moveaxis(C_, 1, 0), jnp.moveaxis(dt, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


# ----------------------------------------------------------------------
# moe_gating: softmax + top-k gate (renormalized) + load-balance aux
# ----------------------------------------------------------------------

def moe_gating(logits: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k softmax gating.

    logits: (T, E). Returns (gate_vals (T, k) f32 renormalized to sum 1,
    gate_idx (T, k) int32, aux_loss scalar f32).
    """
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / (jnp.sum(vals, axis=-1, keepdims=True) + 1e-9)
    me = jnp.mean(probs, axis=0)
    assign = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1)
    ce = jnp.mean(assign, axis=0)
    aux = jnp.sum(me * ce) * E
    return vals, idx.astype(jnp.int32), aux
