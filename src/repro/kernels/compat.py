"""Version compatibility shims for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(jax >= 0.5); this repo runs on either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
