"""Pallas TPU kernel: blocked flash attention (causal / SWA / softcap / GQA).

TPU-flash conventions (DESIGN.md §3): running max/denominator/accumulator
live in VMEM scratch, key/value blocks stream HBM->VMEM along the
innermost (sequential) grid axis, query/head/batch axes are parallel.

  grid = (B, Hq, Lq/BLK_Q, Lk/BLK_K)          (last axis sequential)
  scratch: m (BLK_Q, 1), l (BLK_Q, 1), acc (BLK_Q, hd)
  per step: s = q @ k^T / sqrt(hd)  -> softcap -> causal/window mask
            online-softmax rescale of (m, l, acc)
  last step: out = acc / l

GQA is expressed in the k/v index maps (kv head = q head // group) so
no K/V replication ever materializes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  blk_q: int, blk_k: int, lq: int, lk: int):
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (BLK_Q, hd)
    k = k_ref[0, 0].astype(jnp.float32)                     # (BLK_K, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(2) * blk_q + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 0)
    ik = jk * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (iq < lq) & (ik < lk)                            # padding mask
    # decode mode: per-sequence valid key length (flash-decode against a
    # partially-filled KV cache); valid_ref holds one int32 per batch row
    valid &= ik < valid_ref[0]
    if causal:
        row = iq + (lk - lq)                                 # align ends
        valid &= ik <= row
        if window:
            valid &= ik > row - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                      # (BLK_Q, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # fully-masked rows keep m == -inf; guard all exp() through `valid`
    alpha = jnp.where(m_new == NEG_INF, 1.0, jnp.exp(m_prev - m_new))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)            # (BLK_Q, BLK_K)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _emit():
        l = l_ref[...]
        o_ref[0, 0] = jnp.where(
            l > 0, acc_ref[...] / jnp.where(l > 0, l, 1.0), 0.0
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "blk_q", "blk_k", "interpret"))
def flash_attention_pallas(q, k, v, kv_valid=None, *, causal: bool = True,
                           window: int = 0,
                           softcap: float = 0.0, blk_q: int = 128,
                           blk_k: int = 128, interpret: bool = True):
    """q (B, Hq, Lq, hd); k, v (B, Hkv, Lk, hd); Hq % Hkv == 0.

    kv_valid: optional (B,) int32 — per-sequence number of valid cache
    keys (flash-decode against a partially-filled KV cache; defaults
    to Lk, i.e. all keys live).
    Lq/Lk need not be block-aligned (padding is masked in-kernel);
    hd should be 128-aligned for MXU efficiency (ops.py pads).
    Returns (B, Hq, Lq, hd) in q.dtype.
    """
    B, Hq, Lq, hd = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    blk_q = min(blk_q, max(Lq, 8))
    blk_k = min(blk_k, max(Lk, 8))
    Lqp = math.ceil(Lq / blk_q) * blk_q
    Lkp = math.ceil(Lk / blk_k) * blk_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Lqp - Lq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Lkp - Lk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Lkp - Lk), (0, 0)))
    if kv_valid is None:
        kv_valid = jnp.full((B,), Lk, jnp.int32)
    kv_valid = jnp.asarray(kv_valid, jnp.int32)

    grid = (B, Hq, Lqp // blk_q, Lkp // blk_k)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, softcap=softcap, blk_q=blk_q, blk_k=blk_k,
        lq=Lq, lk=Lk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, blk_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1,), lambda b, h, i, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Lqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp, kv_valid)
    return out[:, :, :Lq]
