"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §3).

  router_topk     — fused weighted-cosine scoring + filter mask + top-k
                    over the MRES catalog (the paper's routing hot loop)
  flash_attention — blocked causal/SWA/softcap GQA attention
  ssd_scan        — Mamba2 chunked state-space-duality scan
  moe_gating      — fused softmax top-k gate + load-balance partials

Each kernel lives in <name>.py (pl.pallas_call + BlockSpec), with
``ops.py`` as the jit'd public wrapper and ``ref.py`` as the pure-jnp
oracle.  On CPU the kernels run under interpret=True; on TPU compiled.
"""
