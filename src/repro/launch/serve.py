"""Serving launcher: the deployable OptiRoute service loop.

Builds the 10-architecture MRES catalog (reduced runners on CPU), loads
or trains the Task Analyzer, then serves a synthetic request stream
through the batched ServingEngine, printing per-request routing
decisions and the final accounting summary.

  PYTHONPATH=src python -m repro.launch.serve --requests 24 --mode interactive

``--async`` drives the same request stream through the asyncio
front-end (``AsyncServingEngine``): per-request awaitable submits,
micro-batch aggregation windows, per-tenant attribution.  ``--soak
SECONDS`` replays a bursty multi-tenant episode (two quiet tenants plus
a rate-limited flooding one) through the engine's window path in
virtual time and prints the per-tenant admission tally.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import PROFILES
from repro.data.workload import make_workload
from repro.serving.catalog import build_catalog
from repro.serving.engine import Request, ServingEngine

ANALYZER_CKPT = pathlib.Path(__file__).resolve().parents[3] / "results" / "analyzer.npz"


def load_analyzer(train_steps: int = 250) -> TaskAnalyzer:
    an = TaskAnalyzer(AnalyzerConfig())
    if ANALYZER_CKPT.exists():
        from repro.checkpoint import load
        an.params, _ = load(str(ANALYZER_CKPT))
        return an
    print("[serve] training task analyzer (first run only) ...")
    metrics = an.train(steps=train_steps)
    from repro.checkpoint import save
    save(str(ANALYZER_CKPT), an.params, {"metrics": metrics})
    return an


def _run_async(engine, reqs, args):
    """Drive ``reqs`` through the asyncio front-end; return responses."""
    import asyncio

    from repro.serving.async_engine import AsyncServingEngine

    tenants = ("acme", "globex")
    for i, r in enumerate(reqs):
        r.tenant = r.tenant or tenants[i % len(tenants)]
    aeng = AsyncServingEngine(engine, max_batch=args.max_batch,
                              max_wait_ms=args.max_wait_ms)
    print(f"[serve] submitting {len(reqs)} requests (async, "
          f"max_batch={args.max_batch} max_wait={args.max_wait_ms}ms) ...")

    async def _drive():
        async with aeng:
            return await asyncio.gather(*(aeng.submit(r) for r in reqs))

    resps = asyncio.run(_drive())
    print(f"[serve] async windows: {aeng.windows}")
    return resps


def _run_soak(engine, telemetry, args):
    """Virtual-time bursty multi-tenant replay through the window path.

    Two well-behaved tenants plus a rate-limited flooding one; every
    window goes through the same ``engine.submit`` hot path the flat
    stream uses.  Prints the per-tenant admission funnel.
    """
    from repro.data.workload import (MultiTenantScenario, TenantSpec,
                                     TrafficScenario, multi_tenant_arrivals)
    from repro.serving.async_engine import MicroBatcher, TenantPolicy

    sc = MultiTenantScenario(
        base=TrafficScenario(duration_s=float(args.soak), base_rate=4.0,
                             burst_rate=16.0, burst_start=0.3,
                             burst_len=0.3, deadline_ms=800.0,
                             seed=args.seed),
        tenants=(TenantSpec("acme", weight=2.0),
                 TenantSpec("globex"),
                 TenantSpec("flood", rate_scale=3.0, rate_limit=6.0,
                            deadline_ms=400.0)))
    times, tidx = multi_tenant_arrivals(sc)
    wl = make_workload(64, seed=args.seed + 1)
    mb = MicroBatcher(
        max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3,
        policies={t.name: TenantPolicy(weight=t.weight, rate=t.rate_limit)
                  for t in sc.tenants})
    tally: dict = {}
    windows = []

    def bump(tenant, kind):
        tally.setdefault(tenant, {}).setdefault(kind, 0)
        tally[tenant][kind] += 1

    def flush(now):
        items = mb.take(now)
        if not items:
            return
        windows.append(len(items))
        for r in engine.submit(items):
            bump(r.request.tenant, r.admission)

    print(f"[serve] soak: replaying {times.size} arrivals over "
          f"{float(args.soak):.0f}s virtual time ...")
    for k in range(times.size):
        t = float(times[k])
        while True:                       # flush windows that came due
            dl = mb.next_deadline(t)
            if dl is None or dl > t:
                break
            flush(dl)
        ti = int(tidx[k])
        name = sc.tenants[ti].name
        src = wl[k % len(wl)]
        req = Request(text=src.text, prefs="balanced", id=k,
                      max_new=args.max_new,
                      deadline_ms=sc.deadline_ms_of(ti), tenant=name)
        if mb.offer(name, req, t) != "queued":
            bump(name, "shed")            # intake-level rejection
            if telemetry is not None:
                telemetry.record_admission("shed", tenant=name)
    end = float(times[-1]) if times.size else 0.0
    while mb.pending():                   # drain the tail
        dl = mb.next_deadline(end)
        end = max(end, dl if dl is not None else end)
        flush(end)

    print(f"[serve] soak: {len(windows)} windows "
          f"(max {max(windows) if windows else 0})")
    for name in sorted(tally):
        print(f"  {name:>8}: "
              + ", ".join(f"{k}={v}" for k, v in sorted(tally[name].items())))
    print("[serve] summary:", json.dumps(engine.summary(), indent=2))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--mode", choices=("interactive", "batch"),
                    default="interactive")
    ap.add_argument("--profile", default=None,
                    help="force one preference profile; default cycles")
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--archs", nargs="*", default=None,
                    help="subset of catalog archs to load runners for")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--merge-threshold", type=float, default=None)
    ap.add_argument("--metrics-out", default=None,
                    help="dump Prometheus text exposition here "
                         "(e.g. results/metrics.prom)")
    ap.add_argument("--trace-out", default=None,
                    help="dump the span ring as JSONL here "
                         "(e.g. results/trace.jsonl)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics on this port while the "
                         "request stream runs (0 = ephemeral)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="drive requests through the asyncio front-end "
                         "(micro-batch windows + per-tenant intake)")
    ap.add_argument("--soak", type=float, default=None, metavar="SECONDS",
                    help="replay a SECONDS-long bursty multi-tenant "
                         "episode through the window path in virtual "
                         "time instead of the flat request stream")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="aggregation window size (--async / --soak)")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="aggregation window age bound (--async / --soak)")
    args = ap.parse_args(argv)

    obs_on = (args.metrics_out or args.trace_out
              or args.metrics_port is not None)
    tracer = telemetry = None
    if obs_on:
        from repro.core.telemetry import Telemetry
        from repro.obs import Tracer
        tracer = Tracer()
        telemetry = Telemetry()

    print("[serve] building catalog (reduced runners) ...")
    mres = build_catalog(smoke_runners=True, archs=args.archs)
    analyzer = load_analyzer()
    extra = {}
    if args.use_async or args.soak is not None:
        # the window path exercises deadline admission, so give the
        # router a live load tracker (the flat stream keeps the
        # original load-blind behaviour)
        from repro.serving.load import LoadTracker
        extra = dict(load=LoadTracker(default_service_s=0.05),
                     load_weight=1.0)
    router = OptiRoute(mres, analyzer, merge_threshold=args.merge_threshold,
                       telemetry=telemetry, tracer=tracer, **extra)
    engine = ServingEngine(router)

    server = None
    if args.metrics_port is not None:
        from repro.obs import serve_metrics
        server = serve_metrics(telemetry, tracer=tracer,
                               port=args.metrics_port)
        print(f"[serve] /metrics on http://127.0.0.1:{server.port}/metrics")

    profiles = ([args.profile] if args.profile
                else list(PROFILES))
    if args.soak is not None:
        _run_soak(engine, telemetry, args)
    else:
        wl = make_workload(args.requests, seed=args.seed)
        reqs = [Request(text=r.text, prefs=profiles[i % len(profiles)],
                        id=r.id, max_new=args.max_new)
                for i, r in enumerate(wl)]
        if args.use_async:
            resps = _run_async(engine, reqs, args)
        else:
            print(f"[serve] submitting {len(reqs)} requests "
                  f"({args.mode}) ...")
            resps = engine.submit(reqs, mode=args.mode)
        for r in resps:
            print(f"  #{r.request.id:>3} prefs={r.request.prefs:<18} "
                  f"sig=({r.sig.task_type}/{r.sig.domain}"
                  f"/{r.sig.complexity:.2f}) -> {r.model}"
                  f"{'  [' + r.fallback + ']' if r.fallback else ''}")
            # thumbs: synthetic user approves iff the routed model is
            # tagged for the task type
            entry = mres.entry(r.model)
            engine.feedback(r,
                            thumbs_up=r.sig.task_type in entry.task_types)
        print("[serve] summary:", json.dumps(engine.summary(), indent=2))

    if args.metrics_out:
        from repro.obs import write_prom
        pathlib.Path(args.metrics_out).parent.mkdir(parents=True,
                                                    exist_ok=True)
        write_prom(args.metrics_out, telemetry, load=engine.load,
                   tracer=tracer)
        print(f"[serve] metrics -> {args.metrics_out}")
    if args.trace_out:
        pathlib.Path(args.trace_out).parent.mkdir(parents=True,
                                                  exist_ok=True)
        n = tracer.export_jsonl(args.trace_out)
        print(f"[serve] {n} spans -> {args.trace_out}")
    if server is not None:
        server.close()


if __name__ == "__main__":
    main()
