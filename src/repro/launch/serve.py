"""Serving launcher: the deployable OptiRoute service loop.

Builds the 10-architecture MRES catalog (reduced runners on CPU), loads
or trains the Task Analyzer, then serves a synthetic request stream
through the batched ServingEngine, printing per-request routing
decisions and the final accounting summary.

  PYTHONPATH=src python -m repro.launch.serve --requests 24 --mode interactive
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import PROFILES
from repro.data.workload import make_workload
from repro.serving.catalog import build_catalog
from repro.serving.engine import Request, ServingEngine

ANALYZER_CKPT = pathlib.Path(__file__).resolve().parents[3] / "results" / "analyzer.npz"


def load_analyzer(train_steps: int = 250) -> TaskAnalyzer:
    an = TaskAnalyzer(AnalyzerConfig())
    if ANALYZER_CKPT.exists():
        from repro.checkpoint import load
        an.params, _ = load(str(ANALYZER_CKPT))
        return an
    print("[serve] training task analyzer (first run only) ...")
    metrics = an.train(steps=train_steps)
    from repro.checkpoint import save
    save(str(ANALYZER_CKPT), an.params, {"metrics": metrics})
    return an


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--mode", choices=("interactive", "batch"),
                    default="interactive")
    ap.add_argument("--profile", default=None,
                    help="force one preference profile; default cycles")
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--archs", nargs="*", default=None,
                    help="subset of catalog archs to load runners for")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--merge-threshold", type=float, default=None)
    ap.add_argument("--metrics-out", default=None,
                    help="dump Prometheus text exposition here "
                         "(e.g. results/metrics.prom)")
    ap.add_argument("--trace-out", default=None,
                    help="dump the span ring as JSONL here "
                         "(e.g. results/trace.jsonl)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics on this port while the "
                         "request stream runs (0 = ephemeral)")
    args = ap.parse_args(argv)

    obs_on = (args.metrics_out or args.trace_out
              or args.metrics_port is not None)
    tracer = telemetry = None
    if obs_on:
        from repro.core.telemetry import Telemetry
        from repro.obs import Tracer
        tracer = Tracer()
        telemetry = Telemetry()

    print("[serve] building catalog (reduced runners) ...")
    mres = build_catalog(smoke_runners=True, archs=args.archs)
    analyzer = load_analyzer()
    router = OptiRoute(mres, analyzer, merge_threshold=args.merge_threshold,
                       telemetry=telemetry, tracer=tracer)
    engine = ServingEngine(router)

    server = None
    if args.metrics_port is not None:
        from repro.obs import serve_metrics
        server = serve_metrics(telemetry, tracer=tracer,
                               port=args.metrics_port)
        print(f"[serve] /metrics on http://127.0.0.1:{server.port}/metrics")

    profiles = ([args.profile] if args.profile
                else list(PROFILES))
    wl = make_workload(args.requests, seed=args.seed)
    reqs = [Request(text=r.text, prefs=profiles[i % len(profiles)],
                    id=r.id, max_new=args.max_new)
            for i, r in enumerate(wl)]
    print(f"[serve] submitting {len(reqs)} requests ({args.mode}) ...")
    resps = engine.submit(reqs, mode=args.mode)
    for r in resps:
        print(f"  #{r.request.id:>3} prefs={r.request.prefs:<18} "
              f"sig=({r.sig.task_type}/{r.sig.domain}"
              f"/{r.sig.complexity:.2f}) -> {r.model}"
              f"{'  [' + r.fallback + ']' if r.fallback else ''}")
        # thumbs: synthetic user approves iff the routed model is tagged
        # for the task type
        entry = mres.entry(r.model)
        engine.feedback(r, thumbs_up=r.sig.task_type in entry.task_types)
    print("[serve] summary:", json.dumps(engine.summary(), indent=2))

    if args.metrics_out:
        from repro.obs import write_prom
        pathlib.Path(args.metrics_out).parent.mkdir(parents=True,
                                                    exist_ok=True)
        write_prom(args.metrics_out, telemetry, load=engine.load,
                   tracer=tracer)
        print(f"[serve] metrics -> {args.metrics_out}")
    if args.trace_out:
        pathlib.Path(args.trace_out).parent.mkdir(parents=True,
                                                  exist_ok=True)
        n = tracer.export_jsonl(args.trace_out)
        print(f"[serve] {n} spans -> {args.trace_out}")
    if server is not None:
        server.close()


if __name__ == "__main__":
    main()
