import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

For each pair this proves the sharding config is coherent on the
production mesh (256-chip single pod and 512-chip 2-pod) and extracts
``memory_analysis()`` / ``cost_analysis()`` plus the collective-bytes
scan of the HLO that feeds EXPERIMENTS.md §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                   # all pairs
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod ...
Results are cached as JSON under results/dryrun/ (skip with --force).
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback
import warnings

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import shapes as S
from repro.models import model as M
from repro.sharding import rules as R
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.steps import make_train_step, make_prefill_step, make_decode_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# ----------------------------------------------------------------------
# collective-bytes extraction from HLO text
# ----------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\S+)\s*=\s*((?:bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|c64)"
    r"\[[0-9,]*\][^ ]*|\([^)]*\))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([0-9,]*)\]")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "c64": 8}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum output bytes of collective ops in (compiled) HLO, by kind."""
    by_kind = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(3)
        nbytes = _shape_bytes(m.group(2))
        by_kind[kind] = by_kind.get(kind, 0) + nbytes
    return by_kind


# ----------------------------------------------------------------------
# lowering one pair
# ----------------------------------------------------------------------

def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               overrides: dict | None = None):
    """Lower + compile one (arch, shape) on the production mesh.

    overrides: ModelConfig field overrides (perf iterations compare
    e.g. attn_impl="naive" vs "blocked" — EXPERIMENTS.md §Perf).
    Returns a result dict (also JSON-serializable).
    """
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides).validate()
    ok, why = S.applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "n/a", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = S.input_specs(cfg, shape_name)
    t0 = time.time()
    # Snapshot the silent-replication counter around spec construction:
    # every time rules.maybe() falls back to replication because a named
    # axis is absent from this mesh, a tensor the config claims is
    # sharded actually materializes N full copies.  That must be loud.
    repl0 = R.silent_replication_count()

    with jax.set_mesh(mesh):
        params_shape = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        pspecs = R.param_specs(cfg, mesh, params_shape)

        if spec["kind"] == "train":
            opt_shape = jax.eval_shape(lambda: init_opt_state(params_shape))
            ospecs = {"mu": pspecs, "nu": pspecs,
                      "step": jax.sharding.PartitionSpec()}
            bspecs = R.batch_spec(cfg, mesh, spec["batch"])
            step = make_train_step(cfg)
            jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs))
            lowered = jitted.lower(params_shape, opt_shape, spec["batch"])
        elif spec["kind"] == "prefill":
            bspecs = R.batch_spec(cfg, mesh, spec["batch"])
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(pspecs, bspecs))
            lowered = jitted.lower(params_shape, spec["batch"])
        else:
            cfg_eff = spec.get("cfg", cfg)   # long_500k SWA degradation
            cspecs = R.cache_specs(cfg_eff, mesh, spec["cache"])
            bspecs = R.decode_batch_spec(cfg_eff, mesh, spec["batch"])
            step = make_decode_step(cfg_eff, long_mode=spec["long_mode"])
            jitted = jax.jit(step, in_shardings=(pspecs, cspecs, bspecs))
            lowered = jitted.lower(params_shape, spec["cache"], spec["batch"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    silent_repl = R.silent_replication_count() - repl0
    if silent_repl:
        warnings.warn(
            f"[{arch}/{shape_name}] sharding.rules.maybe() silently "
            f"replicated {silent_repl} spec axis(es): a tensor the "
            f"rules name as sharded has no matching mesh axis and is "
            f"stored as {mesh.devices.size} full copies",
            stacklevel=2)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    def _get(d, k):
        try:
            return float(d[k])
        except Exception:
            return 0.0

    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": _get(cost, "flops"),
        "bytes_accessed": _get(cost, "bytes accessed"),
        "collective_bytes": coll,
        "silent_replications": int(silent_repl),
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=None,
                    help="write results here instead of results/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig field override, key=value")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    archs = args.arch or ARCH_NAMES
    shape_names = args.shape or list(S.SHAPES)
    global RESULTS
    if args.out_dir:
        RESULTS = pathlib.Path(args.out_dir)
    RESULTS.mkdir(parents=True, exist_ok=True)
    failures = []

    for arch in archs:
        for shape_name in shape_names:
            tag = f"{arch}__{shape_name}__{'pod2' if args.multi_pod else 'pod1'}"
            out = RESULTS / f"{tag}.json"
            if out.exists() and not args.force:
                prev = json.loads(out.read_text())
                print(f"[skip] {tag}: cached ({prev['status']})")
                if prev["status"] == "error":
                    failures.append(tag)
                continue
            print(f"[run ] {tag} ...", flush=True)
            try:
                res = lower_pair(arch, shape_name, multi_pod=args.multi_pod,
                                 overrides=overrides or None)
            except Exception as e:  # noqa: BLE001 — record and continue
                res = {"arch": arch, "shape": shape_name, "status": "error",
                       "multi_pod": args.multi_pod,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                failures.append(tag)
            out.write_text(json.dumps(res, indent=2))
            status = res["status"]
            extra = ""
            if status == "ok":
                extra = (f" flops={res['flops']:.3e}"
                         f" coll={sum(res['collective_bytes'].values()):.3e}B"
                         f" compile={res['compile_s']}s")
            elif status == "error":
                extra = " " + res["error"][:200]
            print(f"[done] {tag}: {status}{extra}", flush=True)

    if failures:
        print(f"\nFAILED pairs: {failures}")
        sys.exit(1)
    print("\nAll dry-run pairs OK.")


if __name__ == "__main__":
    main()
