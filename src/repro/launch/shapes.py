"""Assigned input shapes and ShapeDtypeStruct stand-ins per architecture.

``input_specs(cfg, shape_name)`` returns the exact abstract inputs the
dry-run lowers against — weak-type-correct, shardable, zero allocation.

Shapes (assignment):
  train_4k       seq_len=  4,096  global_batch= 256  (training)
  prefill_32k    seq_len= 32,768  global_batch=  32  (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch= 128  (inference-decode)
  long_500k      seq_len=524,288  global_batch=   1  (long-context-decode)

For VLM/audio the seq_len is the TOTAL context (frontend tokens + text).
Decode shapes lower ``serve_step`` — one token against a seq_len cache.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-not). long_500k needs sub-quadratic serving."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention family; 500k dense KV decode " \
                      "is not sub-quadratic-servable (DESIGN.md §4)"
    return True, ""


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token budget once frontend tokens are accounted for."""
    if cfg.is_encdec:
        return seq_len // 2
    if cfg.frontend:
        return max(seq_len - cfg.frontend_tokens, 16)
    return seq_len


def train_specs(cfg: ModelConfig, seq_len: int, batch: int) -> Dict[str, Any]:
    Lt = text_len(cfg, seq_len)
    b: Dict[str, Any] = {
        "tokens": sds((batch, Lt), jnp.int32),
        "labels": sds((batch, Lt), jnp.int32),
    }
    if cfg.is_encdec:
        b["src_embeds"] = sds((batch, seq_len - Lt, cfg.frontend_dim), jnp.bfloat16)
    elif cfg.frontend:
        b["frontend"] = sds((batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    return b


def prefill_specs(cfg: ModelConfig, seq_len: int, batch: int) -> Dict[str, Any]:
    b = train_specs(cfg, seq_len, batch)
    b.pop("labels")
    return b


def decode_specs(cfg: ModelConfig, seq_len: int, batch: int, *,
                 long_mode: bool) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (cache_specs, batch_specs) for one serve step."""
    enc_len = seq_len // 2 if cfg.is_encdec else 0
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, batch, seq_len, long_mode=long_mode,
                             enc_len=enc_len))
    b = {"token": sds((batch, 1), jnp.int32), "pos": sds((batch,), jnp.int32)}
    return cache, b


def input_specs(cfg: ModelConfig, shape_name: str):
    """Abstract inputs for (cfg, shape). Returns dict with 'kind' and specs."""
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    long_mode = shape_name == "long_500k" and cfg.long_mode_local_only
    if kind == "train":
        return {"kind": "train",
                "batch": train_specs(cfg, sh["seq_len"], sh["global_batch"])}
    if kind == "prefill":
        return {"kind": "prefill",
                "batch": prefill_specs(cfg, sh["seq_len"], sh["global_batch"])}
    cfg_eff = cfg.long_serving_config() if shape_name == "long_500k" else cfg
    cache, b = decode_specs(cfg_eff, sh["seq_len"], sh["global_batch"],
                            long_mode=long_mode)
    return {"kind": "decode", "cache": cache, "batch": b,
            "long_mode": long_mode, "cfg": cfg_eff}
