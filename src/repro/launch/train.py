"""Training launcher.

Trains any assigned architecture (reduced or full config) with the
pure-JAX AdamW train step under pjit sharding, synthetic LM data,
checkpointing, and periodic eval.  On this CPU container it is used
with ``--smoke`` (reduced configs) and a ~100M custom config for the
end-to-end example; on a real TPU slice the same entry point shards
over the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.sharding import rules as R
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.steps import make_train_step


def make_lm_sampler(rng, cfg):
    """Synthetic LM data: a FIXED token cycle (the learnable structure —
    it must not be re-sampled per batch) + 5% replacement noise."""
    base = rng.integers(2, cfg.vocab_size - 1, 257)

    def sample(batch, seq):
        starts = rng.integers(0, 257, batch)
        toks = np.stack([base[(s + np.arange(seq + 1)) % 257]
                         for s in starts])
        noise = rng.random((batch, seq + 1)) < 0.05
        toks = np.where(noise, rng.integers(2, cfg.vocab_size - 1,
                                            (batch, seq + 1)), toks)
        b = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if cfg.is_encdec:
            b["src_embeds"] = jnp.asarray(
                rng.standard_normal((batch, 16, cfg.frontend_dim)),
                jnp.float32)
        elif cfg.frontend:
            b["frontend"] = jnp.asarray(
                rng.standard_normal((batch, cfg.frontend_tokens,
                                     cfg.frontend_dim)), jnp.float32)
        return b

    return sample


def build_config(args):
    if args.d_model:     # custom size (e.g. the ~100M example driver)
        base = get_smoke(args.arch) if args.smoke else get_config(args.arch)
        n_heads = max(args.d_model // 64, 2)
        n_kv = max(n_heads // 4, 1)
        while n_heads % n_kv:                   # GQA group must divide
            n_kv -= 1
        return dataclasses.replace(
            base, d_model=args.d_model, n_layers=args.n_layers or base.n_layers,
            n_heads=n_heads, n_kv_heads=n_kv,
            d_ff=args.d_ff or 4 * args.d_model,
            vocab_size=args.vocab or base.vocab_size,
            name=f"{base.name}-custom").validate()
    return get_smoke(args.arch) if args.smoke else get_config(args.arch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (TPU) instead of host mesh")
    args = ap.parse_args(argv)

    cfg = build_config(args)
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params "
          f"({cfg.n_active_params()/1e6:.1f}M active), "
          f"{jax.device_count()} device(s)")
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    rng = np.random.default_rng(args.seed)

    with jax.set_mesh(mesh):
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        pspecs = R.param_specs(cfg, mesh, params)
        opt = init_opt_state(params)
        ospecs = {"mu": pspecs, "nu": pspecs,
                  "step": jax.sharding.PartitionSpec()}
        sampler = make_lm_sampler(rng, cfg)
        bspecs = R.batch_spec(cfg, mesh, sampler(args.batch, args.seq))
        step = jax.jit(make_train_step(cfg, AdamWConfig(
                           lr=args.lr, warmup_steps=args.warmup)),
                       in_shardings=(pspecs, ospecs, bspecs))

        cm = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        losses = []
        t0 = time.time()
        for i in range(args.steps):
            batch = sampler(args.batch, args.seq)
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                tput = (i + 1) * args.batch * args.seq / dt
                print(f"[train] step {i:>5} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} "
                      f"({tput:.0f} tok/s)", flush=True)
            if cm and (i + 1) % args.ckpt_every == 0:
                cm.save(i + 1, params, {"loss": losses[-1]})

    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({(1 - last / first):.1%} drop)")
    return first, last


if __name__ == "__main__":
    main()
