"""Production mesh factory.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state.  TPU v5e target:
  single pod:  (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke/serving runs."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_routing_mesh(n_devices: int | None = None):
    """1-D mesh for the mega-catalog sharded ``route_step``: the
    catalog (N) axis of every routing operand shards over its single
    ``"catalog"`` axis (``sharding.rules.CATALOG_AXIS``); queries stay
    replicated.  Defaults to all visible devices — on a CPU CI box set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (or more)
    to exercise the cross-device program."""
    from repro.sharding.rules import CATALOG_AXIS
    nd = jax.device_count() if n_devices is None else int(n_devices)
    assert 1 <= nd <= jax.device_count(), (nd, jax.device_count())
    return jax.make_mesh((nd,), (CATALOG_AXIS,))
