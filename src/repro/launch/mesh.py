"""Production mesh factory.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state.  TPU v5e target:
  single pod:  (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke/serving runs."""
    return jax.make_mesh((1, 1), ("data", "model"))
