"""Data substrate: hash tokenizer + synthetic query workloads."""
