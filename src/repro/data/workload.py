"""Synthetic query workload generator (the paper's "query logs of a
production MLaaS cloud provider" stand-in, §3.2).

Each query is built from a task-type template + domain lexicon words +
complexity-controlled filler.  The generator records the ground-truth
TaskSignature (the label the analyzer is trained against) and a
per-model ground-truth quality table used by the routing benchmarks.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.preferences import DOMAINS, TASK_TYPES, TaskSignature

# ----------------------------------------------------------------------
# templates & lexicons
# ----------------------------------------------------------------------

TEMPLATES: Dict[str, List[str]] = {
    "chat": ["hello can you help me with {topic}",
             "i have a question about {topic}",
             "what do you think about {topic}"],
    "code": ["write a python function that computes {topic}",
             "fix the bug in this code {blob}",
             "refactor this module for readability {blob}"],
    "reasoning": ["solve this step by step {blob}",
                  "prove that {topic} holds for all cases",
                  "which option is correct and why {blob}"],
    "summarization": ["summarize the following article {blob}",
                      "give me a tl dr of this document {blob}",
                      "condense these meeting notes {blob}"],
    "classification": ["find the sentiment of the passage {blob}",
                       "classify this ticket into a category {blob}",
                       "label the intent of this message {blob}"],
    "translation": ["translate this passage to german {blob}",
                    "convert the following text into french {blob}",
                    "translate to spanish keeping the tone {blob}"],
    "transcription": ["transcribe the attached audio about {topic}",
                      "produce a transcript of this recording {topic}",
                      "caption the spoken audio {topic}"],
    "vqa": ["looking at the image what is {topic}",
            "answer the question about the attached picture {topic}",
            "from the screenshot determine {topic}"],
    "captioning": ["describe the attached image of {topic}",
                   "write alt text for this picture of {topic}",
                   "caption this photo about {topic}"],
    "creative-writing": ["write a short story about {topic}",
                         "compose a poem on {topic}",
                         "draft a fictional dialogue about {topic}"],
    "long-context": ["using the entire report below answer {topic} {blob}",
                     "search this long document for {topic} {blob}",
                     "cross reference the chapters below about {topic} {blob}"],
}

DOMAIN_LEXICON: Dict[str, List[str]] = {
    "general": ["weather", "travel", "cooking", "music", "history",
                "sports", "gardening"],
    "software": ["kubernetes", "compiler", "database", "frontend", "api",
                 "microservice", "deployment", "regression"],
    "finance": ["portfolio", "derivatives", "equity", "hedging", "ledger",
                "liquidity", "arbitrage", "quarterly"],
    "legal": ["contract", "liability", "statute", "plaintiff", "clause",
              "compliance", "jurisdiction", "tort"],
    "healthcare": ["diagnosis", "dosage", "radiology", "oncology",
                   "symptom", "clinical", "pathology", "triage"],
    "multilingual": ["german", "mandarin", "localization", "dialect",
                     "idiom", "bilingual", "transliteration"],
}

_FILLER = ["the", "report", "shows", "that", "we", "observed", "several",
           "items", "during", "review", "and", "noted", "further", "points",
           "for", "discussion", "in", "section"]
_HARD_MARKERS = ["however", "sarcastically", "notwithstanding", "paradox",
                 "ambiguous", "nested", "caveat", "irony", "subtle",
                 "counterintuitive"]


@dataclass(frozen=True)
class QueryRecord:
    text: str
    sig: TaskSignature          # ground truth implicit preferences
    id: int = 0


def _complexity_blob(rng, complexity: float, domain_words) -> Tuple[str, int]:
    """Filler blob whose length/markers encode the complexity."""
    n_fill = int(10 + complexity * 120)
    words = list(rng.choice(_FILLER, n_fill))
    n_hard = int(round(complexity * 6))
    for _ in range(n_hard):
        words.insert(int(rng.integers(0, len(words))),
                     str(rng.choice(_HARD_MARKERS)))
    for _ in range(3):
        words.insert(int(rng.integers(0, len(words))),
                     str(rng.choice(domain_words)))
    return " ".join(words), n_hard


def make_query(rng: np.random.Generator, *, task_type: Optional[str] = None,
               domain: Optional[str] = None,
               complexity: Optional[float] = None, qid: int = 0
               ) -> QueryRecord:
    tt = task_type or str(rng.choice(TASK_TYPES))
    dm = domain or str(rng.choice(DOMAINS))
    cx = float(rng.random()) if complexity is None else float(complexity)
    lex = DOMAIN_LEXICON[dm]
    template = str(rng.choice(TEMPLATES[tt]))
    blob, _ = _complexity_blob(rng, cx, lex)
    topic = " ".join(rng.choice(lex, 2))
    text = template.format(topic=topic, blob=blob)
    # quantize ground-truth complexity to what is recoverable from text
    cx_obs = min(1.0, (len(text.split()) - 10) / 130.0 * 0.7
                 + sum(text.count(m) for m in _HARD_MARKERS) / 6.0 * 0.3 + 0.0)
    sig = TaskSignature(task_type=tt, domain=dm,
                        complexity=round(max(0.0, cx_obs), 4))
    return QueryRecord(text=text, sig=sig, id=qid)


def inflate_query(rec: QueryRecord, target_words: int,
                  rng: np.random.Generator) -> QueryRecord:
    """Pad a query's middle with context filler to ``target_words``
    keeping the task description at the edges (the paper's 10k+-word
    long-query shape).  The signature is unchanged: the blob is context,
    not task."""
    words = rec.text.split()
    need = target_words - len(words)
    if need <= 0:
        return rec
    blob = list(rng.choice(_FILLER, need))
    cut = max(len(words) // 2, 1)
    return dataclasses.replace(
        rec, text=" ".join(words[:cut] + blob + words[cut:]))


def make_workload(n: int, seed: int = 0, *, task_type=None, domain=None,
                  complexity=None, long_frac: float = 0.0,
                  long_words: Tuple[int, int] = (200, 2000)
                  ) -> List[QueryRecord]:
    """``long_frac`` of the queries are inflated to long-context shape
    (uniform word count in ``long_words``) — the paper's production
    query-log mix."""
    rng = np.random.default_rng(seed)
    out = [make_query(rng, task_type=task_type, domain=domain,
                      complexity=complexity, qid=i) for i in range(n)]
    if long_frac:
        for i in range(n):
            if rng.random() < long_frac:
                out[i] = inflate_query(
                    out[i], int(rng.integers(*long_words)), rng)
    return out


# ----------------------------------------------------------------------
# ground-truth model quality (for routing benchmarks)
# ----------------------------------------------------------------------

def meta_of(entry) -> Dict:
    """The ``quality_of`` meta dict for an MRES-style entry (anything
    with name / raw_metrics / task_types / domains attributes)."""
    return {"name": entry.name,
            "accuracy": float(entry.raw_metrics.get("accuracy", 0.5)),
            "task_types": tuple(entry.task_types),
            "domains": tuple(entry.domains)}


def quality_of(entry_meta: Dict, sig: TaskSignature) -> float:
    """Synthetic probability that a model answers a query well.

    Capability model: a model with catalog accuracy ``a`` and domain /
    task-type tags answers with quality a - penalty(complexity beyond
    capability) - penalty(out-of-domain).  Deterministic given
    (entry, sig) so experiments are reproducible.
    """
    acc = float(entry_meta.get("accuracy", 0.5))
    cap = acc                                  # capability proxy
    q = acc
    if sig.complexity > cap:
        q -= 0.8 * (sig.complexity - cap)
    if sig.task_type not in entry_meta.get("task_types", ()):  # wrong tool
        q -= 0.25
    if sig.domain not in entry_meta.get("domains", ()):
        q -= 0.15
    return float(np.clip(q, 0.0, 1.0))


# ----------------------------------------------------------------------
# non-stationary scenarios (online-learning benchmarks)
# ----------------------------------------------------------------------

DRIFT_KINDS = ("quality-drift", "domain-shift", "model-degrade")


@dataclass(frozen=True)
class DriftScenario:
    """A non-stationary traffic episode for the adaptive router.

    kind:
      * ``quality-drift``  — every model's true quality follows a slow
        deterministic sinusoid around its catalog value (phase-shifted
        per model, amplitude ``drift_amp``), so the best model keeps
        changing;
      * ``domain-shift``   — the query mix jumps from ``domain_a`` to
        ``domain_b`` at ``shift_frac`` of the episode (quality table
        static: the context distribution is what moves);
      * ``model-degrade``  — ``degrade_model`` (default: the catalog's
        accuracy leader) loses ``degrade_delta`` true quality at
        ``shift_frac`` of the episode while its catalog metrics stay
        stale — the recovery-after-drift stress test.
    """
    kind: str = "model-degrade"
    n_steps: int = 60
    batch: int = 16
    seed: int = 0
    task_type: Optional[str] = None
    drift_amp: float = 0.35
    drift_period: float = 40.0
    shift_frac: float = 0.5
    domain_a: str = "general"
    domain_b: str = "healthcare"
    degrade_model: Optional[str] = None
    degrade_delta: float = 0.6

    def validate(self) -> "DriftScenario":
        assert self.kind in DRIFT_KINDS, self.kind
        assert 0.0 < self.shift_frac < 1.0
        return self


class NonStationaryWorkload:
    """Per-step query batches plus the time-varying ground-truth
    quality table ``quality(t, model, sig)`` they are scored against.

    ``entries_meta`` is one ``quality_of`` meta dict per catalog model
    (see ``meta_of``), in catalog order; batches and the quality
    trajectory are deterministic in (scenario.seed, t).
    """

    def __init__(self, entries_meta: Sequence[Dict],
                 scenario: DriftScenario):
        self.meta = list(entries_meta)
        self.sc = scenario.validate()
        self.names = [m["name"] for m in self.meta]
        self._col = {n: j for j, n in enumerate(self.names)}
        self.shift_step = int(round(self.sc.n_steps * self.sc.shift_frac))
        if self.sc.kind == "model-degrade":
            name = self.sc.degrade_model or max(
                self.meta, key=lambda m: m["accuracy"])["name"]
            self._degrade_idx = self._col[name]
        else:
            self._degrade_idx = -1

    @property
    def degraded_model(self) -> Optional[str]:
        return (self.names[self._degrade_idx]
                if self._degrade_idx >= 0 else None)

    # ---------------- queries ----------------
    def _domain_at(self, t: int) -> Optional[str]:
        if self.sc.kind != "domain-shift":
            return None                       # uniform domain mix
        return self.sc.domain_a if t < self.shift_step else self.sc.domain_b

    def batch(self, t: int) -> List[QueryRecord]:
        """The step-t query batch (deterministic in (seed, t))."""
        assert 0 <= t < self.sc.n_steps, t
        rng = np.random.default_rng(
            np.random.SeedSequence([self.sc.seed, t]))
        return [make_query(rng, task_type=self.sc.task_type,
                           domain=self._domain_at(t),
                           qid=t * self.sc.batch + i)
                for i in range(self.sc.batch)]

    # ---------------- time-varying quality ----------------
    def _offsets(self, t: int) -> np.ndarray:
        """(N,) true-quality offsets vs. the static catalog table."""
        n = len(self.meta)
        off = np.zeros(n, np.float64)
        if self.sc.kind == "quality-drift":
            phase = 2.0 * np.pi * np.arange(n) / max(n, 1)
            off = self.sc.drift_amp * np.sin(
                2.0 * np.pi * t / self.sc.drift_period + phase)
        elif self.sc.kind == "model-degrade" and t >= self.shift_step:
            off[self._degrade_idx] = -self.sc.degrade_delta
        return off

    def quality(self, t: int, model: str, sig: TaskSignature) -> float:
        """True quality of ``model`` answering ``sig`` at step ``t``."""
        j = self._col[model]
        return float(np.clip(quality_of(self.meta[j], sig)
                             + self._offsets(t)[j], 0.0, 1.0))

    def quality_matrix(self, t: int, sigs: Sequence[TaskSignature]
                       ) -> np.ndarray:
        """(B, N) true qualities of every model on every query — the
        oracle table regret accounting is computed against."""
        base = np.array([[quality_of(m, s) for m in self.meta]
                         for s in sigs], np.float64)
        return np.clip(base + self._offsets(t)[None, :], 0.0, 1.0)


# ----------------------------------------------------------------------
# repeat-heavy replay traffic (semantic-cache benchmarks)
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class ZipfReplayScenario:
    """Repeat-heavy traffic: a fixed pool of ``n_unique`` queries
    replayed ``n_requests`` times with Zipf-distributed popularity
    (rank r drawn with probability proportional to ``r**-zipf_a``) —
    the production query-log shape where a small head of queries
    dominates traffic and a semantic cache pays for itself.

    ``zipf_a`` > 1 concentrates mass on the head (the classic web/LLM
    traffic exponent is ~1); after the pool has been seen once, the
    steady-state repeat fraction is what the cache-hit benchmarks
    measure.  Deterministic in ``seed``.
    """
    n_unique: int = 64
    n_requests: int = 512
    zipf_a: float = 1.1
    seed: int = 0
    task_type: Optional[str] = None
    domain: Optional[str] = None
    complexity: Optional[float] = None

    def validate(self) -> "ZipfReplayScenario":
        assert self.n_unique > 0 and self.n_requests > 0
        assert self.zipf_a > 0.0
        return self

    @property
    def rank_probs(self) -> np.ndarray:
        """(n_unique,) popularity of each pool rank (descending)."""
        p = np.arange(1, self.n_unique + 1, dtype=np.float64) ** -self.zipf_a
        return p / p.sum()


def zipf_replay(sc: ZipfReplayScenario
                ) -> Tuple[List[QueryRecord], np.ndarray]:
    """(query pool, replay order): ``order`` is the (n_requests,) array
    of pool indices in arrival order, drawn from the scenario's Zipf
    popularity.  Replay ``pool[order[i]]`` to reproduce the episode."""
    sc = sc.validate()
    pool = make_workload(sc.n_unique, seed=sc.seed,
                         task_type=sc.task_type, domain=sc.domain,
                         complexity=sc.complexity)
    rng = np.random.default_rng(np.random.SeedSequence([sc.seed, 1]))
    order = rng.choice(sc.n_unique, size=sc.n_requests, p=sc.rank_probs)
    return pool, order.astype(np.int64)


# ----------------------------------------------------------------------
# bursty open-loop traffic + discrete-event serving simulation
# (load-/SLO-aware routing benchmarks)
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class TrafficScenario:
    """A bursty open-loop arrival episode.

    Arrivals follow a piecewise-homogeneous Poisson process: ``base_rate``
    req/s outside the burst window, ``burst_rate`` inside it (the window
    spans ``[burst_start, burst_start + burst_len)`` as fractions of the
    episode).  Every request carries the same latency SLO
    ``deadline_ms``.  The stress shape this models: steady traffic a
    catalog handles easily, then a burst that saturates the statically
    best-scoring model while its alternates still have headroom.
    """
    duration_s: float = 20.0
    base_rate: float = 30.0           # req/s outside the burst
    burst_rate: float = 150.0         # req/s inside the burst
    burst_start: float = 0.25         # fraction of the episode
    burst_len: float = 0.35           # fraction of the episode
    deadline_ms: float = 400.0
    seed: int = 0
    task_type: Optional[str] = "chat"
    domain: Optional[str] = "general"

    def validate(self) -> "TrafficScenario":
        assert self.duration_s > 0 and self.base_rate > 0
        assert self.burst_rate >= self.base_rate
        assert 0.0 <= self.burst_start < 1.0
        assert 0.0 < self.burst_len <= 1.0 - self.burst_start
        return self

    @property
    def burst_window_s(self) -> Tuple[float, float]:
        t0 = self.burst_start * self.duration_s
        return t0, t0 + self.burst_len * self.duration_s


def poisson_arrivals(sc: TrafficScenario) -> np.ndarray:
    """Arrival times for the scenario by thinning: draw a homogeneous
    process at the peak rate, keep each point with prob rate(t)/peak.
    Deterministic in ``sc.seed``."""
    sc = sc.validate()
    rng = np.random.default_rng(sc.seed)
    rmax = sc.burst_rate
    ts: List[np.ndarray] = []
    t = 0.0
    while t < sc.duration_s:                 # chunked gap draws
        gaps = rng.exponential(1.0 / rmax, int(rmax * sc.duration_s) + 64)
        chunk = t + np.cumsum(gaps)
        ts.append(chunk)
        t = float(chunk[-1])
    all_ts = np.concatenate(ts)
    all_ts = all_ts[all_ts < sc.duration_s]
    b0, b1 = sc.burst_window_s
    rate = np.where((all_ts >= b0) & (all_ts < b1),
                    sc.burst_rate, sc.base_rate)
    keep = rng.random(all_ts.size) < rate / rmax
    return all_ts[keep]


# ----------------------------------------------------------------------
# multi-tenant traffic (async serving + soak harness)
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class TenantSpec:
    """One tenant's share of a multi-tenant episode.

    ``rate_scale`` multiplies the base scenario's arrival rates (a
    flooding tenant is simply ``rate_scale`` >> 1); ``weight`` is the
    fair-dequeue share the serving layer should give it; ``rate_limit``
    (req/s) is the token-bucket ceiling intake enforces (None = no
    limit); ``deadline_ms`` overrides the base scenario's SLO for this
    tenant's requests (None = inherit)."""
    name: str
    weight: float = 1.0
    rate_scale: float = 1.0
    rate_limit: Optional[float] = None
    deadline_ms: Optional[float] = None

    def validate(self) -> "TenantSpec":
        assert self.name, "tenant needs a name"
        assert self.weight > 0 and self.rate_scale > 0
        assert self.rate_limit is None or self.rate_limit > 0
        return self


@dataclass(frozen=True)
class MultiTenantScenario:
    """A shared bursty episode fanned out across tenants: every tenant
    draws its own independent Poisson process shaped like ``base``
    scaled by its ``rate_scale`` (seeded per tenant, so episodes are
    reproducible and tenants are independent)."""
    base: TrafficScenario = TrafficScenario()
    tenants: Tuple[TenantSpec, ...] = (
        TenantSpec("acme"), TenantSpec("globex"))

    def validate(self) -> "MultiTenantScenario":
        self.base.validate()
        names = [t.validate().name for t in self.tenants]
        assert len(names) == len(set(names)), f"duplicate tenants: {names}"
        assert names, "need at least one tenant"
        return self

    def deadline_ms_of(self, tenant_idx: int) -> float:
        t = self.tenants[tenant_idx]
        return float(t.deadline_ms if t.deadline_ms is not None
                     else self.base.deadline_ms)


def multi_tenant_arrivals(sc: MultiTenantScenario
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Merged arrival stream: ``(times, tenant_idx)`` — times sorted
    ascending, ``tenant_idx[i]`` indexing ``sc.tenants``.  Each
    tenant's process is an independently-seeded copy of the base
    scenario with its rates scaled by ``rate_scale``."""
    sc = sc.validate()
    times: List[np.ndarray] = []
    idx: List[np.ndarray] = []
    for i, t in enumerate(sc.tenants):
        per = dataclasses.replace(
            sc.base,
            base_rate=sc.base.base_rate * t.rate_scale,
            burst_rate=sc.base.burst_rate * t.rate_scale,
            seed=sc.base.seed + 7919 * (i + 1))
        a = poisson_arrivals(per)
        times.append(a)
        idx.append(np.full(a.size, i, np.int64))
    ts = np.concatenate(times) if times else np.zeros(0)
    ti = np.concatenate(idx) if idx else np.zeros(0, np.int64)
    order = np.argsort(ts, kind="stable")
    return ts[order], ti[order]


def jain_fairness(x: Sequence[float]) -> float:
    """Jain's fairness index of per-tenant allocations: 1.0 when all
    equal, -> 1/n as one tenant dominates.  Feed it WEIGHT-NORMALIZED
    goodput (served/weight) so weighted fairness scores as 1.0."""
    v = np.asarray(list(x), np.float64)
    if v.size == 0 or not np.any(v):
        return 1.0
    return float(v.sum() ** 2 / (v.size * (v ** 2).sum()))


class ServingSimulator:
    """Discrete-event queueing simulator over a routed catalog.

    Model ``n`` has ``capacity[n]`` parallel servers with deterministic
    per-request service time ``service_s[n]``.  Requests arrive at the
    given times, are assigned a model by ``route_fn`` (which sees the
    LIVE tracker state, because every queue/slot transition is mirrored
    into ``tracker`` as it happens), wait FIFO for a free server,
    execute, and complete.  Shed requests never occupy a server.

    ``route_fn(i, t) -> (model_col, admission_kind)`` with kind in
    ``repro.serving.load.ADMISSION_KINDS``; a load-blind policy simply
    always returns ("admitted", static choice).

    ``run`` returns packed per-request arrays (model, admission codes,
    wait/latency seconds, SLO misses) plus aggregate percentiles — the
    evidence table the load-aware benchmark reads.
    """

    def __init__(self, service_s: Sequence[float],
                 capacity: Sequence[float], tracker=None):
        self.service_s = np.asarray(service_s, np.float64)
        self.capacity = np.asarray(capacity, np.int64)
        assert self.service_s.shape == self.capacity.shape
        assert (self.service_s > 0).all() and (self.capacity > 0).all()
        self.tracker = tracker
        if tracker is not None:
            tracker.ensure(len(self.service_s))
            for j, c in enumerate(self.capacity):
                tracker.set_capacity(j, float(c))

    # ------------------------------------------------------------------
    def run(self, arrivals: np.ndarray,
            route_fn: Callable[[int, float], Tuple[int, str]],
            deadline_ms: Optional[float] = None) -> Dict[str, np.ndarray]:
        arrivals = np.asarray(arrivals, np.float64)
        R = arrivals.size
        n = len(self.service_s)
        busy = np.zeros(n, np.int64)
        queues: List[List[Tuple[float, int]]] = [[] for _ in range(n)]
        qhead = np.zeros(n, np.int64)        # FIFO pop index per model
        done_t = np.full(R, np.nan)
        start_t = np.full(R, np.nan)
        model = np.full(R, -1, np.int64)
        shed = np.zeros(R, bool)
        rerouted = np.zeros(R, bool)
        events: List[Tuple[float, int, int]] = []   # (finish, model, req)
        trk = self.tracker

        def begin(req: int, m: int, now: float) -> None:
            busy[m] += 1
            start_t[req] = now
            fin = now + self.service_s[m]
            done_t[req] = fin
            if trk is not None:
                trk.start(m)
            heapq.heappush(events, (fin, m, req))

        def drain_until(now: float) -> None:
            while events and events[0][0] <= now:
                fin, m, req = heapq.heappop(events)
                busy[m] -= 1
                if trk is not None:
                    trk.finish(m, float(self.service_s[m]))
                if qhead[m] < len(queues[m]):        # hand the slot on
                    _, nxt = queues[m][qhead[m]]
                    qhead[m] += 1
                    begin(nxt, m, fin)

        for i, t in enumerate(arrivals):
            drain_until(float(t))
            m, kind = route_fn(i, float(t))
            if kind == "shed":
                shed[i] = True
                model[i] = m
                continue
            rerouted[i] = kind == "rerouted"
            model[i] = m
            if trk is not None:
                trk.admit(m)
            if busy[m] < self.capacity[m]:
                begin(i, m, float(t))
            else:
                queues[m].append((float(t), i))
        drain_until(np.inf)                          # flush the tail

        served = ~shed
        latency = np.where(served, done_t - arrivals, np.nan)
        wait = np.where(served, start_t - arrivals, np.nan)
        out: Dict[str, np.ndarray] = {
            "arrival_s": arrivals, "model": model, "shed": shed,
            "rerouted": rerouted, "latency_s": latency, "wait_s": wait,
        }
        lat_ok = latency[served]
        out["p50_s"] = float(np.quantile(lat_ok, 0.5)) if lat_ok.size else 0.0
        out["p99_s"] = float(np.quantile(lat_ok, 0.99)) if lat_ok.size else 0.0
        if deadline_ms is not None:
            # a shed request is an SLO miss by definition: it got no answer
            miss = shed | (np.nan_to_num(latency, nan=np.inf)
                           > deadline_ms / 1e3)
            out["slo_miss"] = miss
            out["slo_miss_rate"] = float(miss.mean()) if R else 0.0
        return out
