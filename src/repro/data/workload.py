"""Synthetic query workload generator (the paper's "query logs of a
production MLaaS cloud provider" stand-in, §3.2).

Each query is built from a task-type template + domain lexicon words +
complexity-controlled filler.  The generator records the ground-truth
TaskSignature (the label the analyzer is trained against) and a
per-model ground-truth quality table used by the routing benchmarks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.preferences import DOMAINS, TASK_TYPES, TaskSignature

# ----------------------------------------------------------------------
# templates & lexicons
# ----------------------------------------------------------------------

TEMPLATES: Dict[str, List[str]] = {
    "chat": ["hello can you help me with {topic}",
             "i have a question about {topic}",
             "what do you think about {topic}"],
    "code": ["write a python function that computes {topic}",
             "fix the bug in this code {blob}",
             "refactor this module for readability {blob}"],
    "reasoning": ["solve this step by step {blob}",
                  "prove that {topic} holds for all cases",
                  "which option is correct and why {blob}"],
    "summarization": ["summarize the following article {blob}",
                      "give me a tl dr of this document {blob}",
                      "condense these meeting notes {blob}"],
    "classification": ["find the sentiment of the passage {blob}",
                       "classify this ticket into a category {blob}",
                       "label the intent of this message {blob}"],
    "translation": ["translate this passage to german {blob}",
                    "convert the following text into french {blob}",
                    "translate to spanish keeping the tone {blob}"],
    "transcription": ["transcribe the attached audio about {topic}",
                      "produce a transcript of this recording {topic}",
                      "caption the spoken audio {topic}"],
    "vqa": ["looking at the image what is {topic}",
            "answer the question about the attached picture {topic}",
            "from the screenshot determine {topic}"],
    "captioning": ["describe the attached image of {topic}",
                   "write alt text for this picture of {topic}",
                   "caption this photo about {topic}"],
    "creative-writing": ["write a short story about {topic}",
                         "compose a poem on {topic}",
                         "draft a fictional dialogue about {topic}"],
    "long-context": ["using the entire report below answer {topic} {blob}",
                     "search this long document for {topic} {blob}",
                     "cross reference the chapters below about {topic} {blob}"],
}

DOMAIN_LEXICON: Dict[str, List[str]] = {
    "general": ["weather", "travel", "cooking", "music", "history",
                "sports", "gardening"],
    "software": ["kubernetes", "compiler", "database", "frontend", "api",
                 "microservice", "deployment", "regression"],
    "finance": ["portfolio", "derivatives", "equity", "hedging", "ledger",
                "liquidity", "arbitrage", "quarterly"],
    "legal": ["contract", "liability", "statute", "plaintiff", "clause",
              "compliance", "jurisdiction", "tort"],
    "healthcare": ["diagnosis", "dosage", "radiology", "oncology",
                   "symptom", "clinical", "pathology", "triage"],
    "multilingual": ["german", "mandarin", "localization", "dialect",
                     "idiom", "bilingual", "transliteration"],
}

_FILLER = ["the", "report", "shows", "that", "we", "observed", "several",
           "items", "during", "review", "and", "noted", "further", "points",
           "for", "discussion", "in", "section"]
_HARD_MARKERS = ["however", "sarcastically", "notwithstanding", "paradox",
                 "ambiguous", "nested", "caveat", "irony", "subtle",
                 "counterintuitive"]


@dataclass(frozen=True)
class QueryRecord:
    text: str
    sig: TaskSignature          # ground truth implicit preferences
    id: int = 0


def _complexity_blob(rng, complexity: float, domain_words) -> Tuple[str, int]:
    """Filler blob whose length/markers encode the complexity."""
    n_fill = int(10 + complexity * 120)
    words = list(rng.choice(_FILLER, n_fill))
    n_hard = int(round(complexity * 6))
    for _ in range(n_hard):
        words.insert(int(rng.integers(0, len(words))),
                     str(rng.choice(_HARD_MARKERS)))
    for _ in range(3):
        words.insert(int(rng.integers(0, len(words))),
                     str(rng.choice(domain_words)))
    return " ".join(words), n_hard


def make_query(rng: np.random.Generator, *, task_type: Optional[str] = None,
               domain: Optional[str] = None,
               complexity: Optional[float] = None, qid: int = 0
               ) -> QueryRecord:
    tt = task_type or str(rng.choice(TASK_TYPES))
    dm = domain or str(rng.choice(DOMAINS))
    cx = float(rng.random()) if complexity is None else float(complexity)
    lex = DOMAIN_LEXICON[dm]
    template = str(rng.choice(TEMPLATES[tt]))
    blob, _ = _complexity_blob(rng, cx, lex)
    topic = " ".join(rng.choice(lex, 2))
    text = template.format(topic=topic, blob=blob)
    # quantize ground-truth complexity to what is recoverable from text
    cx_obs = min(1.0, (len(text.split()) - 10) / 130.0 * 0.7
                 + sum(text.count(m) for m in _HARD_MARKERS) / 6.0 * 0.3 + 0.0)
    sig = TaskSignature(task_type=tt, domain=dm,
                        complexity=round(max(0.0, cx_obs), 4))
    return QueryRecord(text=text, sig=sig, id=qid)


def inflate_query(rec: QueryRecord, target_words: int,
                  rng: np.random.Generator) -> QueryRecord:
    """Pad a query's middle with context filler to ``target_words``
    keeping the task description at the edges (the paper's 10k+-word
    long-query shape).  The signature is unchanged: the blob is context,
    not task."""
    words = rec.text.split()
    need = target_words - len(words)
    if need <= 0:
        return rec
    blob = list(rng.choice(_FILLER, need))
    cut = max(len(words) // 2, 1)
    return dataclasses.replace(
        rec, text=" ".join(words[:cut] + blob + words[cut:]))


def make_workload(n: int, seed: int = 0, *, task_type=None, domain=None,
                  complexity=None, long_frac: float = 0.0,
                  long_words: Tuple[int, int] = (200, 2000)
                  ) -> List[QueryRecord]:
    """``long_frac`` of the queries are inflated to long-context shape
    (uniform word count in ``long_words``) — the paper's production
    query-log mix."""
    rng = np.random.default_rng(seed)
    out = [make_query(rng, task_type=task_type, domain=domain,
                      complexity=complexity, qid=i) for i in range(n)]
    if long_frac:
        for i in range(n):
            if rng.random() < long_frac:
                out[i] = inflate_query(
                    out[i], int(rng.integers(*long_words)), rng)
    return out


# ----------------------------------------------------------------------
# ground-truth model quality (for routing benchmarks)
# ----------------------------------------------------------------------

def meta_of(entry) -> Dict:
    """The ``quality_of`` meta dict for an MRES-style entry (anything
    with name / raw_metrics / task_types / domains attributes)."""
    return {"name": entry.name,
            "accuracy": float(entry.raw_metrics.get("accuracy", 0.5)),
            "task_types": tuple(entry.task_types),
            "domains": tuple(entry.domains)}


def quality_of(entry_meta: Dict, sig: TaskSignature) -> float:
    """Synthetic probability that a model answers a query well.

    Capability model: a model with catalog accuracy ``a`` and domain /
    task-type tags answers with quality a - penalty(complexity beyond
    capability) - penalty(out-of-domain).  Deterministic given
    (entry, sig) so experiments are reproducible.
    """
    acc = float(entry_meta.get("accuracy", 0.5))
    cap = acc                                  # capability proxy
    q = acc
    if sig.complexity > cap:
        q -= 0.8 * (sig.complexity - cap)
    if sig.task_type not in entry_meta.get("task_types", ()):  # wrong tool
        q -= 0.25
    if sig.domain not in entry_meta.get("domains", ()):
        q -= 0.15
    return float(np.clip(q, 0.0, 1.0))


# ----------------------------------------------------------------------
# non-stationary scenarios (online-learning benchmarks)
# ----------------------------------------------------------------------

DRIFT_KINDS = ("quality-drift", "domain-shift", "model-degrade")


@dataclass(frozen=True)
class DriftScenario:
    """A non-stationary traffic episode for the adaptive router.

    kind:
      * ``quality-drift``  — every model's true quality follows a slow
        deterministic sinusoid around its catalog value (phase-shifted
        per model, amplitude ``drift_amp``), so the best model keeps
        changing;
      * ``domain-shift``   — the query mix jumps from ``domain_a`` to
        ``domain_b`` at ``shift_frac`` of the episode (quality table
        static: the context distribution is what moves);
      * ``model-degrade``  — ``degrade_model`` (default: the catalog's
        accuracy leader) loses ``degrade_delta`` true quality at
        ``shift_frac`` of the episode while its catalog metrics stay
        stale — the recovery-after-drift stress test.
    """
    kind: str = "model-degrade"
    n_steps: int = 60
    batch: int = 16
    seed: int = 0
    task_type: Optional[str] = None
    drift_amp: float = 0.35
    drift_period: float = 40.0
    shift_frac: float = 0.5
    domain_a: str = "general"
    domain_b: str = "healthcare"
    degrade_model: Optional[str] = None
    degrade_delta: float = 0.6

    def validate(self) -> "DriftScenario":
        assert self.kind in DRIFT_KINDS, self.kind
        assert 0.0 < self.shift_frac < 1.0
        return self


class NonStationaryWorkload:
    """Per-step query batches plus the time-varying ground-truth
    quality table ``quality(t, model, sig)`` they are scored against.

    ``entries_meta`` is one ``quality_of`` meta dict per catalog model
    (see ``meta_of``), in catalog order; batches and the quality
    trajectory are deterministic in (scenario.seed, t).
    """

    def __init__(self, entries_meta: Sequence[Dict],
                 scenario: DriftScenario):
        self.meta = list(entries_meta)
        self.sc = scenario.validate()
        self.names = [m["name"] for m in self.meta]
        self._col = {n: j for j, n in enumerate(self.names)}
        self.shift_step = int(round(self.sc.n_steps * self.sc.shift_frac))
        if self.sc.kind == "model-degrade":
            name = self.sc.degrade_model or max(
                self.meta, key=lambda m: m["accuracy"])["name"]
            self._degrade_idx = self._col[name]
        else:
            self._degrade_idx = -1

    @property
    def degraded_model(self) -> Optional[str]:
        return (self.names[self._degrade_idx]
                if self._degrade_idx >= 0 else None)

    # ---------------- queries ----------------
    def _domain_at(self, t: int) -> Optional[str]:
        if self.sc.kind != "domain-shift":
            return None                       # uniform domain mix
        return self.sc.domain_a if t < self.shift_step else self.sc.domain_b

    def batch(self, t: int) -> List[QueryRecord]:
        """The step-t query batch (deterministic in (seed, t))."""
        assert 0 <= t < self.sc.n_steps, t
        rng = np.random.default_rng(
            np.random.SeedSequence([self.sc.seed, t]))
        return [make_query(rng, task_type=self.sc.task_type,
                           domain=self._domain_at(t),
                           qid=t * self.sc.batch + i)
                for i in range(self.sc.batch)]

    # ---------------- time-varying quality ----------------
    def _offsets(self, t: int) -> np.ndarray:
        """(N,) true-quality offsets vs. the static catalog table."""
        n = len(self.meta)
        off = np.zeros(n, np.float64)
        if self.sc.kind == "quality-drift":
            phase = 2.0 * np.pi * np.arange(n) / max(n, 1)
            off = self.sc.drift_amp * np.sin(
                2.0 * np.pi * t / self.sc.drift_period + phase)
        elif self.sc.kind == "model-degrade" and t >= self.shift_step:
            off[self._degrade_idx] = -self.sc.degrade_delta
        return off

    def quality(self, t: int, model: str, sig: TaskSignature) -> float:
        """True quality of ``model`` answering ``sig`` at step ``t``."""
        j = self._col[model]
        return float(np.clip(quality_of(self.meta[j], sig)
                             + self._offsets(t)[j], 0.0, 1.0))

    def quality_matrix(self, t: int, sigs: Sequence[TaskSignature]
                       ) -> np.ndarray:
        """(B, N) true qualities of every model on every query — the
        oracle table regret accounting is computed against."""
        base = np.array([[quality_of(m, s) for m in self.meta]
                         for s in sigs], np.float64)
        return np.clip(base + self._offsets(t)[None, :], 0.0, 1.0)
