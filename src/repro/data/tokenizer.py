"""Deterministic hash tokenizer.

No external vocab files: a word maps to ``2 + md5(word) % (vocab - 2)``.
Collisions are acceptable for this system — the Task Analyzer only needs
stable, repeatable ids for template/lexicon keywords, and the serving
stack treats token ids as opaque.  0 = pad, 1 = bos.
"""
from __future__ import annotations

import hashlib
import re
from typing import List, Sequence

import numpy as np

PAD_ID = 0
BOS_ID = 1
_WORD_RE = re.compile(r"[a-z0-9']+")


class HashTokenizer:
    def __init__(self, vocab_size: int = 4096):
        assert vocab_size > 2
        self.vocab_size = vocab_size
        self._cache: dict = {}

    def word_id(self, word: str) -> int:
        wid = self._cache.get(word)
        if wid is None:
            h = hashlib.md5(word.encode()).digest()
            wid = 2 + int.from_bytes(h[:8], "little") % (self.vocab_size - 2)
            self._cache[word] = wid
        return wid

    def words(self, text: str) -> List[str]:
        return _WORD_RE.findall(text.lower())

    def encode(self, text: str, max_len: int = 0, bos: bool = True) -> List[int]:
        ids = [self.word_id(w) for w in self.words(text)]
        if bos:
            ids = [BOS_ID] + ids
        if max_len:
            ids = ids[:max_len]
        return ids

    def encode_batch(self, texts: Sequence[str], max_len: int) -> np.ndarray:
        """Right-padded (B, max_len) int32 batch.

        Vectorized twin of per-row ``encode``: one flat word stream,
        ``np.unique`` to hash (md5 + memo) each distinct word once,
        and a single fancy-index scatter instead of B row writes.
        Bit-identical to the loop (property-tested) — ``encode`` stays
        as the reference implementation.
        """
        B = len(texts)
        out = np.full((B, max_len), PAD_ID, np.int32)
        if B == 0 or max_len == 0:
            return out
        out[:, 0] = BOS_ID
        flat: List[str] = []
        counts = np.empty(B, np.int64)
        keep = max_len - 1          # room after the BOS column
        for i, t in enumerate(texts):
            ws = _WORD_RE.findall(t.lower())[:keep]
            counts[i] = len(ws)
            flat.extend(ws)
        if not flat:
            return out
        uniq, inv = np.unique(np.asarray(flat, object),
                              return_inverse=True)
        ids_flat = np.asarray([self.word_id(w) for w in uniq],
                              np.int32)[inv]
        rows = np.repeat(np.arange(B), counts)
        ends = np.cumsum(counts)
        within = np.arange(len(flat)) - (ends - counts)[rows]
        out[rows, within + 1] = ids_flat
        return out
