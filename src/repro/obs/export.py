"""Prometheus-style text exposition for the telemetry ledger.

``prometheus_text(telemetry, ...)`` renders the full metric set with
stable names (dashboards and the CI SLO gate key on these):

    repro_requests_total{model="..."}           counter
    repro_fallback_total{stage="..."}           counter
    repro_admission_total{kind="..."}           counter
    repro_tenant_admission_total{kind=,tenant=} counter
    repro_cache_total{kind="..."}               counter
    repro_counter_total{name="..."}             counter (Telemetry.inc)
    repro_gauge{name="..."}                     gauge (set_gauge)
    repro_route_step_dispatches_total           counter
    repro_route_step_compiles_total             counter
    repro_sharding_silent_replications_total    counter
    repro_events_total                          counter
    repro_qps                                   gauge
    repro_route_latency_seconds{quantile=...}   summary (+ _sum/_count)
    repro_model_latency_seconds{model=,quantile=} summary
    repro_model_cost_total{model="..."}         counter
    repro_load_queue_depth{model=} / repro_load_inflight{model=} gauges
    repro_trace_spans_total                     counter (when tracer given)

``write_prom`` dumps to ``results/metrics.prom``; ``serve_metrics``
exposes ``GET /metrics`` on a background stdlib HTTP server (no new
dependencies); ``parse_prom_text``/``metrics_from_prom`` read the text
back into a flat dict — that is how the CLI SLO gate consumes a dump
from a previous process.
"""
from __future__ import annotations

import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

_QUANTILES = (0.5, 0.9, 0.99)


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(value: float) -> str:
    f = float(value)
    return str(int(f)) if f == int(f) else repr(f)


class _Writer:
    def __init__(self):
        self.lines = []

    def header(self, name: str, mtype: str, help_: str) -> None:
        self.lines.append(f"# HELP {name} {help_}")
        self.lines.append(f"# TYPE {name} {mtype}")

    def sample(self, name: str, value: float,
               labels: Optional[Dict[str, str]] = None) -> None:
        if labels:
            lab = ",".join(f'{k}="{_esc(str(v))}"'
                           for k, v in sorted(labels.items()))
            self.lines.append(f"{name}{{{lab}}} {_fmt(value)}")
        else:
            self.lines.append(f"{name} {_fmt(value)}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def prometheus_text(telemetry, *, load=None, tracer=None,
                    cost_profile=None) -> str:
    """Render telemetry (and optional load/tracer/profiler state) in
    Prometheus text exposition format."""
    s = telemetry.summary()
    w = _Writer()

    w.header("repro_events_total", "counter", "Route events recorded")
    w.sample("repro_events_total", s["events"])

    w.header("repro_requests_total", "counter", "Requests per model")
    for model, agg in sorted(s["per_model"].items()):
        w.sample("repro_requests_total", agg["requests"],
                 {"model": model})

    w.header("repro_fallback_total", "counter",
             "Routing fallback ladder stage counts")
    for stage, n in sorted(s["fallback_funnel"].items()):
        w.sample("repro_fallback_total", n,
                 {"stage": stage or "none"})

    w.header("repro_admission_total", "counter",
             "Admission verdicts (admitted/rerouted/shed)")
    for kind, n in sorted(s["admission_funnel"].items()):
        w.sample("repro_admission_total", n, {"kind": kind})

    tenants = s.get("admission_by_tenant", {})
    if tenants:
        w.header("repro_tenant_admission_total", "counter",
                 "Admission verdicts per tenant")
        for tenant, kinds in sorted(tenants.items()):
            for kind, n in sorted(kinds.items()):
                w.sample("repro_tenant_admission_total", n,
                         {"kind": kind, "tenant": tenant})

    w.header("repro_cache_total", "counter", "Semantic cache outcomes")
    for kind, n in sorted(s["cache_funnel"].items()):
        w.sample("repro_cache_total", n, {"kind": kind})

    rs = s["route_step"]
    w.header("repro_route_step_dispatches_total", "counter",
             "Fused route-step device dispatches")
    w.sample("repro_route_step_dispatches_total", rs["dispatches"])
    w.header("repro_route_step_compiles_total", "counter",
             "Fused route-step recompiles (0 after warmup)")
    w.sample("repro_route_step_compiles_total", rs["compiles"])

    an = s.get("analyze_step")
    if an is not None:
        w.header("repro_analyze_step_dispatches_total", "counter",
                 "Analyzer-stage device dispatches (solo or fused)")
        w.sample("repro_analyze_step_dispatches_total",
                 an["dispatches"])
        w.header("repro_analyze_step_compiles_total", "counter",
                 "Analyzer-stage recompiles (0 after warmup)")
        w.sample("repro_analyze_step_compiles_total", an["compiles"])

    w.header("repro_sharding_silent_replications_total", "counter",
             "Catalog shards silently replicated instead of split")
    w.sample("repro_sharding_silent_replications_total",
             s["sharding"]["silent_replications"])

    w.header("repro_qps", "gauge", "Requests/s over the rolling window")
    w.sample("repro_qps", s["qps"])

    w.header("repro_route_latency_seconds", "summary",
             "End-to-end route latency (analyzer + route)")
    lp = s["latency_percentiles"]
    for q in _QUANTILES:
        key = f"p{int(q * 100)}"
        w.sample("repro_route_latency_seconds", lp[key],
                 {"quantile": str(q)})
    lt = s["latency_totals"]
    w.sample("repro_route_latency_seconds_sum", lt["sum"])
    w.sample("repro_route_latency_seconds_count", lt["count"])

    w.header("repro_model_latency_seconds", "summary",
             "Per-model route latency")
    for model, agg in sorted(s["per_model"].items()):
        for q, key in ((0.5, "latency_p50_s"), (0.99, "latency_p99_s")):
            w.sample("repro_model_latency_seconds", agg[key],
                     {"model": model, "quantile": str(q)})

    w.header("repro_model_cost_total", "counter",
             "Simulated serving cost per model")
    for model, agg in sorted(s["per_model"].items()):
        w.sample("repro_model_cost_total", agg["cost"], {"model": model})

    counters = s.get("counters", {})
    if counters:
        w.header("repro_counter_total", "counter",
                 "Generic monotonic counters (Telemetry.inc)")
        for name, v in sorted(counters.items()):
            w.sample("repro_counter_total", v, {"name": name})
    gauges = s.get("gauges", {})
    if gauges:
        w.header("repro_gauge", "gauge",
                 "Generic point-in-time gauges (Telemetry.set_gauge)")
        for name, v in sorted(gauges.items()):
            w.sample("repro_gauge", v, {"name": name})

    if load is not None:
        lm = load.metrics()
        w.header("repro_load_queue_depth", "gauge",
                 "Queued requests per model")
        for model, v in sorted(lm["queue_depth"].items()):
            w.sample("repro_load_queue_depth", v, {"model": model})
        w.header("repro_load_inflight", "gauge",
                 "In-flight requests per model")
        for model, v in sorted(lm["inflight"].items()):
            w.sample("repro_load_inflight", v, {"model": model})

    if tracer is not None:
        ts = tracer.stats()
        w.header("repro_trace_spans_total", "counter",
                 "Trace spans recorded")
        w.sample("repro_trace_spans_total", ts["spans_total"])
        w.header("repro_trace_spans_retained", "gauge",
                 "Trace spans currently in the ring")
        w.sample("repro_trace_spans_retained", ts["spans_retained"])

    if cost_profile:
        w.header("repro_route_step_flops", "gauge",
                 "XLA cost_analysis FLOPs per route-step bucket")
        w.header("repro_route_step_bytes", "gauge",
                 "XLA cost_analysis bytes accessed per bucket")
        for bucket, prof in sorted(cost_profile.items()):
            lab = {"bucket": str(bucket)}
            if prof.get("flops") is not None:
                w.sample("repro_route_step_flops", prof["flops"], lab)
            if prof.get("bytes_accessed") is not None:
                w.sample("repro_route_step_bytes",
                         prof["bytes_accessed"], lab)

    return w.text()


def write_prom(path, telemetry, **kw) -> str:
    """Render and write ``path``; returns the rendered text."""
    text = prometheus_text(telemetry, **kw)
    with open(path, "w") as f:
        f.write(text)
    return text


# ----------------------------------------------------------------------
# reading the text format back (CI SLO gate on a dumped .prom file)
# ----------------------------------------------------------------------
def parse_prom_text(text: str) -> Dict[str, float]:
    """Parse exposition text into ``{name{label="v"}: value}`` flat keys
    (label-free samples key on the bare name)."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        # value is the last whitespace-separated token; the metric key
        # (which may contain spaces inside label values) is the rest
        key, _, val = line.rpartition(" ")
        try:
            out[key] = float(val)
        except ValueError:
            continue
    return out


def metrics_from_prom(text: str) -> Dict[str, float]:
    """Flat metrics plus the derived ratios the SLO rules target
    (shed_rate, cache_hit_rate, ...)."""
    raw = parse_prom_text(text)
    m = dict(raw)

    def lab(name: str, **labels) -> float:
        lab_s = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
        return raw.get(f"{name}{{{lab_s}}}", 0.0)

    admitted = lab("repro_admission_total", kind="admitted")
    rerouted = lab("repro_admission_total", kind="rerouted")
    shed = lab("repro_admission_total", kind="shed")
    failed = lab("repro_admission_total", kind="failed")
    planned = admitted + rerouted + shed + failed
    m["shed_rate"] = shed / planned if planned else 0.0
    m["failed_rate"] = failed / planned if planned else 0.0

    # per-tenant shed rates (SLO rules key on tenant_shed_rate_<name>
    # and the cross-tenant worst case tenant_shed_rate_max)
    per_tenant: Dict[str, Dict[str, float]] = {}
    pat = re.compile(r'^repro_tenant_admission_total\{kind="([^"]+)",'
                     r'tenant="([^"]+)"\}$')
    for key, v in raw.items():
        match = pat.match(key)
        if match:
            per_tenant.setdefault(match.group(2), {})[match.group(1)] = v
    rates = []
    for tenant, kinds in sorted(per_tenant.items()):
        total = sum(kinds.values())
        rate = kinds.get("shed", 0.0) / total if total else 0.0
        m[f"tenant_shed_rate_{tenant}"] = rate
        rates.append(rate)
    m["tenant_shed_rate_max"] = max(rates) if rates else 0.0

    # generic counters/gauges surface under their bare names so SLO
    # rules can target them directly (e.g. the soak harness's
    # ``soak_p999_s`` / ``soak_post_warmup_compiles`` gauges); derived
    # keys above win any collision
    for pat2, kind in (
            (re.compile(r'^repro_gauge\{name="([^"]+)"\}$'), "gauge"),
            (re.compile(r'^repro_counter_total\{name="([^"]+)"\}$'),
             "counter")):
        for key, v in raw.items():
            match = pat2.match(key)
            if match and match.group(1) not in m:
                m[match.group(1)] = v

    hits = lab("repro_cache_total", kind="hit")
    misses = lab("repro_cache_total", kind="miss")
    looked = hits + misses
    m["cache_hit_rate"] = hits / looked if looked else 0.0

    m["route_step_compiles"] = raw.get(
        "repro_route_step_compiles_total", 0.0)
    m["route_step_dispatches"] = raw.get(
        "repro_route_step_dispatches_total", 0.0)
    m["analyze_step_compiles"] = raw.get(
        "repro_analyze_step_compiles_total", 0.0)
    m["analyze_step_dispatches"] = raw.get(
        "repro_analyze_step_dispatches_total", 0.0)
    m["silent_replications"] = raw.get(
        "repro_sharding_silent_replications_total", 0.0)
    m["route_latency_p99"] = lab("repro_route_latency_seconds",
                                 quantile="0.99")
    m["route_latency_p50"] = lab("repro_route_latency_seconds",
                                 quantile="0.5")
    m["qps"] = raw.get("repro_qps", 0.0)
    m["events"] = raw.get("repro_events_total", 0.0)
    return m


# ----------------------------------------------------------------------
# /metrics endpoint (stdlib only)
# ----------------------------------------------------------------------
class MetricsServer:
    """Background HTTP server exposing ``GET /metrics``.

    Renders fresh exposition text per scrape from the live telemetry
    (plus optional load/tracer).  ``close()`` shuts it down; also
    usable as a context manager.
    """

    def __init__(self, telemetry, *, load=None, tracer=None,
                 host: str = "127.0.0.1", port: int = 0):
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                       # noqa: N802
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = prometheus_text(
                    outer.telemetry, load=outer.load,
                    tracer=outer.tracer).encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):               # quiet
                pass

        self.telemetry = telemetry
        self.load = load
        self.tracer = tracer
        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def serve_metrics(telemetry, *, load=None, tracer=None,
                  host: str = "127.0.0.1", port: int = 0) -> MetricsServer:
    return MetricsServer(telemetry, load=load, tracer=tracer,
                        host=host, port=port)
