"""Observability layer: tracing, bounded metrics, export, SLOs.

* ``trace``   — nestable span tracer threaded through the serving path
* ``metrics`` — fixed-memory counters / gauges / log histograms
* ``export``  — Prometheus text exposition (+ /metrics endpoint)
* ``slo``     — declarative SLO rules with burn-rate breach detection
* ``profile`` — XLA cost_analysis + jax.profiler capture hooks
"""
from .export import (MetricsServer, metrics_from_prom, parse_prom_text,
                     prometheus_text, serve_metrics, write_prom)
from .metrics import Counter, Gauge, LogHistogram
from .profile import DeviceCostProfiler, trace_capture
from .slo import (SLOEvaluator, SLORule, Verdict, evaluate_rules,
                  parse_rule, parse_rules)
from .trace import NOOP_SPAN, Span, Tracer

__all__ = [
    "Counter", "DeviceCostProfiler", "Gauge", "LogHistogram",
    "MetricsServer", "NOOP_SPAN", "SLOEvaluator", "SLORule", "Span",
    "Tracer", "Verdict", "evaluate_rules", "metrics_from_prom",
    "parse_prom_text", "parse_rule", "parse_rules", "prometheus_text",
    "serve_metrics", "trace_capture", "write_prom",
]
