"""Declarative SLO rules with burn-rate breach detection.

Rule grammar (one rule per line / per ``--rule`` flag)::

    [name:] <metric> <op> <threshold> [burn <short>s/<long>s [x<factor>]]

    route_p99:  route_latency_p99 <= 0.05
    shed:       shed_rate         <= 0.01   burn 60s/600s x2
    cache:      cache_hit_rate    >= 0.30
    recompiles: route_step_compiles == 0

Without a ``burn`` clause the rule is a point check against the
current metric value.  With one, the rule becomes a multi-window
burn-rate alert in the SRE-workbook style: the evaluator keeps
cumulative ``(ts, bad, total)`` snapshots (fed via ``observe``) and
fires only when the *bad fraction* over BOTH the short and the long
window exceeds ``factor * threshold`` — the short window makes the
alert reset quickly when the problem stops, the long window keeps a
brief spike from paging.  Ratio metrics (``*_rate``) map naturally;
for point metrics the "bad fraction" degenerates to the windowed mean.

``evaluate`` returns per-rule verdicts; the CLI (``python -m
repro.obs.slo --metrics results/metrics.prom --rule ...``) exits 1 on
any breach, which is how CI gates on the smoke run.
"""
from __future__ import annotations

import argparse
import re
import sys
from bisect import bisect_left
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_OPS: Dict[str, Callable[[float, float], bool]] = {
    "<=": lambda v, t: v <= t,
    ">=": lambda v, t: v >= t,
    "<": lambda v, t: v < t,
    ">": lambda v, t: v > t,
    "==": lambda v, t: v == t,
    "!=": lambda v, t: v != t,
}

_RULE_RE = re.compile(
    r"^\s*(?:(?P<name>[\w.-]+)\s*:)?\s*"
    r"(?P<metric>[\w.]+)\s*"
    r"(?P<op><=|>=|==|!=|<|>)\s*"
    r"(?P<threshold>[-+0-9.eE]+)\s*"
    r"(?:burn\s+(?P<short>[0-9.]+)s\s*/\s*(?P<long>[0-9.]+)s"
    r"(?:\s*x(?P<factor>[0-9.]+))?)?\s*$")


@dataclass(frozen=True)
class SLORule:
    name: str
    metric: str
    op: str                       # one of _OPS
    threshold: float
    burn_short_s: Optional[float] = None   # None -> point check
    burn_long_s: Optional[float] = None
    burn_factor: float = 1.0

    @property
    def is_burn(self) -> bool:
        return self.burn_short_s is not None

    def check(self, value: float) -> bool:
        """Point check: True when the SLO holds."""
        return _OPS[self.op](value, self.threshold)

    def describe(self) -> str:
        s = f"{self.name}: {self.metric} {self.op} {self.threshold:g}"
        if self.is_burn:
            s += (f" burn {self.burn_short_s:g}s/{self.burn_long_s:g}s"
                  f" x{self.burn_factor:g}")
        return s


def parse_rule(line: str) -> SLORule:
    m = _RULE_RE.match(line)
    if not m:
        raise ValueError(f"unparseable SLO rule: {line!r}")
    g = m.groupdict()
    short = float(g["short"]) if g["short"] else None
    long_ = float(g["long"]) if g["long"] else None
    if (short is None) != (long_ is None):
        raise ValueError(f"burn clause needs both windows: {line!r}")
    if short is not None and short >= long_:
        raise ValueError(
            f"burn short window must be < long window: {line!r}")
    return SLORule(name=g["name"] or g["metric"], metric=g["metric"],
                   op=g["op"], threshold=float(g["threshold"]),
                   burn_short_s=short, burn_long_s=long_,
                   burn_factor=float(g["factor"]) if g["factor"] else 1.0)


def parse_rules(text_or_lines) -> List[SLORule]:
    """Parse a rules file body or an iterable of rule strings;
    blank lines and ``#`` comments are skipped."""
    if isinstance(text_or_lines, str):
        lines = text_or_lines.splitlines()
    else:
        lines = list(text_or_lines)
    rules = []
    for ln in lines:
        ln = ln.split("#", 1)[0].strip()
        if ln:
            rules.append(parse_rule(ln))
    return rules


@dataclass
class Verdict:
    rule: SLORule
    ok: bool
    value: float
    detail: str = ""

    def line(self) -> str:
        mark = "OK   " if self.ok else "BREACH"
        out = f"[{mark}] {self.rule.describe()}  (value={self.value:g}"
        if self.detail:
            out += f"; {self.detail}"
        return out + ")"


@dataclass
class _Series:
    """Cumulative (ts, bad, total) snapshots for one burn-rate rule."""
    points: deque = field(default_factory=lambda: deque(maxlen=4096))

    def observe(self, ts: float, bad: float, total: float) -> None:
        # cumulative, so each point must be >= its predecessor
        if self.points:
            pt, pb, ptot = self.points[-1]
            bad = max(bad, pb)
            total = max(total, ptot)
        self.points.append((ts, bad, total))

    def rate_over(self, now: float, window_s: float) -> Optional[float]:
        """Bad fraction over [now - window_s, now]; None until the
        window has at least two snapshots to difference."""
        if len(self.points) < 2:
            return None
        ts = [p[0] for p in self.points]
        i = bisect_left(ts, now - window_s)
        i = min(i, len(self.points) - 2)
        t0, bad0, tot0 = self.points[i]
        t1, bad1, tot1 = self.points[-1]
        if t1 <= t0:
            return None
        dtot = tot1 - tot0
        if dtot <= 0:
            return 0.0
        return (bad1 - bad0) / dtot


class SLOEvaluator:
    """Evaluates a rule set against metric snapshots.

    Point rules read the latest value.  Burn-rate rules additionally
    need ``observe(now, metrics, totals)`` calls over time so the
    evaluator can difference cumulative bad/total counts per window.
    For a ``*_rate`` metric the evaluator derives bad/total from the
    companion cumulative counters when provided via ``totals`` —
    e.g. ``{"shed_rate": (shed_count, planned_count)}``.
    """

    def __init__(self, rules: Sequence[SLORule]):
        self.rules = list(rules)
        self._series: Dict[str, _Series] = {
            r.name: _Series() for r in self.rules if r.is_burn}

    def observe(self, now: float, metrics: Dict[str, float],
                totals: Optional[Dict[str, Tuple[float, float]]] = None
                ) -> None:
        """Feed a snapshot: current metric values plus, for burn-rate
        ratio rules, cumulative (bad, total) counter pairs."""
        totals = totals or {}
        for r in self.rules:
            if not r.is_burn:
                continue
            if r.metric in totals:
                bad, total = totals[r.metric]
            else:
                # point metric: treat the value itself as the "bad"
                # accumulation against a unit-rate total
                v = metrics.get(r.metric, 0.0)
                prev = self._series[r.name].points
                n = (prev[-1][2] + 1.0) if prev else 1.0
                bad, total = (prev[-1][1] + v if prev else v), n
            self._series[r.name].observe(now, bad, total)

    def evaluate(self, metrics: Dict[str, float],
                 now: Optional[float] = None) -> List[Verdict]:
        verdicts = []
        for r in self.rules:
            value = metrics.get(r.metric, 0.0)
            if not r.is_burn:
                verdicts.append(Verdict(r, r.check(value), value))
                continue
            series = self._series[r.name]
            t = now if now is not None else (
                series.points[-1][0] if series.points else 0.0)
            short = series.rate_over(t, r.burn_short_s)
            long_ = series.rate_over(t, r.burn_long_s)
            limit = r.burn_factor * r.threshold
            if short is None or long_ is None:
                # not enough history: fall back to the point check
                verdicts.append(Verdict(r, r.check(value), value,
                                        "insufficient history"))
                continue
            breach = short > limit and long_ > limit
            verdicts.append(Verdict(
                r, not breach, value,
                f"burn short={short:g} long={long_:g} limit={limit:g}"))
        return verdicts


def evaluate_rules(rules: Sequence[SLORule],
                   metrics: Dict[str, float]) -> List[Verdict]:
    """One-shot point evaluation (the CLI path)."""
    return SLOEvaluator(rules).evaluate(metrics)


# ----------------------------------------------------------------------
# CLI: python -m repro.obs.slo --metrics results/metrics.prom \
#          --rule "route_step_compiles == 0" --rule "shed_rate <= 0.0"
# ----------------------------------------------------------------------
def main(argv=None) -> int:
    from .export import metrics_from_prom

    p = argparse.ArgumentParser(
        description="Evaluate SLO rules against a Prometheus text dump")
    p.add_argument("--metrics", required=True,
                   help="path to a metrics.prom exposition dump")
    p.add_argument("--rule", action="append", default=[],
                   help="inline rule (repeatable)")
    p.add_argument("--rules-file", default=None,
                   help="file with one rule per line")
    args = p.parse_args(argv)

    lines = list(args.rule)
    if args.rules_file:
        with open(args.rules_file) as f:
            lines.extend(f.read().splitlines())
    rules = parse_rules(lines)
    if not rules:
        print("no SLO rules given", file=sys.stderr)
        return 2

    with open(args.metrics) as f:
        metrics = metrics_from_prom(f.read())

    verdicts = evaluate_rules(rules, metrics)
    bad = 0
    for v in verdicts:
        print(v.line())
        bad += not v.ok
    print(f"{len(verdicts) - bad}/{len(verdicts)} SLO rules hold")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
