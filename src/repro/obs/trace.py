"""Request tracing for the serving hot path.

A ``Tracer`` hands out nestable ``Span``s — named, timed records
carrying free-form attributes (request/tenant/batch ids, fallback
kinds, cache outcomes, admission verdicts, bucket shapes, ...) and a
``trace_id``/``span_id``/``parent_id`` triple that links them into
per-request trees.  Nesting is automatic: entering a span (``with
tracer.span("route_step"): ...``) makes it the implicit parent of any
span opened inside it (contextvar-propagated, so it crosses layer
boundaries — ``ServingEngine.submit`` -> ``OptiRoute.route_all`` ->
``kernels.ops.route_step`` -> ``SemanticCache`` — without threading a
span argument through every call).

Batch work fans out: the serving engine runs analyze / route / admit
/ generate ONCE per batch under batch-level spans, then records one
retrospective child span per request (``record_span``) carrying that
request's amortized timings and per-request attributes, so every
``Response`` ends up with a trace id whose tree shows exactly the
stages that ran for it.

Finished spans land in a bounded ring (oldest evicted first) — the
tracer's memory is fixed no matter how long the serving process
lives.  ``export_jsonl`` writes one span per line (OTLP-style flat
records); ``summary_tree`` rebuilds the nested view for tests and
debugging.  A disabled tracer (``enabled=False``) returns a shared
no-op span from every call: the hot path pays one attribute check and
nothing else.
"""
from __future__ import annotations

import contextvars
import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional
from repro.analysis.sanitize import make_lock

# the implicit parent of the next span opened on this thread/context
_CURRENT: contextvars.ContextVar[Optional["Span"]] = \
    contextvars.ContextVar("repro_obs_current_span", default=None)


class Span:
    """One timed, attributed node of a trace tree.

    Context-manager entry makes it the implicit parent for nested
    spans; exit (or ``end()``) stamps the duration and records it into
    the tracer's ring.  ``set(**attrs)`` attaches attributes at any
    point before export.
    """
    __slots__ = ("tracer", "trace_id", "span_id", "parent_id", "name",
                 "attrs", "wall0", "t0", "duration_s", "_token", "_done")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 span_id: str, parent_id: str, attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = attrs
        self.wall0 = time.time()
        self.t0 = time.perf_counter()
        self.duration_s = 0.0
        self._token = None
        self._done = False

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self) -> "Span":
        if not self._done:
            self._done = True
            self.duration_s = time.perf_counter() - self.t0
            self.tracer._record(self)
        return self

    def __enter__(self) -> "Span":
        self._token = _CURRENT.set(self)
        return self

    def __exit__(self, *exc) -> bool:
        if self._token is not None:
            _CURRENT.reset(self._token)
            self._token = None
        self.end()
        return False

    def to_dict(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "name": self.name,
                "ts": self.wall0, "duration_s": self.duration_s,
                "attrs": self.attrs}


class _NoopSpan:
    """Shared do-nothing span: what a disabled tracer hands out.

    Stateless (safe to re-enter concurrently); every method is a
    cheap no-op so instrumented code needs no ``if enabled`` guards.
    """
    __slots__ = ()
    trace_id = ""
    span_id = ""
    parent_id = ""
    name = ""
    duration_s = 0.0

    @property
    def attrs(self):
        return {}

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def end(self) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Factory + bounded store of spans.

    * ``span(name, **attrs)``       — live span, implicit parent from
      the ambient context (enter it with ``with``);
    * ``start_trace(name, **attrs)``— live ROOT span (new trace id)
      regardless of ambient context;
    * ``record_span(name, parent=, duration_s=, **attrs)`` — already-
      finished span (the batch->request fan-out path); ``parent=None``
      roots a new trace;
    * ``export_jsonl(path)``        — one span per line;
    * ``summary_tree(trace_id)``    — nested dict view for tests.
    """

    def __init__(self, max_spans: int = 16384, *, enabled: bool = True):
        assert max_spans > 0, max_spans
        self.enabled = bool(enabled)
        self.max_spans = int(max_spans)
        self._spans: deque = deque(maxlen=self.max_spans)
        self._lock = make_lock("obs.tracer")
        self._ids = itertools.count(1)
        self.spans_total = 0            # monotonic; ring evicts, this doesn't

    # ------------------------------------------------------------------
    def _new_id(self) -> str:
        return f"{next(self._ids):012x}"      # count() is atomic in CPython

    def _record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)
            self.spans_total += 1

    # ------------------------------------------------------------------
    def span(self, name: str, **attrs):
        """A live child span of the ambient current span (or a root
        when none is active).  Enter it with ``with`` to both time it
        and make it the parent of nested spans."""
        if not self.enabled:
            return NOOP_SPAN
        parent = _CURRENT.get()
        sid = self._new_id()
        if parent is not None and parent.trace_id:
            return Span(self, name, parent.trace_id, sid,
                        parent.span_id, attrs)
        return Span(self, name, f"t{sid}", sid, "", attrs)

    def start_trace(self, name: str, **attrs):
        """A live ROOT span: always begins a fresh trace."""
        if not self.enabled:
            return NOOP_SPAN
        sid = self._new_id()
        return Span(self, name, f"t{sid}", sid, "", attrs)

    def record_span(self, name: str, *, parent=None,
                    duration_s: float = 0.0, **attrs):
        """Record an already-finished span (fan-out/retrospective).

        ``parent`` is a ``Span`` (or None to root a new trace); the
        span is stamped with ``duration_s`` and recorded immediately.
        """
        if not self.enabled:
            return NOOP_SPAN
        sid = self._new_id()
        if parent is not None and parent.trace_id:
            s = Span(self, name, parent.trace_id, sid, parent.span_id,
                     attrs)
        else:
            s = Span(self, name, f"t{sid}", sid, "", attrs)
        s.duration_s = float(duration_s)
        s._done = True
        self._record(s)
        return s

    def current(self):
        """The ambient span on this thread/context (or None)."""
        return _CURRENT.get()

    # ------------------------------------------------------------------
    # export & inspection
    # ------------------------------------------------------------------
    def spans(self, trace_id: Optional[str] = None) -> List[Span]:
        """Retained finished spans, oldest first (optionally one trace)."""
        with self._lock:
            out = list(self._spans)
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def export_jsonl(self, path, trace_id: Optional[str] = None) -> int:
        """Write retained spans as JSON-lines; returns the line count."""
        spans = self.spans(trace_id)
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict(), default=str) + "\n")
        return len(spans)

    def summary_tree(self, trace_id: str) -> Optional[Dict[str, Any]]:
        """Nested view of one trace: {name, attrs, duration_s,
        children: [...]} rooted at the trace's parentless span.
        Returns None when the trace has been evicted from the ring."""
        spans = self.spans(trace_id)
        if not spans:
            return None
        nodes = {s.span_id: {"name": s.name, "span_id": s.span_id,
                             "attrs": dict(s.attrs),
                             "duration_s": s.duration_s, "children": []}
                 for s in spans}
        root = None
        for s in spans:
            if s.parent_id and s.parent_id in nodes:
                nodes[s.parent_id]["children"].append(nodes[s.span_id])
            else:
                root = nodes[s.span_id]
        return root

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"spans_total": self.spans_total,
                    "spans_retained": len(self._spans),
                    "max_spans": self.max_spans}
