"""Device-side cost profiling for the fused route step.

``DeviceCostProfiler`` hooks into ``kernels.ops.route_step`` (via
``ops.set_cost_profiler``) and, the first time each (path, q-bucket,
n-bucket, quant) shape bucket is dispatched, lowers and compiles the
same jitted call to read XLA's ``compiled.cost_analysis()`` — static
FLOPs / bytes-accessed estimates per device program.  That is one
extra compile per *bucket* (not per dispatch) and only while a
profiler is attached, so the steady-state hot path is untouched; the
per-bucket numbers feed the ``repro_route_step_flops`` /
``repro_route_step_bytes`` gauges in the Prometheus export.

``trace_capture(dir)`` optionally wraps a region in a
``jax.profiler.trace`` so the fused dispatch shows up in a real
profiler timeline (TensorBoard-compatible); degrades to a no-op when
the profiler backend is unavailable.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional
from repro.analysis.sanitize import make_lock


def _extract_costs(analysis) -> Dict[str, Optional[float]]:
    """Normalize ``cost_analysis()`` output across JAX versions
    (dict, list-of-dict, or absent keys)."""
    if isinstance(analysis, (list, tuple)):
        analysis = analysis[0] if analysis else {}
    if not isinstance(analysis, dict):
        return {"flops": None, "bytes_accessed": None}
    return {"flops": analysis.get("flops"),
            "bytes_accessed": analysis.get("bytes accessed",
                                           analysis.get("bytes_accessed"))}


class DeviceCostProfiler:
    """Captures per-bucket XLA cost analysis for jitted dispatches.

    ``capture(bucket, jit_fn, call)`` is invoked by the ops layer with
    the already-bound call (a ``functools.partial``); unseen buckets
    are lowered+compiled once to read ``cost_analysis()``.  Thread-safe
    and failure-tolerant: a backend without cost analysis records
    ``None`` entries rather than raising into the hot path.
    """

    def __init__(self):
        self._lock = make_lock("obs.profiler")
        self._by_bucket: Dict[Any, Dict[str, Optional[float]]] = {}
        self.captures = 0
        self.errors = 0

    def capture(self, bucket, jit_fn, call) -> None:
        with self._lock:
            if bucket in self._by_bucket:
                return
            # reserve the slot so concurrent dispatchers of the same
            # bucket don't double-compile
            self._by_bucket[bucket] = {"flops": None,
                                       "bytes_accessed": None}
        try:
            lowered = jit_fn.lower(*call.args, **call.keywords)
            analysis = lowered.compile().cost_analysis()
            costs = _extract_costs(analysis)
        except Exception:                   # noqa: BLE001 - best effort
            with self._lock:
                self.errors += 1
            return
        with self._lock:
            self._by_bucket[bucket] = costs
            self.captures += 1

    def profile(self) -> Dict[str, Dict[str, Optional[float]]]:
        """Per-bucket costs keyed by a stable string form of the bucket."""
        with self._lock:
            return {"/".join(str(p) for p in k): dict(v)
                    for k, v in self._by_bucket.items()}


@contextlib.contextmanager
def trace_capture(trace_dir: Optional[str]):
    """``jax.profiler.trace`` around a region; no-op when ``trace_dir``
    is falsy or the profiler backend refuses to start."""
    if not trace_dir:
        yield
        return
    try:
        import jax
        ctx = jax.profiler.trace(str(trace_dir))
        ctx.__enter__()
    except Exception:                       # noqa: BLE001
        yield
        return
    try:
        yield
    finally:
        try:
            ctx.__exit__(None, None, None)
        except Exception:                   # noqa: BLE001 - profiler-only
            pass
