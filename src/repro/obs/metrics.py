"""Fixed-memory metric primitives: counters, gauges, log histograms.

The telemetry rewrite (PR 7) replaces "keep every event and re-quantile
the raw list under the lock" with these: a ``LogHistogram`` is a fixed
array of log-spaced buckets (HDR-histogram style) — O(1) record, O(1)
memory, mergeable, with quantile estimates whose relative error is
bounded by the bucket width.  At the default 128 buckets/octave the
bucket width is ``2**(1/128) - 1`` ~ 0.54%, comfortably inside the 1%
tolerances the telemetry tests assert.

Quantile estimation: cumulative counts + searchsorted for the target
rank, linear interpolation within the landing bucket, and the estimate
clamped to the observed ``[min, max]`` — which makes single-sample
quantiles *exact* (p50 == p99 == the sample) and keeps estimates from
drifting outside the data range at the edges.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, Tuple

import numpy as np
from repro.analysis.sanitize import make_lock


class Counter:
    """Monotonic counter with optional single-label children."""
    __slots__ = ("name", "help", "_vals", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._vals: Dict[str, float] = {}
        self._lock = make_lock("obs.metrics.counter")

    def inc(self, amount: float = 1.0, label: str = "") -> None:
        assert amount >= 0, amount
        with self._lock:
            self._vals[label] = self._vals.get(label, 0.0) + amount

    def value(self, label: str = "") -> float:
        with self._lock:
            return self._vals.get(label, 0.0)

    def items(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._vals)


class Gauge:
    """Point-in-time value (load depth, qps, ...)."""
    __slots__ = ("name", "help", "_vals", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._vals: Dict[str, float] = {}
        self._lock = make_lock("obs.metrics.gauge")

    def set(self, value: float, label: str = "") -> None:
        with self._lock:
            self._vals[label] = float(value)

    def value(self, label: str = "") -> float:
        with self._lock:
            return self._vals.get(label, 0.0)

    def items(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._vals)


class LogHistogram:
    """Log-spaced fixed-bucket histogram over ``(0, inf)`` values.

    Buckets cover ``[lo, hi)`` at ``per_octave`` buckets per factor-of-2;
    values below ``lo`` land in the underflow bucket (index 0), values
    at/above ``hi`` in the overflow bucket (index -1).  Records are two
    integer ops and an array increment — no allocation, no sort.

    NOT internally locked: the owner (Telemetry) already serializes
    writers; standalone users should wrap access in their own lock.
    """
    __slots__ = ("lo", "hi", "per_octave", "_inv_ln2", "nbuckets",
                 "counts", "count", "total", "vmin", "vmax", "_edges")

    def __init__(self, lo: float = 1e-5, hi: float = 1e2,
                 per_octave: int = 128):
        assert 0 < lo < hi and per_octave > 0
        self.lo = float(lo)
        self.hi = float(hi)
        self.per_octave = int(per_octave)
        self._inv_ln2 = per_octave / math.log(2.0)
        n_core = int(math.ceil(math.log(hi / lo, 2.0) * per_octave))
        self.nbuckets = n_core + 2              # + underflow + overflow
        self.counts = np.zeros(self.nbuckets, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        # geometric bucket edges; edge[i] is the lower bound of core
        # bucket i (used for interpolation at quantile time)
        self._edges = lo * np.exp2(np.arange(n_core + 1) / per_octave)

    def _index(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self.nbuckets - 1
        return 1 + int(math.log(v / self.lo) * self._inv_ln2)

    def record(self, v: float) -> None:
        v = float(v)
        if v <= 0.0:
            # zero/negative durations: count them against the
            # underflow bucket so quantiles stay mass-consistent
            self.counts[0] += 1
        else:
            self.counts[min(self._index(v), self.nbuckets - 1)] += 1
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
        self.count += 1
        self.total += max(v, 0.0)

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        assert (self.lo, self.hi, self.per_octave) == \
               (other.lo, other.hi, other.per_octave), "bucket mismatch"
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile; 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        if not math.isfinite(self.vmin):        # only non-positive values
            return 0.0
        cum = np.cumsum(self.counts)
        target = q * self.count
        idx = int(np.searchsorted(cum, target, side="left"))
        idx = min(idx, self.nbuckets - 1)
        if idx == 0 or idx == self.nbuckets - 1:
            # under/overflow bucket: best estimate is the clamp below
            est = self.vmin if idx == 0 else self.vmax
        else:
            lo_e = self._edges[idx - 1]
            hi_e = self._edges[idx]
            prev = cum[idx - 1]
            inbucket = self.counts[idx]
            frac = (target - prev) / inbucket if inbucket else 0.0
            est = lo_e + (hi_e - lo_e) * min(max(frac, 0.0), 1.0)
        # clamping to the observed range makes single-sample quantiles
        # exact and pins estimates inside the data
        return float(min(max(est, self.vmin), self.vmax))

    def quantiles(self, qs: Iterable[float]) -> Tuple[float, ...]:
        return tuple(self.quantile(q) for q in qs)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if math.isfinite(self.vmax) else 0.0}
