"""Pytree checkpointing (npz, no external deps).

Flattens any nested dict/list pytree of arrays into ``path -> array``
entries, saves with np.savez_compressed, restores with exact structure
(structure comes from a reference pytree or is rebuilt from the paths).
Atomic writes (tmp + rename) so an interrupted save never corrupts the
latest checkpoint.  Step-numbered with a retention policy.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{SEP}#{i}" if prefix else f"#{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict = {}
    for path, arr in flat.items():
        keys = path.split(SEP)
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = arr

    def finish(node):
        if not isinstance(node, dict):
            return node
        if node and all(re.fullmatch(r"#\d+", k) for k in node):
            return [finish(node[f"#{i}"]) for i in range(len(node))]
        return {k: finish(v) for k, v in node.items()}

    return finish(root)


def save(path: str, tree: Any, metadata: Optional[Dict] = None) -> None:
    """Atomic save of a pytree (+ json metadata) to ``path`` (.npz)."""
    flat = _flatten(jax.device_get(tree))
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=p.parent, suffix=".tmp")
    os.close(fd)
    try:
        np.savez_compressed(tmp, __meta__=json.dumps(metadata or {}), **flat)
        os.replace(tmp if tmp.endswith(".npz") else tmp + ".npz"
                   if os.path.exists(tmp + ".npz") else tmp, p)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)


def load(path: str) -> Tuple[Any, Dict]:
    """Returns (pytree, metadata)."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {k: z[k] for k in z.files if k != "__meta__"}
    return _unflatten(flat), meta


class CheckpointManager:
    """Step-numbered checkpoints with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.keep = keep
        self.dir.mkdir(parents=True, exist_ok=True)

    def _path(self, step: int) -> pathlib.Path:
        return self.dir / f"ckpt_{step:08d}.npz"

    def steps(self) -> List[int]:
        return sorted(int(m.group(1)) for f in self.dir.glob("ckpt_*.npz")
                      if (m := re.match(r"ckpt_(\d+)\.npz", f.name)))

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        save(str(self._path(step)), tree, {**(metadata or {}), "step": step})
        for old in self.steps()[: -self.keep]:
            self._path(old).unlink(missing_ok=True)

    def restore_latest(self) -> Optional[Tuple[int, Any, Dict]]:
        steps = self.steps()
        if not steps:
            return None
        tree, meta = load(str(self._path(steps[-1])))
        return steps[-1], tree, meta
