"""Pytree checkpointing (npz) + durable router state."""
from repro.checkpoint.router_state import (RouterState,  # noqa: F401
                                           load_router_state,
                                           save_router_state)
from repro.checkpoint.store import CheckpointManager, load, save  # noqa: F401
