"""Pytree checkpointing (npz)."""
from repro.checkpoint.store import CheckpointManager, load, save  # noqa: F401
