"""Durable router state: everything the router LEARNS survives restart.

A serving deployment accumulates routing knowledge that is expensive to
re-learn from live traffic: the adaptive bandit's sufficient statistics
(``repro.adaptive.LinearBandit``), the thumbs-feedback biases
(``FeedbackStore``), the load tracker's service-time EWMAs and
capacities (``LoadTracker``), and the semantic cache's validated
responses (``repro.cache.SemanticCache``).  All of it evaporates on
process death unless snapshotted — a restarted engine then routes cold
for thousands of requests.

``RouterState`` captures every attached component of an ``OptiRoute``
into one pytree + JSON-metadata pair and persists it through the
existing npz checkpoint store: atomic (tmp + rename — a crash mid-save
never corrupts the latest snapshot), step-versioned with retention
(``CheckpointManager``), and bit-exact (restore reproduces identical
``route_many`` decisions).  Components the router does not carry are
simply skipped; restoring a snapshot into a router that LACKS a
component the snapshot carries raises (a silent partial restore would
masquerade as warm).

    state = RouterState(directory)
    state.save(router, step=120)          # cadence chosen by the caller
    ...
    router2 = build_router(...)           # fresh process
    state.restore(router2)                # resumes warm

``save_router_state`` / ``load_router_state`` are the single-file
variants for callers managing their own paths.
"""
from __future__ import annotations

import pathlib
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.checkpoint.store import CheckpointManager, load, save

STATE_VERSION = 1


# ----------------------------------------------------------------------
# capture / apply
# ----------------------------------------------------------------------

def _cache_tree(cache) -> Tuple[Dict, Dict]:
    st = cache.state()
    resp: Dict[str, np.ndarray] = {}
    for i, r in enumerate(st["responses"]):
        if r is None:
            continue
        a = np.asarray(r)
        if a.dtype == object:
            raise TypeError("RouterState persists array (or None) cache "
                            f"responses; slot {i} holds {type(r)}")
        resp[str(i)] = a
    tree = {"vecs": st["vecs"], "fps": st["fps"],
            "quality": st["quality"], "created": st["created"],
            "last_used": st["last_used"], "valid": st["valid"],
            "responses": resp}
    meta = {"tick": int(st["tick"]), "models": st["models"],
            "sigs": st["sigs"]}
    return tree, meta


def _cache_state(tree: Dict, meta: Dict) -> Dict:
    C = int(np.asarray(tree["valid"]).shape[0])
    responses: list = [None] * C
    for k, a in (tree.get("responses") or {}).items():
        responses[int(k)] = np.asarray(a)
    return {"vecs": tree["vecs"], "fps": tree["fps"],
            "quality": tree["quality"], "created": tree["created"],
            "last_used": tree["last_used"],
            "valid": np.asarray(tree["valid"], bool),
            "tick": int(meta["tick"]), "models": list(meta["models"]),
            "responses": responses,
            "sigs": [None if s is None else tuple(s)
                     for s in meta["sigs"]]}


def capture(router) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """One consistent (pytree, metadata) snapshot of every learned
    component the ``OptiRoute`` carries."""
    tree: Dict[str, Any] = {}
    meta: Dict[str, Any] = {"router_state_version": STATE_VERSION,
                            "components": []}
    bandit = getattr(router, "adaptive", None)
    if bandit is not None:
        tree["bandit"] = bandit.state()
        meta["components"].append("bandit")
    fb = getattr(router, "feedback", None)
    if fb is not None:
        entries = fb.state()
        tree["feedback"] = {
            # float64: the store keeps python floats, and the restore
            # must reproduce them (and the routing scores) bit-exactly
            "bias": np.array([e["bias"] for e in entries], np.float64),
            "count": np.array([e["count"] for e in entries], np.int64),
        }
        meta["feedback_keys"] = [[*e["cluster"], e["model"]]
                                 for e in entries]
        meta["components"].append("feedback")
    tracker = getattr(router, "load", None)
    if tracker is not None:
        tree["load"] = tracker.state()
        meta["components"].append("load")
    cache = getattr(router, "cache", None)
    if cache is not None:
        tree["cache"], meta["cache"] = _cache_tree(cache)
        meta["components"].append("cache")
    return tree, meta


def apply(router, tree: Dict[str, Any], meta: Dict[str, Any]) -> None:
    """Restore a ``capture`` snapshot into ``router``, replacing the
    live state of every captured component."""
    version = meta.get("router_state_version")
    if version != STATE_VERSION:
        raise ValueError(f"router state version {version!r} != "
                         f"{STATE_VERSION}")
    for comp in meta["components"]:
        target = getattr(router, comp if comp != "bandit" else "adaptive",
                         None)
        if target is None:
            raise ValueError(f"snapshot carries {comp!r} but the router "
                             "has no such component attached")
    if "bandit" in meta["components"]:
        router.adaptive.load_state(tree["bandit"])
    if "feedback" in meta["components"]:
        fbt = tree["feedback"]
        bias = np.atleast_1d(np.asarray(fbt["bias"]))
        count = np.atleast_1d(np.asarray(fbt["count"]))
        router.feedback.load_state([
            {"cluster": [k[0], k[1], int(k[2])], "model": k[3],
             "bias": float(b), "count": int(c)}
            for k, b, c in zip(meta["feedback_keys"], bias, count)])
    if "load" in meta["components"]:
        router.load.load_state(tree["load"])
    if "cache" in meta["components"]:
        router.cache.load_state(_cache_state(tree["cache"], meta["cache"]))


# ----------------------------------------------------------------------
# single-file + step-versioned persistence
# ----------------------------------------------------------------------

def save_router_state(path: str, router) -> None:
    """Atomic single-file snapshot (npz, tmp + rename)."""
    tree, meta = capture(router)
    save(path, tree, meta)


def load_router_state(path: str, router) -> Dict[str, Any]:
    """Restore a ``save_router_state`` snapshot; returns its metadata."""
    tree, meta = load(path)
    apply(router, _none_empty(tree), meta)
    return meta


def _none_empty(tree) -> Dict[str, Any]:
    # an all-empty component (e.g. feedback with zero entries) can
    # flatten to nothing; normalize to dicts the apply path expects
    return tree if isinstance(tree, dict) else {}


class RouterState:
    """Step-versioned durable router state with retention, built on the
    same atomic ``CheckpointManager`` the training loop uses."""

    def __init__(self, directory: str, keep: int = 3):
        self.mgr = CheckpointManager(directory, keep=keep)

    def save(self, router, step: int) -> pathlib.Path:
        tree, meta = capture(router)
        self.mgr.save(step, tree, meta)
        return self.mgr._path(step)

    def restore(self, router) -> Optional[int]:
        """Restore the latest snapshot; returns its step, or None when
        the directory holds no snapshots (a cold start)."""
        latest = self.mgr.restore_latest()
        if latest is None:
            return None
        step, tree, meta = latest
        apply(router, _none_empty(tree), meta)
        return step
