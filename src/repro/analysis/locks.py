"""Lock-discipline rules (`lock-mixed-mutation`, `lock-unlocked-read`).

For every class that creates a lock attribute in ``__init__`` (any
``self.<name> = threading.Lock()/RLock()/Condition()/make_lock(...)``),
the rule classifies each attribute access in each method as
locked/unlocked and read/mutation:

* an attribute is **guarded** if at least one mutation of it happens
  while the lock is held;
* ``lock-mixed-mutation`` — a guarded attribute is also mutated while
  the lock is NOT held (classic torn write / lost update);
* ``lock-unlocked-read`` — a *public* method reads two or more distinct
  guarded attributes without taking the lock (torn multi-field read;
  one guarded field read alone is an atomic-enough snapshot under the
  GIL, so the threshold is >= 2 distinct attributes).

Repo idioms the rule understands:

* methods whose name ends in ``_locked`` are called with the lock held
  (the codebase's documented convention) — their whole body counts as
  locked;
* ``__init__`` is pre-publication (no other thread can hold ``self``
  yet) and is excluded entirely;
* mutator *method calls* on guarded containers count as mutations
  (``self.queue.append(x)``, ``self._stats.setdefault(...)``, ...), as
  do item/attr stores (``self.d[k] = v``, ``del self.d[k]``).

A module-level variant applies the mixed-mutation rule to module
globals guarded by a module-level ``*_LOCK`` (the ``kernels/ops.py``
``_STATS`` pattern): any global mutated somewhere under ``with
<LOCK>:`` must not also be mutated outside it.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.engine import Source
from repro.analysis.findings import Finding

# container methods that mutate their receiver in place
MUTATORS = {
    "append", "appendleft", "extend", "extendleft", "insert", "remove",
    "pop", "popleft", "popitem", "clear", "update", "setdefault", "add",
    "discard", "sort", "reverse", "rotate",
}

_LOCK_FACTORY_NAMES = {"Lock", "RLock", "Condition", "make_lock"}


def _is_lock_ctor(value: ast.AST) -> bool:
    """True for `threading.Lock()`, `RLock()`, `make_lock("x")`, ..."""
    if not isinstance(value, ast.Call):
        return False
    func = value.func
    if isinstance(func, ast.Name):
        return func.id in _LOCK_FACTORY_NAMES
    if isinstance(func, ast.Attribute):
        return func.attr in _LOCK_FACTORY_NAMES
    return False


@dataclass
class _Event:
    attr: str
    line: int
    col: int
    locked: bool
    mutation: bool
    method: str


class _MethodScanner(ast.NodeVisitor):
    """Walks one method body, tracking whether the class lock is held.

    Nested defs/lambdas inherit the current lock state: a closure built
    under the lock but called later is rare enough that the cheap
    approximation wins.
    """

    def __init__(self, lock_attrs: Set[str], method: str,
                 start_locked: bool) -> None:
        self.lock_attrs = lock_attrs
        self.method = method
        self.locked = start_locked
        self.events: List[_Event] = []
        self._skip: Set[int] = set()   # id() of self-attr nodes already
        #                                counted as part of a mutation

    # -- helpers ------------------------------------------------------

    def _self_attr(self, node: ast.AST) -> Optional[ast.Attribute]:
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node
        return None

    def _emit(self, node: ast.Attribute, mutation: bool) -> None:
        if node.attr in self.lock_attrs:
            return
        self.events.append(_Event(node.attr, node.lineno, node.col_offset + 1,
                                  self.locked, mutation, self.method))

    def _mutation_target(self, target: ast.AST) -> None:
        """Record mutations implied by an assignment/delete target."""
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._mutation_target(elt)
            return
        sa = self._self_attr(target)
        if sa is not None:                       # self.x = ...
            self._emit(sa, mutation=True)
            self._skip.add(id(sa))
            return
        if isinstance(target, (ast.Subscript, ast.Attribute)):
            base = target.value                  # self.d[k] = / self.d.f =
            sa = self._self_attr(base)
            if sa is not None:
                self._emit(sa, mutation=True)
                self._skip.add(id(sa))

    # -- visitors -----------------------------------------------------

    def visit_With(self, node: ast.With) -> None:
        self._visit_with(node)

    def visit_AsyncWith(self, node: ast.AsyncWith) -> None:
        self._visit_with(node)

    def _visit_with(self, node) -> None:
        acquires = False
        for item in node.items:
            sa = self._self_attr(item.context_expr)
            if sa is not None and sa.attr in self.lock_attrs:
                acquires = True
            else:
                self.visit(item.context_expr)
        if acquires and not self.locked:
            self.locked = True
            for st in node.body:
                self.visit(st)
            self.locked = False
        else:
            for st in node.body:
                self.visit(st)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._mutation_target(t)
        self.visit(node.value)
        for t in node.targets:
            self.generic_visit(t)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._mutation_target(node.target)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._mutation_target(node.target)
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._mutation_target(t)
            self.generic_visit(t)

    def visit_Call(self, node: ast.Call) -> None:
        # self.<attr>.<mutator>(...) is a mutation of <attr>
        if isinstance(node.func, ast.Attribute) and node.func.attr in MUTATORS:
            sa = self._self_attr(node.func.value)
            if sa is not None:
                self._emit(sa, mutation=True)
                self._skip.add(id(sa))
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        sa = self._self_attr(node)
        if (sa is not None and isinstance(node.ctx, ast.Load)
                and id(node) not in self._skip):
            self._emit(sa, mutation=False)
        self.generic_visit(node)


def _method_names(node) -> Tuple[str, bool]:
    """(name, starts_locked) for a method definition."""
    return node.name, node.name.endswith("_locked")


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _scan_class(cls: ast.ClassDef, src: Source) -> Iterable[Finding]:
    # 1) find lock attributes created anywhere in the class body
    lock_attrs: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and _is_lock_ctor(node.value):
            for t in node.targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    lock_attrs.add(t.attr)
    if not lock_attrs:
        return []

    # 2) per-method event streams
    methods: List[Tuple[str, List[_Event], object]] = []
    for item in cls.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        name, starts_locked = _method_names(item)
        if name == "__init__":
            continue                 # pre-publication, single-threaded
        scan = _MethodScanner(lock_attrs, name, starts_locked)
        for st in item.body:
            scan.visit(st)
        methods.append((name, scan.events, item))

    # 3) guarded attributes: mutated under the lock at least once
    guarded: Set[str] = set()
    for _, events, _node in methods:
        for ev in events:
            if ev.mutation and ev.locked:
                guarded.add(ev.attr)

    findings: List[Finding] = []

    # 4) mixed mutation: guarded attr mutated while unlocked
    mixed_by_method: Dict[str, Set[str]] = {}
    for name, events, _node in methods:
        seen: Set[str] = set()
        for ev in events:
            if ev.mutation and not ev.locked and ev.attr in guarded \
                    and ev.attr not in seen:
                seen.add(ev.attr)
                findings.append(Finding(
                    rule="lock-mixed-mutation", path=src.rel,
                    line=ev.line, col=ev.col,
                    symbol=f"{cls.name}.{name}",
                    message=(f"self.{ev.attr} is mutated here without the "
                             f"lock but is lock-guarded elsewhere in "
                             f"{cls.name}")))
        mixed_by_method[name] = seen

    # 5) torn reads: public method reads >= 2 distinct guarded attrs
    #    while unlocked (attrs already flagged as mixed mutations in the
    #    same method are not double-reported)
    for name, events, node in methods:
        if not _is_public(name):
            continue
        read_attrs: Dict[str, _Event] = {}
        for ev in events:
            if (not ev.mutation and not ev.locked and ev.attr in guarded
                    and ev.attr not in mixed_by_method.get(name, ())):
                read_attrs.setdefault(ev.attr, ev)
        if len(read_attrs) >= 2:
            attrs = ", ".join(sorted(read_attrs))
            first = min(read_attrs.values(), key=lambda e: (e.line, e.col))
            findings.append(Finding(
                rule="lock-unlocked-read", path=src.rel,
                line=first.line, col=first.col,
                symbol=f"{cls.name}.{name}",
                message=(f"reads lock-guarded attributes ({attrs}) without "
                         f"holding the lock — multi-field state may be "
                         f"observed torn")))
    return findings


# --------------------------------------------------------------------
# module-level variant (the ops.py `_STATS` / `_STATS_LOCK` pattern)
# --------------------------------------------------------------------

class _ModuleFnScanner(ast.NodeVisitor):
    def __init__(self, lock_names: Set[str], global_names: Set[str],
                 fn_name: str) -> None:
        self.lock_names = lock_names
        self.global_names = global_names
        self.fn = fn_name
        self.locked = False
        # (name, line, col, locked) — mutations only
        self.mutations: List[Tuple[str, int, int, bool]] = []
        self._declared_global: Set[str] = set()

    def _name_of(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name) and node.id in self.global_names:
            return node.id
        return None

    def visit_Global(self, node: ast.Global) -> None:
        self._declared_global.update(node.names)

    def visit_With(self, node: ast.With) -> None:
        acquires = any(isinstance(i.context_expr, ast.Name)
                       and i.context_expr.id in self.lock_names
                       for i in node.items)
        if acquires and not self.locked:
            self.locked = True
            for st in node.body:
                self.visit(st)
            self.locked = False
        else:
            self.generic_visit(node)

    def _mut(self, name: str, node: ast.AST) -> None:
        self.mutations.append(
            (name, node.lineno, node.col_offset + 1, self.locked))

    def _mutation_target(self, target: ast.AST) -> None:
        if isinstance(target, (ast.Subscript, ast.Attribute)):
            name = self._name_of(target.value)
            if name:
                self._mut(name, target)
        elif isinstance(target, ast.Name):
            # rebinding a module global from inside a function requires
            # a `global` declaration; only then is it a shared mutation
            if target.id in self._declared_global \
                    and target.id in self.global_names:
                self._mut(target.id, target)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._mutation_target(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._mutation_target(node.target)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._mutation_target(t)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute) and node.func.attr in MUTATORS:
            name = self._name_of(node.func.value)
            if name:
                self._mut(name, node.func)
        self.generic_visit(node)


def _scan_module_globals(src: Source) -> Iterable[Finding]:
    tree = src.tree
    lock_names: Set[str] = set()
    global_names: Set[str] = set()
    for node in tree.body:                       # type: ignore[attr-defined]
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    if _is_lock_ctor(node.value):
                        lock_names.add(t.id)
                    else:
                        global_names.add(t.id)
    if not lock_names:
        return []

    scans: List[_ModuleFnScanner] = []
    for node in tree.body:                       # type: ignore[attr-defined]
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            sc = _ModuleFnScanner(lock_names, global_names, node.name)
            for st in node.body:
                sc.visit(st)
            scans.append(sc)

    guarded = {name for sc in scans
               for (name, _l, _c, locked) in sc.mutations if locked}
    findings: List[Finding] = []
    for sc in scans:
        seen: Set[str] = set()
        for name, line, col, locked in sc.mutations:
            if not locked and name in guarded and name not in seen:
                seen.add(name)
                findings.append(Finding(
                    rule="lock-mixed-mutation", path=src.rel,
                    line=line, col=col, symbol=sc.fn,
                    message=(f"module global {name} is mutated here without "
                             f"its lock but is lock-guarded elsewhere")))
    return findings


def check_lock_discipline(src: Source) -> Iterable[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_scan_class(node, src))
    findings.extend(_scan_module_globals(src))
    return findings
