"""repro.analysis — repo-specific static analysis + runtime sanitizers.

The serving stack's load-bearing guarantees (single-dispatch routing,
zero steady-state recompiles, lock-guarded shared state, every Pallas
kernel pinned to a ``ref.py`` oracle) are enforced by convention in
review.  This package makes them *mechanical*:

* ``repro.analysis.lint`` — an AST lint engine with three repo-specific
  rule families, run as ``python -m repro.analysis.lint src/repro`` and
  gated in CI against a checked-in baseline (``analysis/baseline.json``)
  so the gate starts green and ratchets: NEW violations fail, existing
  ones are triaged or suppressed inline
  (``# lint: ignore[rule] -- reason``).

    - lock discipline   (``repro.analysis.locks``)
    - jit / recompile hazards  (``repro.analysis.jit_hazards``)
    - kernel-oracle conformance  (``repro.analysis.kernel_oracle``)

* ``repro.analysis.sanitize`` — opt-in runtime sanitizers activated by
  ``REPRO_SANITIZE=1``: an instrumented lock wrapper that builds a
  global lock-order graph with cycle detection (potential-deadlock
  detector), and a recompile sentinel that fails any test re-compiling
  a route-step shape bucket the session already warmed.  Wired into
  ``tests/conftest.py`` together with JAX's ``transfer_guard`` /
  ``checking_leaks`` debug machinery.

Import cost: this package is a leaf — nothing here imports jax or the
serving stack at module scope, so the hot path's ``make_lock`` calls
stay cheap and cycle-free.
"""
