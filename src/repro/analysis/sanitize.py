"""Opt-in runtime sanitizers (``REPRO_SANITIZE=1``).

**Lock-order sanitizer.**  Every lock-bearing module in the stack
creates its locks through :func:`make_lock`.  With sanitizing off
(default) that returns a plain ``threading.Lock`` — zero overhead, the
env var is read once at lock creation.  With ``REPRO_SANITIZE=1`` it
returns an :class:`OrderedLock` that maintains a per-thread stack of
held locks and a global *lock-order graph*: acquiring ``B`` while
holding ``A`` records the edge ``A → B``.  The first acquisition that
would close a cycle (some thread previously took ``B`` before ``A``)
raises :class:`LockOrderError` at the acquire site — the classic ABBA
deadlock caught deterministically, on the first inverted acquisition,
without needing the unlucky interleaving.

Edges are keyed by lock *name* (role), not instance, so the graph is
meaningful across engine instances; same-name self-edges (two
instances of the same component locked nested, e.g. two tenants'
micro-batchers) are skipped — ordering within a role needs an
instance-level protocol the name graph can't see.

**Recompile sentinel.**  ``kernels.ops.route_step`` reports every
dispatch through :func:`repro.kernels.ops.set_recompile_hook` with its
shape-bucket signature ``(path, q_bucket, n_bucket, quant, shards)``
and the jit cache-miss delta.  The first compile per signature is
warmup; a compile for a signature the sentinel has *already seen
compiled* means the zero-steady-state-recompile guarantee regressed.
``tests/conftest.py`` installs one sentinel per session under
``REPRO_SANITIZE=1`` and fails any test that trips it.
"""
from __future__ import annotations

import os
import threading
from typing import Callable, Dict, List, Optional, Set, Tuple


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() in (
        "1", "true", "on", "yes")


class LockOrderError(RuntimeError):
    """Acquiring this lock would close a cycle in the lock-order graph
    (potential ABBA deadlock)."""


# ---- global lock-order graph ----------------------------------------

_GRAPH_MU = threading.Lock()            # guards _EDGES/_VIOLATIONS
_EDGES: Dict[str, Set[str]] = {}        # name -> names acquired after it
# (edge_src, edge_dst, cycle_path) for every refused acquisition
_VIOLATIONS: List[Tuple[str, str, Tuple[str, ...]]] = []
_HELD = threading.local()               # .stack: per-thread held names


def _held_stack() -> List[str]:
    st = getattr(_HELD, "stack", None)
    if st is None:
        st = _HELD.stack = []
    return st


def _find_path(src: str, dst: str) -> Optional[Tuple[str, ...]]:
    """DFS path src -> dst through _EDGES (caller holds _GRAPH_MU)."""
    stack = [(src, (src,))]
    seen = {src}
    while stack:
        node, path = stack.pop()
        for nxt in _EDGES.get(node, ()):
            if nxt == dst:
                return path + (dst,)
            if nxt not in seen:
                seen.add(nxt)
                stack.append((nxt, path + (nxt,)))
    return None


def lock_order_graph() -> Dict[str, Set[str]]:
    with _GRAPH_MU:
        return {k: set(v) for k, v in _EDGES.items()}


def lock_order_violations() -> List[Tuple[str, str, Tuple[str, ...]]]:
    with _GRAPH_MU:
        return list(_VIOLATIONS)


def reset_lock_order() -> None:
    with _GRAPH_MU:
        _EDGES.clear()
        _VIOLATIONS.clear()


class OrderedLock:
    """A ``threading.Lock`` that checks the global lock-order graph on
    every acquisition.  API-compatible with the subset the stack uses:
    context manager, ``acquire``/``release``, ``locked``."""

    __slots__ = ("name", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._lock = threading.Lock()

    def _check_order(self) -> None:
        held = _held_stack()
        if not held:
            return
        with _GRAPH_MU:
            for h in held:
                if h == self.name:       # same-role nesting: see module doc
                    continue
                dsts = _EDGES.setdefault(h, set())
                if self.name in dsts:
                    continue
                # adding h -> name; a path name ->* h means a cycle
                cycle = _find_path(self.name, h)
                if cycle is not None:
                    _VIOLATIONS.append((h, self.name, cycle))
                    raise LockOrderError(
                        f"lock-order inversion: acquiring '{self.name}' "
                        f"while holding '{h}', but the reverse order "
                        f"{' -> '.join(cycle)} was already established "
                        f"(potential ABBA deadlock)")
                dsts.add(self.name)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check_order()
        got = self._lock.acquire(blocking, timeout)
        if got:
            _held_stack().append(self.name)
        return got

    def release(self) -> None:
        self._lock.release()
        st = _held_stack()
        # remove the most recent occurrence (handles out-of-order release)
        for i in range(len(st) - 1, -1, -1):
            if st[i] == self.name:
                del st[i]
                break

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:          # pragma: no cover
        return f"OrderedLock({self.name!r})"


def make_lock(name: str):
    """The stack's lock factory: plain ``threading.Lock`` normally,
    order-checked :class:`OrderedLock` under ``REPRO_SANITIZE=1``."""
    if enabled():
        return OrderedLock(name)
    return threading.Lock()


# ---- recompile sentinel ---------------------------------------------

class RecompileSentinel:
    """Fails-fast detector for steady-state route-step recompiles.

    Installed via :func:`repro.kernels.ops.set_recompile_hook`; each
    route-step dispatch reports ``(signature, compiles)``.  A non-zero
    compile count for a signature that already compiled once is a
    violation (the padded-bucket cache regressed)."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._seen: Set[tuple] = set()
        self._violations: List[str] = []

    # hook target — called from ops.route_step on every dispatch
    def __call__(self, event: dict) -> None:
        sig = (event.get("path"), event.get("q_bucket"),
               event.get("n_bucket"), event.get("quant"),
               event.get("shards"))
        compiles = int(event.get("compiles", 0) or 0)
        with self._mu:
            # any prior dispatch of this signature — compiled or served
            # from a warm cache — counts as warmup: compiling again for
            # a signature we have already seen dispatched is exactly
            # the steady-state recompile the bucket cache must prevent
            if compiles > 0 and sig in self._seen:
                self._violations.append(
                    f"route_step recompiled signature "
                    f"path={sig[0]} q_bucket={sig[1]} "
                    f"n_bucket={sig[2]} quant={sig[3]} "
                    f"shards={sig[4]} after warmup "
                    f"({compiles} compile(s))")
            self._seen.add(sig)

    def install(self) -> "RecompileSentinel":
        from repro.kernels import ops
        ops.set_recompile_hook(self)
        return self

    def uninstall(self) -> None:
        from repro.kernels import ops
        ops.set_recompile_hook(None)

    def drain(self) -> List[str]:
        with self._mu:
            out = self._violations
            self._violations = []
            return out

    def forget(self) -> None:
        """Reset warmup state (after a deliberate cache clear)."""
        with self._mu:
            self._seen.clear()
            self._violations.clear()
