"""Lint engine core: source model, rule registry, and the driver.

Two rule shapes:

* **file rules** — ``check_file(src: Source) -> Iterable[Finding]``,
  called once per parsed file (lock discipline, jit hazards);
* **project rules** — ``check_project(ctx: Project) -> ...``, called
  once per run with the whole file set (kernel-oracle conformance
  needs kernels/, ref.py and tests/ together).

The driver parses each ``.py`` file once, runs every rule, then drops
findings covered by well-formed inline suppressions (malformed ones
become ``bad-suppression`` findings — no bare suppressions).
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Callable, Iterable, List, Optional

from repro.analysis.findings import Finding, Suppressions


@dataclass
class Source:
    path: str                 # absolute
    rel: str                  # repo-relative, "/"-separated
    text: str
    tree: ast.AST
    lines: List[str]

    @classmethod
    def parse(cls, path: str, root: str) -> "Source":
        with open(path, encoding="utf-8") as f:
            text = f.read()
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        return cls(path=path, rel=rel, text=text,
                   tree=ast.parse(text, filename=path),
                   lines=text.splitlines())


@dataclass
class Project:
    root: str                 # the directory findings are relative to
    sources: List[Source]
    tests_dir: Optional[str] = None

    def source(self, rel_suffix: str) -> Optional[Source]:
        for s in self.sources:
            if s.rel.endswith(rel_suffix):
                return s
        return None


@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)
    # files that failed to parse: (rel_path, error) — reported, not fatal
    errors: List[tuple] = field(default_factory=list)
    n_files: int = 0


FileRule = Callable[[Source], Iterable[Finding]]
ProjectRule = Callable[[Project], Iterable[Finding]]


def default_rules() -> tuple:
    """(file_rules, project_rules) — imported lazily so `import
    repro.analysis.engine` stays cheap for the sanitizer path."""
    from repro.analysis.jit_hazards import check_jit_hazards
    from repro.analysis.kernel_oracle import check_kernel_oracles
    from repro.analysis.locks import check_lock_discipline
    return ([check_lock_discipline, check_jit_hazards],
            [check_kernel_oracles])


def collect_files(paths: List[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(os.path.abspath(p))
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__"
                                 and not d.startswith("."))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.abspath(os.path.join(dirpath, fn)))
    return out


def run_lint(paths: List[str], *, root: Optional[str] = None,
             tests_dir: Optional[str] = None,
             file_rules: Optional[List[FileRule]] = None,
             project_rules: Optional[List[ProjectRule]] = None
             ) -> LintResult:
    """Lint ``paths`` (files or directories).  ``root`` anchors the
    relative paths in findings (defaults to CWD).  ``tests_dir`` feeds
    the kernel-parity project rule (defaults to ``<root>/tests`` when
    it exists)."""
    root = os.path.abspath(root or os.getcwd())
    if tests_dir is None:
        cand = os.path.join(root, "tests")
        tests_dir = cand if os.path.isdir(cand) else None
    if file_rules is None or project_rules is None:
        frs, prs = default_rules()
        file_rules = frs if file_rules is None else file_rules
        project_rules = prs if project_rules is None else project_rules

    result = LintResult()
    sources: List[Source] = []
    for path in collect_files(paths):
        try:
            src = Source.parse(path, root)
        except SyntaxError as e:                 # pragma: no cover
            result.errors.append(
                (os.path.relpath(path, root).replace(os.sep, "/"),
                 str(e)))
            continue
        sources.append(src)
    result.n_files = len(sources)

    raw: List[Finding] = []
    for src in sources:
        for rule in file_rules:
            raw.extend(rule(src))
    project = Project(root=root, sources=sources, tests_dir=tests_dir)
    for prule in project_rules:
        raw.extend(prule(project))

    # apply inline suppressions per file; malformed ones are findings
    by_rel = {s.rel: s for s in sources}
    sup_cache = {}
    kept: List[Finding] = []
    for f in raw:
        src = by_rel.get(f.path)
        if src is None:                           # project-level finding
            kept.append(f)
            continue
        sup = sup_cache.get(f.path)
        if sup is None:
            sup = sup_cache[f.path] = Suppressions.scan(src.lines)
        if not sup.covers(f):
            kept.append(f)
    for rel, src in by_rel.items():
        sup = sup_cache.get(rel)
        if sup is None:
            sup = sup_cache[rel] = Suppressions.scan(src.lines)
        for line, msg in sup.malformed:
            kept.append(Finding(rule="bad-suppression", path=rel,
                                line=line, col=1, message=msg))

    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    result.findings = kept
    return result


def lint_source(text: str, *, rel: str = "snippet.py",
                file_rules: Optional[List[FileRule]] = None
                ) -> List[Finding]:
    """Lint one in-memory snippet (the rule fixtures' entry point)."""
    src = Source(path=rel, rel=rel, text=text, tree=ast.parse(text),
                 lines=text.splitlines())
    if file_rules is None:
        file_rules, _ = default_rules()
    out: List[Finding] = []
    for rule in file_rules:
        out.extend(rule(src))
    sup = Suppressions.scan(src.lines)
    kept = [f for f in out if not sup.covers(f)]
    kept.extend(Finding(rule="bad-suppression", path=rel, line=line,
                        col=1, message=msg)
                for line, msg in sup.malformed)
    kept.sort(key=lambda f: (f.line, f.col, f.rule))
    return kept
