"""CLI: ``python -m repro.analysis.lint [paths...]``.

Exit codes: 0 — clean (or all findings baselined), 1 — new findings,
2 — usage / parse errors.

The CI invocation is ``python -m repro.analysis.lint src/repro`` from
the repo root with the default baseline at ``analysis/baseline.json``.
``--write-baseline`` re-triages: it records the *current* finding set
(after fixes and inline suppressions) as the new baseline, pruning
stale entries — the ratchet only ever tightens unless a human commits
a wider file.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis.engine import run_lint
from repro.analysis.findings import (RULES, SCHEMA_VERSION, load_baseline,
                                     save_baseline, split_new,
                                     stale_baseline)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific AST lint (lock discipline, jit "
                    "hazards, kernel-oracle conformance)")
    p.add_argument("paths", nargs="*", default=["src/repro"],
                   help="files or directories to lint "
                        "(default: src/repro)")
    p.add_argument("--root", default=None,
                   help="directory findings are reported relative to "
                        "(default: cwd)")
    p.add_argument("--tests-dir", default=None,
                   help="tests directory for kernel-parity discovery "
                        "(default: <root>/tests when present)")
    p.add_argument("--baseline", default="analysis/baseline.json",
                   help="ratchet baseline file (default: "
                        "analysis/baseline.json; missing file = empty)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline: report and gate on ALL "
                        "findings")
    p.add_argument("--write-baseline", action="store_true",
                   help="write the current finding set to --baseline "
                        "and exit 0")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as a JSON document on stdout")
    p.add_argument("--rules", action="store_true", dest="show_rules",
                   help="print the rule catalog and exit")
    return p


def main(argv: List[str] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.show_rules:
        for rule, desc in RULES.items():
            print(f"{rule:28s} {desc}")
        return 0

    paths = args.paths or ["src/repro"]
    result = run_lint(paths, root=args.root, tests_dir=args.tests_dir)
    for rel, err in result.errors:
        print(f"{rel}: parse error: {err}", file=sys.stderr)

    if args.write_baseline:
        os.makedirs(os.path.dirname(os.path.abspath(args.baseline)),
                    exist_ok=True)
        save_baseline(args.baseline, result.findings)
        print(f"wrote {len(result.findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    baseline = ({} if args.no_baseline
                else load_baseline(args.baseline))
    new, baselined = split_new(result.findings, baseline)
    stale = stale_baseline(result.findings, baseline)

    if args.as_json:
        doc = {
            "schema_version": SCHEMA_VERSION,
            "n_files": result.n_files,
            "findings": [f.to_dict() for f in new],
            "baselined": [f.to_dict() for f in baselined],
            "stale_baseline": stale,
            "errors": [{"path": p, "error": e} for p, e in result.errors],
        }
        json.dump(doc, sys.stdout, indent=1, sort_keys=True)
        sys.stdout.write("\n")
    else:
        for f in new:
            print(f.render())
        tail = (f"{result.n_files} file(s): {len(new)} new finding(s), "
                f"{len(baselined)} baselined")
        if stale:
            tail += (f", {sum(stale.values())} stale baseline entr"
                     f"{'y' if sum(stale.values()) == 1 else 'ies'} "
                     f"(re-run --write-baseline to prune)")
        print(tail)

    if result.errors:
        return 2
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
