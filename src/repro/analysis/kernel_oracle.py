"""Kernel-oracle conformance (project rule).

Every Pallas kernel entry exported from ``kernels/*.py`` — any
module-level ``*_pallas`` or ``*_jit`` def/assignment — must have:

* ``kernel-missing-oracle`` — a matching pure-JAX oracle in
  ``kernels/ref.py``.  The oracle name is the entry's base name with
  dispatch suffixes stripped (``_pallas`` / ``_jit``, then a trailing
  quantization tag like ``_q8``), mapped through a small alias table
  (``flash_attention`` → ``mha_attention``: the oracle implements plain
  multi-head attention), with a prefix fallback for sharded variants
  (``route_step_sharded`` validates against ``route_step`` — sharding
  changes the partitioning, not the math).

* ``kernel-missing-parity-test`` — at least one test under ``tests/``
  that imports ``repro.kernels.ref`` and references the oracle by name
  (discovered by AST scan, so a new kernel without a parity test fails
  lint rather than review).
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.engine import Project, Source
from repro.analysis.findings import Finding

# kernel whose oracle lives under a different name in ref.py
ORACLE_ALIASES = {"flash_attention": "mha_attention"}

_SUFFIXES = ("_pallas", "_jit")
_QUANT_TAG = re.compile(r"_q\d+$")


def oracle_name_for(entry: str, oracles: Set[str]) -> Optional[str]:
    """Resolve a kernel entry name to its ref.py oracle, or None."""
    base = entry
    for suf in _SUFFIXES:
        if base.endswith(suf):
            base = base[: -len(suf)]
            break
    base = _QUANT_TAG.sub("", base)
    base = ORACLE_ALIASES.get(base, base)
    if base in oracles:
        return base
    # prefix fallback: route_step_sharded -> route_step (longest match)
    for cand in sorted(oracles, key=len, reverse=True):
        if base.startswith(cand + "_"):
            return cand
    return None


def _kernel_entries(src: Source) -> List[Tuple[str, int, int]]:
    """Module-level *_pallas / *_jit names with their def locations."""
    out: List[Tuple[str, int, int]] = []

    def is_entry(name: str) -> bool:
        return not name.startswith("_") and (
            name.endswith("_pallas") or name.endswith("_jit")
            or _QUANT_TAG.sub("", name).endswith("_pallas"))

    for node in src.tree.body:                   # type: ignore[attr-defined]
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if is_entry(node.name):
                out.append((node.name, node.lineno, node.col_offset + 1))
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and is_entry(t.id):
                    out.append((t.id, node.lineno, node.col_offset + 1))
    return out


def _oracle_names(ref_src: Source) -> Set[str]:
    return {node.name
            for node in ref_src.tree.body       # type: ignore[attr-defined]
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and not node.name.startswith("_")}


def _imports_ref(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name.startswith("repro.kernels.ref")
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.startswith("repro.kernels.ref"):
                return True
            if mod == "repro.kernels" and any(a.name == "ref"
                                              for a in node.names):
                return True
    return False


def _referenced_names(tree: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            # `from repro.kernels.ref import route_step` references the
            # oracle even before any call site
            out.update(a.name for a in node.names)
    return out


def _parity_tested_oracles(tests_dir: str) -> Set[str]:
    """Union of names referenced by every ref-importing test file."""
    tested: Set[str] = set()
    for fn in sorted(os.listdir(tests_dir)):
        if not fn.endswith(".py"):
            continue
        path = os.path.join(tests_dir, fn)
        try:
            with open(path, encoding="utf-8") as f:
                tree = ast.parse(f.read(), filename=path)
        except SyntaxError:                      # pragma: no cover
            continue
        if _imports_ref(tree):
            tested |= _referenced_names(tree)
    return tested


def check_kernel_oracles(project: Project) -> Iterable[Finding]:
    ref_src = project.source("kernels/ref.py")
    kernel_srcs = [s for s in project.sources
                   if "kernels/" in s.rel and not s.rel.endswith("/ref.py")]
    if ref_src is None or not kernel_srcs:
        return []
    oracles = _oracle_names(ref_src)
    tested: Optional[Set[str]] = None
    if project.tests_dir and os.path.isdir(project.tests_dir):
        tested = _parity_tested_oracles(project.tests_dir)

    findings: List[Finding] = []
    resolved: Dict[str, List[Tuple[Source, str, int, int]]] = {}
    for src in kernel_srcs:
        for name, line, col in _kernel_entries(src):
            oracle = oracle_name_for(name, oracles)
            if oracle is None:
                findings.append(Finding(
                    rule="kernel-missing-oracle", path=src.rel,
                    line=line, col=col, symbol=name,
                    message=(f"kernel entry `{name}` has no matching "
                             f"oracle in kernels/ref.py — add a pure-JAX "
                             f"reference implementation")))
            else:
                resolved.setdefault(oracle, []).append((src, name, line, col))

    if tested is not None:
        for oracle, entries in sorted(resolved.items()):
            if oracle in tested:
                continue
            src, name, line, col = entries[0]
            findings.append(Finding(
                rule="kernel-missing-parity-test", path=src.rel,
                line=line, col=col, symbol=name,
                message=(f"oracle `{oracle}` (validating `{name}`) is "
                         f"never referenced by a ref-importing test "
                         f"under tests/ — add a parity test")))
    return findings
