"""Finding model, inline suppressions, and the ratcheting baseline.

A ``Finding`` is one rule violation at one source location.  Its
``fingerprint`` deliberately excludes the line number — fingerprints
are ``sha1(rule | path | symbol | message)`` — so reformatting or code
motion above a violation does not churn the baseline, while any change
to *what* is wrong (rule, file, enclosing symbol, message) does.

Baseline semantics (the ratchet): ``analysis/baseline.json`` holds the
fingerprints of triaged, pre-existing findings *with multiplicity*.  A
lint run fails only on findings beyond the baselined count per
fingerprint — new violations fail CI, baselined ones pass, and fixing
a violation can only shrink the file.

Inline suppressions: ``# lint: ignore[rule1,rule2] -- reason`` on the
flagged line (or the line directly above) silences those rules there.
The reason is REQUIRED: a ``# lint: ignore`` without a rule list or
without a ``-- reason`` is itself reported as ``bad-suppression``.
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

# every rule the engine can emit, with a one-line catalog entry
# (README + `--rules` render this; tests pin the set so renames are
# deliberate)
RULES: Dict[str, str] = {
    "lock-mixed-mutation":
        "attribute mutated both inside and outside `with self._lock`",
    "lock-unlocked-read":
        "public method reads multiple lock-guarded attributes without "
        "holding the lock (torn multi-field read)",
    "jit-traced-branch":
        "Python `if`/`while` on a traced value inside a jitted function "
        "(retraces per value or fails under jit)",
    "jit-host-sync":
        "host synchronization (.item() / float() / np.asarray / "
        "device_get) inside a jitted or fused-path function",
    "jit-constant-rebuild":
        "jnp.asarray/jnp.array of a fresh per-call Python literal "
        "(defeats the ops.py padded-constant cache)",
    "jit-bucket-bypass":
        "raw route-step / router-topk kernel entry called outside "
        "repro.kernels (bypasses q_bucket/n_bucket shape buckets)",
    "kernel-missing-oracle":
        "Pallas kernel exported from kernels/*.py without a matching "
        "kernels/ref.py oracle",
    "kernel-missing-parity-test":
        "kernel oracle never exercised by a ref-importing parity test "
        "under tests/",
    "bad-suppression":
        "malformed `# lint: ignore` (missing [rule] list or -- reason)",
}

# JSON output schema version — tests pin this; bump on breaking change
SCHEMA_VERSION = 1


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str                 # repo-relative, "/"-separated
    line: int                 # 1-indexed
    col: int
    message: str
    symbol: str = ""          # enclosing "Class.method" / function

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha1(
            f"{self.rule}|{self.path}|{self.symbol}|{self.message}"
            .encode()).hexdigest()[:16]
        return f"{self.rule}:{h}"

    def to_dict(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "symbol": self.symbol,
                "message": self.message,
                "fingerprint": self.fingerprint}

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}"
                f"{sym}: {self.message}")


# --------------------------------------------------------------------
# inline suppressions
# --------------------------------------------------------------------

# full, well-formed form: rules list AND a non-empty reason
_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*ignore\[([A-Za-z0-9_,\- ]+)\]\s*--\s*(\S.*)")
# anything that *tries* to be a lint suppression (to catch bare ones)
_SUPPRESS_ANY_RE = re.compile(r"#\s*lint:\s*ignore")


def _comment_lines(lines: List[str]):
    """(lineno, comment_text) for real COMMENT tokens only — a
    suppression example quoted in a docstring is not a suppression."""
    import io
    import tokenize
    try:
        toks = list(tokenize.generate_tokens(
            io.StringIO("\n".join(lines) + "\n").readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        # unparseable fragment: fall back to raw lines (lint runs on
        # parsed files, so this only happens for snippets in tests)
        return list(enumerate(lines, start=1))
    return [(tok.start[0], tok.string) for tok in toks
            if tok.type == tokenize.COMMENT]


@dataclass
class Suppressions:
    """Per-file map of line -> set of suppressed rules, plus findings
    for malformed suppression comments."""
    by_line: Dict[int, set] = field(default_factory=dict)
    malformed: List[Tuple[int, str]] = field(default_factory=list)

    @classmethod
    def scan(cls, lines: List[str]) -> "Suppressions":
        out = cls()
        for i, text in _comment_lines(lines):
            if not _SUPPRESS_ANY_RE.search(text):
                continue
            m = _SUPPRESS_RE.search(text)
            if not m:
                out.malformed.append(
                    (i, "suppression must name its rules and a reason: "
                        "`# lint: ignore[rule] -- reason`"))
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            unknown = sorted(r for r in rules if r not in RULES)
            if unknown:
                out.malformed.append(
                    (i, f"suppression names unknown rule(s) "
                        f"{', '.join(unknown)}"))
                rules -= set(unknown)
            # a suppression covers its own line plus the next *code*
            # line: a trailing comment covers its statement, and a
            # comment block above a statement (the reason often wraps
            # over several comment lines) covers the statement below it
            j = i + 1
            while (j <= len(lines)
                   and lines[j - 1].lstrip().startswith("#")):
                j += 1
            for ln in range(i, j + 1):
                out.by_line.setdefault(ln, set()).update(rules)
        return out

    def covers(self, finding: Finding) -> bool:
        return finding.rule in self.by_line.get(finding.line, ())


# --------------------------------------------------------------------
# baseline (the ratchet)
# --------------------------------------------------------------------

def load_baseline(path: str) -> Dict[str, int]:
    """fingerprint -> allowed multiplicity (empty when absent)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    counts: Dict[str, int] = {}
    for row in data.get("findings", []):
        fp = row["fingerprint"]
        counts[fp] = counts.get(fp, 0) + 1
    return counts


def save_baseline(path: str, findings: Iterable[Finding]) -> None:
    rows = sorted((f.to_dict() for f in findings),
                  key=lambda r: (r["path"], r["rule"], r["line"]))
    for r in rows:
        # line/col are context for the human reading the file, not part
        # of the match — drop nothing, but order keys stably
        r.pop("col", None)
    with open(path, "w") as f:
        json.dump({"version": SCHEMA_VERSION,
                   "comment": "triaged pre-existing lint findings; the "
                              "gate fails only on findings NOT counted "
                              "here (ratchet — see repro.analysis)",
                   "findings": rows}, f, indent=1, sort_keys=True)
        f.write("\n")


def split_new(findings: List[Finding], baseline: Dict[str, int]
              ) -> Tuple[List[Finding], List[Finding]]:
    """(new, baselined): matches findings against the baseline's
    per-fingerprint multiplicity, greedily in file order."""
    budget = dict(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        fp = f.fingerprint
        if budget.get(fp, 0) > 0:
            budget[fp] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


def stale_baseline(findings: List[Finding], baseline: Dict[str, int]
                   ) -> Dict[str, int]:
    """Baseline entries with no surviving finding (fixed or moved):
    fingerprint -> unused count.  Informational — `--write-baseline`
    prunes them."""
    live: Dict[str, int] = {}
    for f in findings:
        live[f.fingerprint] = live.get(f.fingerprint, 0) + 1
    out = {}
    for fp, n in baseline.items():
        unused = n - live.get(fp, 0)
        if unused > 0:
            out[fp] = unused
    return out
