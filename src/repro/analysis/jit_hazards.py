"""Jit / recompile hazard rules.

* ``jit-traced-branch`` — Python ``if``/``while`` whose test references
  a traced value inside a jitted function.  Shape/dtype/static tests
  are fine (``x.shape[0] > 4``, ``x is None``, ``len(xs)``,
  ``isinstance(...)``) — the rule skips those forms; anything else
  either fails under jit (`TracerBoolConversionError`) or silently
  retraces per concrete value.

* ``jit-host-sync`` — ``.item()`` / ``.tolist()`` /
  ``.block_until_ready()`` on traced values, ``float()/int()/bool()``
  of a traced value, ``np.asarray``/``np.array`` of a traced value, and
  ``jax.device_get`` inside a jitted function: all force a device→host
  sync in the middle of a traced computation.

* ``jit-constant-rebuild`` — ``jnp.asarray``/``jnp.array`` of a Python
  *literal* (list/tuple/number/comprehension) inside a function body.
  Each call builds a fresh device constant; under jit each fresh
  ndarray is a new tracer-constant, defeating the ``ops.py``
  padded-constant cache.  Hoist to module scope or route through the
  cache.

* ``jit-bucket-bypass`` — calling a raw jitted kernel entry
  (``route_step_jit``, ``router_topk_pallas``, ...) from outside
  ``repro/kernels``.  Only the bucketed dispatchers (``route_step``,
  ``router_topk_bucketed``) pad to the q/n shape buckets; raw calls
  compile one executable per exact shape.

Jitted scopes recognized (the repo's idioms):

* ``@jax.jit`` / ``@jit`` decorators;
* ``@functools.partial(jax.jit, static_argnames=(...))`` (statics are
  excluded from the traced set);
* ``name = jax.jit(fn, ...)`` module-level wraps (marks ``fn``);
* ``*_kernel`` functions in ``kernels/`` files (Pallas kernel bodies —
  traced by ``pallas_call``).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.engine import Source
from repro.analysis.findings import Finding

# attribute reads that yield static (Python-level) values even on tracers
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval",
                 "itemsize"}
# calls whose result is static even with traced arguments
_STATIC_CALLS = {"len", "isinstance", "type", "getattr", "hasattr",
                 "range", "enumerate", "zip"}
# method calls on a traced value that force a host sync
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
# builtins that force a concrete (host) value out of a tracer
_SYNC_CASTS = {"float", "int", "bool", "complex"}
_NUMPY_ALIASES = {"np", "numpy", "onp"}

# raw jitted kernel entries that bypass q_bucket/n_bucket padding; the
# sanctioned public dispatchers are route_step / router_topk_bucketed
RAW_KERNEL_ENTRIES = {
    "route_step_jit", "route_step_ivf_jit", "route_step_sharded_jit",
    "router_topk_pallas", "router_topk_q8_pallas",
    "analyze_step_jit", "analyze_route_step_jit",
}


class _TracedRefFinder(ast.NodeVisitor):
    """Collect Name nodes referring to traced values, skipping forms
    that are static under tracing (shape reads, len(), `is None`, ...)."""

    def __init__(self, traced: Set[str]) -> None:
        self.traced = traced
        self.found: List[ast.Name] = []

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if node.attr in _STATIC_ATTRS:
            return                               # skip whole subtree
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Name) and node.func.id in _STATIC_CALLS:
            return
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return                               # `x is None` style
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if node.id in self.traced:
            self.found.append(node)


def _traced_refs(expr: ast.AST, traced: Set[str]) -> List[ast.Name]:
    finder = _TracedRefFinder(traced)
    finder.visit(expr)
    return finder.found


# --------------------------------------------------------------------
# jit-scope discovery
# --------------------------------------------------------------------

def _call_is(func: ast.AST, *names: str) -> bool:
    """Match `jit` / `jax.jit` / `functools.partial` style references."""
    if isinstance(func, ast.Name):
        return func.id in names
    if isinstance(func, ast.Attribute):
        return func.attr in names
    return False


def _statics_from_call(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.add(n.value)
    return names, nums


def _jit_statics_of_def(fn) -> Optional[Tuple[Set[str], Set[int]]]:
    """(static_argnames, static_argnums) if `fn` is jit-decorated."""
    for dec in fn.decorator_list:
        if _call_is(dec, "jit"):
            return set(), set()
        if isinstance(dec, ast.Call):
            if _call_is(dec.func, "jit"):
                return _statics_from_call(dec)
            if _call_is(dec.func, "partial") and dec.args \
                    and _call_is(dec.args[0], "jit"):
                return _statics_from_call(dec)
    return None


def _collect_functions(tree: ast.AST):
    """Yield (qualname, node) for every def, with Class.method names."""
    def walk(body: Sequence[ast.stmt], prefix: str):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{node.name}"
                yield qual, node
                yield from walk(node.body, f"{qual}.")
            elif isinstance(node, ast.ClassDef):
                yield from walk(node.body, f"{prefix}{node.name}.")
    yield from walk(tree.body, "")               # type: ignore[attr-defined]


def _find_jitted(src: Source) -> List[Tuple[str, object, Set[str]]]:
    """[(qualname, fn_node, static_param_names)] for jitted scopes."""
    funcs = list(_collect_functions(src.tree))
    by_name: Dict[str, object] = {}
    for qual, node in funcs:
        by_name.setdefault(node.name, node)

    # `foo_jit = jax.jit(foo, static_argnames=...)` wraps
    wrapped: Dict[object, Tuple[Set[str], Set[int]]] = {}
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _call_is(node.value.func, "jit") and node.value.args:
            tgt = node.value.args[0]
            if isinstance(tgt, ast.Name) and tgt.id in by_name:
                wrapped[by_name[tgt.id]] = _statics_from_call(node.value)

    in_kernels = "/kernels/" in f"/{src.rel}"
    out: List[Tuple[str, object, Set[str], bool]] = []
    for qual, node in funcs:
        kernel_body = False
        statics = _jit_statics_of_def(node)
        if statics is None and node in wrapped:
            statics = wrapped[node]
        if statics is None and in_kernels and node.name.endswith("_kernel"):
            statics = (set(), set())             # Pallas kernel body
            kernel_body = True
        if statics is None:
            continue
        names, nums = statics
        params = [a.arg for a in (node.args.posonlyargs + node.args.args)]
        static_params = set(names)
        static_params.update(params[i] for i in nums if i < len(params))
        out.append((qual, node, static_params, kernel_body))
    return out


# --------------------------------------------------------------------
# per-scope scan
# --------------------------------------------------------------------

class _JitScopeScanner:
    def __init__(self, src: Source, qual: str, fn, statics: Set[str],
                 kernel_body: bool = False) -> None:
        self.src = src
        self.qual = qual
        args = fn.args
        if kernel_body:
            # Pallas kernel bodies: positional `*_ref` params are the
            # traced memory refs; everything else (keyword params bound
            # through functools.partial at pallas_call time) is a
            # compile-time Python constant, branched on freely.
            params = [a.arg for a in (args.posonlyargs + args.args)]
            self.traced: Set[str] = {p for p in params
                                     if p.endswith("_ref")}
        else:
            params = [a.arg for a in (args.posonlyargs + args.args
                                      + args.kwonlyargs)]
            self.traced = {p for p in params
                           if p not in statics and p != "self"}
        self.findings: List[Finding] = []
        for st in fn.body:
            self._stmt(st)

    def _finding(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.src.rel, line=node.lineno,
            col=node.col_offset + 1, symbol=self.qual, message=message))

    # statement walk, propagating tracedness through simple assignments
    def _stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.If, ast.While)):
            refs = _traced_refs(node.test, self.traced)
            if refs:
                kind = "if" if isinstance(node, ast.If) else "while"
                self._finding(
                    "jit-traced-branch", node,
                    f"Python `{kind}` on traced value "
                    f"`{refs[0].id}` — use jnp.where/lax.cond/lax.select "
                    f"or hoist the decision out of the jitted scope")
            self._expr(node.test)
            for st in node.body:
                self._stmt(st)
            for st in node.orelse:
                self._stmt(st)
            return
        if isinstance(node, ast.Assign):
            self._expr(node.value)
            if _traced_refs(node.value, self.traced):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        self.traced.add(t.id)
                    elif isinstance(t, (ast.Tuple, ast.List)):
                        for elt in t.elts:
                            if isinstance(elt, ast.Name):
                                self.traced.add(elt.id)
            return
        if isinstance(node, ast.AugAssign):
            self._expr(node.value)
            return
        if isinstance(node, (ast.For, ast.AsyncFor)):
            self._expr(node.iter)
            for st in node.body:
                self._stmt(st)
            for st in node.orelse:
                self._stmt(st)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                self._expr(item.context_expr)
            for st in node.body:
                self._stmt(st)
            return
        if isinstance(node, ast.Try):
            for part in (node.body, node.orelse, node.finalbody):
                for st in part:
                    self._stmt(st)
            for h in node.handlers:
                for st in h.body:
                    self._stmt(st)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for st in node.body:                 # nested def: same scope
                self._stmt(st)
            return
        if isinstance(node, ast.Return) and node.value is not None:
            self._expr(node.value)
            return
        if isinstance(node, ast.Expr):
            self._expr(node.value)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._stmt(child)
            elif isinstance(child, ast.expr):
                self._expr(child)

    def _expr(self, node: ast.expr) -> None:
        for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
            self._check_call(call)

    def _check_call(self, call: ast.Call) -> None:
        func = call.func
        if isinstance(func, ast.Attribute):
            if func.attr in _SYNC_METHODS \
                    and _traced_refs(func.value, self.traced):
                self._finding(
                    "jit-host-sync", call,
                    f"`.{func.attr}()` on a traced value forces a "
                    f"device→host sync inside a jitted function")
                return
            if func.attr in {"asarray", "array"} \
                    and isinstance(func.value, ast.Name) \
                    and func.value.id in _NUMPY_ALIASES \
                    and call.args \
                    and _traced_refs(call.args[0], self.traced):
                self._finding(
                    "jit-host-sync", call,
                    f"`{func.value.id}.{func.attr}` of a traced value "
                    f"materializes it on host inside a jitted function")
                return
            if func.attr == "device_get":
                self._finding(
                    "jit-host-sync", call,
                    "`device_get` inside a jitted function is a host sync")
                return
        if isinstance(func, ast.Name) and func.id in _SYNC_CASTS \
                and call.args and _traced_refs(call.args[0], self.traced):
            self._finding(
                "jit-host-sync", call,
                f"`{func.id}()` of a traced value concretizes it "
                f"(host sync / TracerConversionError) inside a jitted "
                f"function")


# --------------------------------------------------------------------
# whole-file rules (constant rebuild, bucket bypass)
# --------------------------------------------------------------------

_LITERALS = (ast.List, ast.Tuple, ast.Constant, ast.ListComp, ast.Dict,
             ast.Set)


def _scan_constant_rebuild(src: Source) -> Iterable[Finding]:
    for qual, fn in _collect_functions(src.tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not (isinstance(func, ast.Attribute)
                    and func.attr in {"asarray", "array"}
                    and isinstance(func.value, ast.Name)
                    and func.value.id == "jnp"):
                continue
            if node.args and isinstance(node.args[0], _LITERALS):
                yield Finding(
                    rule="jit-constant-rebuild", path=src.rel,
                    line=node.lineno, col=node.col_offset + 1, symbol=qual,
                    message=(f"jnp.{func.attr} of a Python literal builds "
                             f"a fresh device constant on every call — "
                             f"hoist to module scope or use the ops.py "
                             f"padded-constant cache"))


def _scan_bucket_bypass(src: Source) -> Iterable[Finding]:
    if "/kernels/" in f"/{src.rel}" or src.rel.startswith("kernels/"):
        return
    for qual, fn in _collect_functions(src.tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name = None
            if isinstance(func, ast.Name) and func.id in RAW_KERNEL_ENTRIES:
                name = func.id
            elif isinstance(func, ast.Attribute) \
                    and func.attr in RAW_KERNEL_ENTRIES:
                name = func.attr
            if name:
                yield Finding(
                    rule="jit-bucket-bypass", path=src.rel,
                    line=node.lineno, col=node.col_offset + 1, symbol=qual,
                    message=(f"`{name}` is a raw jitted kernel entry — "
                             f"call the bucketed dispatcher "
                             f"(route_step / router_topk_bucketed) so "
                             f"shapes hit the q_bucket/n_bucket pads"))


def check_jit_hazards(src: Source) -> Iterable[Finding]:
    findings: List[Finding] = []
    for qual, fn, statics, kernel_body in _find_jitted(src):
        findings.extend(
            _JitScopeScanner(src, qual, fn, statics,
                             kernel_body=kernel_body).findings)
    findings.extend(_scan_constant_rebuild(src))
    findings.extend(_scan_bucket_bypass(src))
    return findings
