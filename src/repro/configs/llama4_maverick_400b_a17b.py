"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E]

MoE 128 experts top-1 with a shared expert, early fusion:
48L d_model=5120 40H (kv=8) d_ff=8192 vocab=202048.

Simplification (DESIGN.md §4): every layer is MoE top-1 + shared expert
(the released model interleaves dense layers; uniform layers keep the
layer scan homogeneous).
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    n_experts=128,
    moe_top_k=1,
    shared_expert=True,
    rope_theta=500_000.0,
    # beyond-paper long-context SERVING mode (DESIGN.md §4): 500k
    # decode degrades to a 4096 SWA ring cache instead of refusing
    long_serving_window=4096,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.90, helpfulness=0.88, harmlessness=0.86, honesty=0.86,
            steerability=0.82, creativity=0.84,
            task_types=("chat", "code", "reasoning", "creative-writing"),
            domains=("general", "software", "finance", "legal"))
