"""Model configuration system.

Every assigned architecture gets one module in ``repro/configs/`` that
exports ``FULL`` (the exact published config) and ``SMOKE`` (a reduced
variant of the same family: <=2 layers, d_model<=512, <=4 experts) plus
an MRES catalog entry describing the model to the OptiRoute router.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256


def pad_vocab(v: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    return int(math.ceil(v / multiple) * multiple)


@dataclass(frozen=True)
class ModelConfig:
    """Unified configuration covering all six architecture families.

    ``arch_type`` selects the mixer stack:
      dense | moe | ssm | hybrid | encdec | vlm | audio
    (vlm/audio are decoder-only transformers consuming a stubbed
    modality frontend; encdec is an encoder-decoder whose encoder
    consumes frontend embeddings — Seamless-style.)
    """

    name: str
    arch_type: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation bracket from the assignment

    # --- attention options ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 => full attention
    local_global_pattern: bool = False  # gemma2: even layers local(SWA), odd global
    attn_softcap: float = 0.0        # gemma2 attention logit softcap
    final_softcap: float = 0.0       # gemma2 final logit softcap
    # long-context serving mode: if True, "global" layers degrade to SWA
    # for the long_500k shape (documented in DESIGN.md).
    long_mode_local_only: bool = False

    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    shared_expert: bool = False
    moe_group: int = 2048            # dispatch group size along sequence
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- encoder-decoder ---
    n_enc_layers: int = 0

    # --- modality frontend stub ---
    frontend: str = ""               # "" | "vision" | "audio"
    frontend_dim: int = 0            # dim of precomputed embeddings
    frontend_tokens: int = 0         # patches/frames prepended

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True

    # --- performance (beyond-paper hillclimbs; EXPERIMENTS.md §Perf) ---
    # "naive"   = materialize the full (Lq, Lk) masked score matrix
    # "blocked" = scan over query blocks (flash-style row softmax);
    #             uniform-SWA archs additionally slice the key BAND so
    #             scores are (blk_q, W + blk_q) instead of (blk_q, L)
    attn_impl: str = "blocked"
    attn_block_q: int = 512
    # int8 KV cache for decode (halves the cache-streaming memory term)
    kv_cache_dtype: str = ""         # "" = compute dtype | "int8"
    # expert-weight second shard axis: "f" avoids partial-sum all-reduce
    # of (g, E, C, f) intermediates ("d" = the naive FSDP baseline)
    moe_shard_axis: str = "f"
    # embedding d-axis FSDP ("True" = naive baseline): replicating d
    # keeps the tied LM head local and logits vocab-sharded
    embed_shard_d: bool = False
    # long-context SERVING degradation for full-attention families:
    # at long_500k, attention falls back to this sliding window (ring
    # KV cache) — an explicit approximation (DESIGN.md §4), the same
    # trade production servers make rather than refusing 500k contexts.
    # 0 = refuse long_500k (the paper-faithful default behaviour).
    long_serving_window: int = 0

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_model // max(self.n_heads, 1)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def ssm_groups(self) -> int:
        return 1 if self.ssm_state else 0

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.arch_type in ("encdec", "audio") and self.n_enc_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        if self.arch_type == "ssm":
            return True
        if self.arch_type == "hybrid":
            return self.sliding_window > 0
        if self.sliding_window > 0:
            return True
        if self.local_global_pattern and self.long_mode_local_only:
            return True
        return self.long_serving_window > 0

    def long_serving_config(self) -> "ModelConfig":
        """Effective config for the long_500k serving shape: full-
        attention families degrade to the long_serving_window SWA ring
        cache (parameters are unchanged — only the cache/mask differ)."""
        if self.sliding_window or self.arch_type == "ssm" \
                or not self.long_serving_window:
            return self
        return replace(self, sliding_window=self.long_serving_window,
                       local_global_pattern=False)

    # ------------------------------------------------------------------
    def n_params(self) -> int:
        """Total parameter count (analytic, matches init)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_padded
        per_layer = 0
        if self.has_attention:
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
        if self.is_moe:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * f
            if self.shared_expert:
                per_layer += 3 * d * f
        elif f > 0:
            per_layer += 3 * d * f
        if self.has_ssm:
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = di + 2 * self.ssm_groups * N
            per_layer += d * (2 * di + 2 * self.ssm_groups * N + H)
            per_layer += conv_dim * self.ssm_conv_width
            per_layer += 3 * H          # A_log, D, dt_bias
            per_layer += di             # gated norm
            per_layer += di * d
        per_layer += 2 * d              # pre-norms
        if self.arch_type == "hybrid":
            per_layer += 2 * d          # per-branch output norms
        total = self.n_layers * per_layer
        if self.is_encdec:
            enc_layer = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + 3 * d * f + 2 * d
            cross = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d
            total += self.n_enc_layers * enc_layer + self.n_layers * cross + d
        if self.frontend:
            total += self.frontend_dim * d + d
        total += V * d + d              # embed + final norm
        return int(total)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        inactive = self.n_layers * (self.n_experts - self.moe_top_k) * 3 * d * f
        return self.n_params() - inactive

    # ------------------------------------------------------------------
    def validate(self) -> "ModelConfig":
        assert self.arch_type in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"), self.arch_type
        if self.has_attention and self.n_heads:
            assert self.d_model % self.n_heads == 0, (self.name, "d_model % n_heads")
            assert self.n_heads % self.n_kv_heads == 0, (self.name, "GQA group")
        if self.is_moe:
            assert 0 < self.moe_top_k <= self.n_experts
        if self.has_ssm:
            assert self.d_inner % self.ssm_head_dim == 0
        return self


def smoke_variant(full: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config: <=2 layers, d_model<=512, <=4 experts."""
    d = min(full.d_model, 256)
    n_heads = min(full.n_heads, 4) or full.n_heads
    if full.has_attention:
        # keep the GQA grouping structure of the family
        group = max(full.n_heads // max(full.n_kv_heads, 1), 1)
        n_kv = max(n_heads // min(group, n_heads), 1)
    else:
        n_heads, n_kv = 0, 0
    kw = dict(
        n_layers=2,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=min(full.d_ff, 512) if full.d_ff else 0,
        vocab_size=min(full.vocab_size, 1024),
        n_experts=min(full.n_experts, 4) if full.n_experts else 0,
        moe_top_k=min(full.moe_top_k, 2) if full.moe_top_k else 0,
        # no-drop capacity so decode == full-forward exactly in tests
        moe_capacity_factor=(min(full.n_experts, 4) / min(full.moe_top_k, 2)
                             if full.n_experts else 1.25),
        n_enc_layers=2 if full.n_enc_layers else 0,
        frontend_dim=min(full.frontend_dim, 128) if full.frontend else 0,
        frontend_tokens=min(full.frontend_tokens, 16) if full.frontend else 0,
        sliding_window=min(full.sliding_window, 64) if full.sliding_window else 0,
        ssm_state=min(full.ssm_state, 16) if full.ssm_state else 0,
        ssm_head_dim=32 if full.ssm_state else full.ssm_head_dim,
        ssm_chunk=16 if full.ssm_state else full.ssm_chunk,
        param_dtype="float32",
        compute_dtype="float32",
        name=full.name + "-smoke",
    )
    kw.update(overrides)
    return replace(full, **kw).validate()
