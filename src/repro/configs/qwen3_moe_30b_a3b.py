"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]

48L d_model=2048 32H (GQA kv=4) d_ff=768/expert vocab=151936,
MoE 128 experts top-8.
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    n_experts=128,
    moe_top_k=8,
    rope_theta=1_000_000.0,
    # beyond-paper long-context SERVING mode (DESIGN.md §4): 500k
    # decode degrades to a 4096 SWA ring cache instead of refusing
    long_serving_window=4096,
    source="hf:Qwen/Qwen3-30B-A3B",
).validate()

SMOKE = smoke_variant(FULL)

# synthetic MRES evaluation record (paper §3.3) — quality/ethics scores are
# calibration-pass stand-ins; cost/latency are replaced by measured roofline
# terms at registration time.
EVAL = dict(accuracy=0.86, helpfulness=0.85, harmlessness=0.88, honesty=0.84,
            steerability=0.80, creativity=0.78,
            task_types=("chat", "code", "reasoning", "summarization"),
            domains=("general", "software", "finance"))
