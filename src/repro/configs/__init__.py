"""Config registry: one module per assigned architecture.

``get_config(name)`` -> full published config;
``get_smoke(name)``  -> reduced same-family variant;
``get_eval(name)``   -> synthetic MRES evaluation record.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, smoke_variant, pad_vocab  # noqa: F401

_MODULES: Dict[str, str] = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-1.3b": "mamba2_1_3b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma2-2b": "gemma2_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama3.2-1b": "llama3_2_1b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).FULL


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def get_eval(name: str) -> dict:
    return dict(_module(name).EVAL)


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
