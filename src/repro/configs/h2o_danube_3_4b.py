"""h2o-danube-3-4b [arXiv:2401.16818]

Llama+mistral mix with sliding-window attention:
24L d_model=3840 32H (kv=8) d_ff=10240 vocab=32000, SWA 4096.
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32_000,
    sliding_window=4096,
    source="arXiv:2401.16818",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.72, helpfulness=0.70, harmlessness=0.74, honesty=0.72,
            steerability=0.62, creativity=0.60,
            task_types=("chat", "summarization", "long-context"),
            domains=("general", "finance"))
