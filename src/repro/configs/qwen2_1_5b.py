"""qwen2-1.5b [arXiv:2407.10671]

Dense GQA with QKV bias: 28L d_model=1536 12H (kv=2) d_ff=8960
vocab=151936.
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    # beyond-paper long-context SERVING mode (DESIGN.md §4): 500k
    # decode degrades to a 4096 SWA ring cache instead of refusing
    long_serving_window=4096,
    source="arXiv:2407.10671",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.68, helpfulness=0.66, harmlessness=0.74, honesty=0.70,
            steerability=0.60, creativity=0.58,
            task_types=("chat", "classification", "summarization"),
            domains=("general", "multilingual"))
