"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf]

VLM backbone (mistral-7b decoder): 32L d_model=4096 32H (kv=8)
d_ff=14336 vocab=32000. The SigLIP/CLIP vision tower + anyres tiling is
stubbed — ``input_specs`` supplies precomputed patch embeddings
(anyres: base 576 + 4 tiles x 576 = 2880 patch tokens).
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    frontend="vision",
    frontend_dim=1024,
    frontend_tokens=2880,
    rope_theta=1_000_000.0,
    # beyond-paper long-context SERVING mode (DESIGN.md §4): 500k
    # decode degrades to a 4096 SWA ring cache instead of refusing
    long_serving_window=4096,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.76, helpfulness=0.74, harmlessness=0.78, honesty=0.72,
            steerability=0.60, creativity=0.62,
            task_types=("vqa", "captioning", "chat"),
            domains=("general", "healthcare"))
