"""mamba2-1.3b [arXiv:2405.21060]

Attention-free SSD (state-space duality): 48L d_model=2048 vocab=50280,
ssm_state=128, expand 2 (d_inner=4096, 64 heads of dim 64).
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    source="arXiv:2405.21060",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.64, helpfulness=0.60, harmlessness=0.70, honesty=0.66,
            steerability=0.50, creativity=0.55,
            task_types=("summarization", "classification", "long-context"),
            domains=("general", "legal"))
