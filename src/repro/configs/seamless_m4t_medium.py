"""seamless-m4t-medium [arXiv:2308.11596]

Enc-dec multimodal (speech/text) backbone: 12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206. The mel/conv speech frontend is stubbed —
``input_specs`` provides precomputed frame embeddings (carve-out).
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    frontend="audio",
    frontend_dim=1024,
    frontend_tokens=0,  # encoder side consumes src_embeds directly
    # beyond-paper long-context SERVING mode (DESIGN.md §4): 500k
    # decode degrades to a 4096 SWA ring cache instead of refusing
    long_serving_window=4096,
    source="arXiv:2308.11596",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.74, helpfulness=0.70, harmlessness=0.86, honesty=0.80,
            steerability=0.55, creativity=0.40,
            task_types=("translation", "transcription"),
            domains=("general", "multilingual"))
