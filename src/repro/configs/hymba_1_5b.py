"""hymba-1.5b [arXiv:2411.13676]

Hybrid-head: parallel attention + mamba heads in every layer.
32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001, ssm_state=16,
sliding-window attention (1024) keeps it sub-quadratic.
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    sliding_window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    source="arXiv:2411.13676",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.66, helpfulness=0.64, harmlessness=0.72, honesty=0.68,
            steerability=0.55, creativity=0.52,
            task_types=("chat", "classification", "long-context"),
            domains=("general",))
