"""gemma2-2b [arXiv:2408.00118]

Dense with alternating local(SWA 4096)/global attention and logit
softcaps: 26L d_model=2304 8H (kv=4) d_ff=9216 vocab=256000.
``long_mode_local_only``: for the long_500k shape, global layers degrade
to the sliding window (documented long-context serving mode).
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab_size=256_000,
    sliding_window=4096,
    local_global_pattern=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    long_mode_local_only=True,
    source="arXiv:2408.00118",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.70, helpfulness=0.72, harmlessness=0.90, honesty=0.78,
            steerability=0.62, creativity=0.60,
            task_types=("chat", "summarization", "classification"),
            domains=("general", "healthcare"))
