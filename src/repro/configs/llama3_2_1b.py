"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B]

Small llama3: 16L d_model=2048 32H (kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="llama3.2-1b",
    arch_type="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128_256,
    rope_theta=500_000.0,
    # beyond-paper long-context SERVING mode (DESIGN.md §4): 500k
    # decode degrades to a 4096 SWA ring cache instead of refusing
    long_serving_window=4096,
    source="hf:meta-llama/Llama-3.2-1B",
).validate()

SMOKE = smoke_variant(FULL)

EVAL = dict(accuracy=0.58, helpfulness=0.56, harmlessness=0.72, honesty=0.62,
            steerability=0.48, creativity=0.50,
            task_types=("chat", "classification", "summarization"),
            domains=("general",))
