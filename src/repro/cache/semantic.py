"""Semantic response cache (the MetaLLM / GPTCache serving win: the
cheapest model call is the one you never make).

``SemanticCache`` stores finished, quality-validated responses in
packed arrays keyed on the same task-vector space the routing kNN
searches:

  vecs       (C, dim) f32   cache-key vectors (preference axes + a
                            hashed text sketch, see ``keys_for``)
  fps        (C,) i64       prefs fingerprints (exact-match gate)
  quality    (C,) f32       validated quality of the stored response
  created    (C,) f64       wall-clock insert time (TTL)
  last_used  (C,) i64       LRU recency tick
  valid      (C,) bool      live-slot mask

A batched lookup is ONE fused similarity + top-1 pass over the whole
packed store: the existing Pallas ``router_topk`` kernel with the
per-query fingerprint-compatibility mask and the similarity threshold
fused in as its ``min_score`` operand (large stores), or the equivalent
masked numpy matmul (small ones).  A row is a hit iff its fingerprint
matches exactly, its TTL has not lapsed, and its cosine similarity
clears ``threshold`` — so a hit short-circuits the analyze -> route ->
admit -> generate path entirely.

Eviction keeps the arrays bounded: expired entries are purged lazily at
lookup/insert time, and a full store evicts the least-recently-used
slot.  Inserts below ``min_quality`` are rejected (a cache must never
replay a response the quality loop would not vouch for), and an insert
that semantically duplicates a live entry refreshes that entry in place
instead of burning a second slot.

Thread-safe; all state round-trips through ``state()``/``load_state``
for ``repro.checkpoint.RouterState``.
"""
from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.preferences import N_METRICS, TaskSignature, resolve
from repro.obs.trace import NOOP_SPAN
from repro.analysis.sanitize import make_lock

# cache_funnel outcome kinds (Telemetry.cache_funnel key set, stable
# even on empty engines): lookup outcomes, then insert outcomes
CACHE_KINDS = ("hit", "miss", "stored", "rejected", "evicted", "expired")


# ----------------------------------------------------------------------
# key construction
# ----------------------------------------------------------------------

def text_sketch(texts: Sequence[str], dims: int = 32) -> np.ndarray:
    """(B, dims) L2-normalized hashed bag-of-words sketches.

    Deterministic across processes (crc32, not python ``hash``), so
    persisted cache entries keep matching after a restart.  Identical
    texts sketch identically; near-duplicates land nearby; unrelated
    texts share only filler mass.
    """
    out = np.zeros((len(texts), dims), np.float32)
    for b, text in enumerate(texts):
        for w in text.split():
            h = zlib.crc32(w.encode())
            sign = 1.0 if (h >> 20) & 1 else -1.0
            out[b, h % dims] += sign
    n = np.linalg.norm(out, axis=1, keepdims=True) + 1e-9
    return out / n


def prefs_fingerprint(prefs_or_profile, extra=None) -> int:
    """Stable int64 fingerprint of the explicit preference weights —
    the exact-match gate of the cache key (a cached answer tuned for
    cost-first must never serve an accuracy-first user).  ``extra``
    mixes additional exact-match request parameters into the gate (the
    serving engine passes the decoding budget: a response generated
    under ``max_new=4`` must never answer a ``max_new=256`` request)."""
    v = resolve(prefs_or_profile).vector()
    h = zlib.crc32(np.ascontiguousarray(v).tobytes())
    if extra is not None:
        h = zlib.crc32(repr(extra).encode(), h)
    return int(np.int64(h))


@dataclass
class CacheEntry:
    """One materialized cache row (what ``get`` hands the engine)."""
    model: str
    response: Any
    quality: float
    sig: TaskSignature


class SemanticCache:
    def __init__(self, capacity: int = 4096, *, threshold: float = 0.95,
                 ttl_s: Optional[float] = None, min_quality: float = 0.5,
                 sketch_dims: int = 32, text_weight: float = 1.0,
                 dim: Optional[int] = None, use_kernel: bool = False,
                 kernel_min_n: int = 1024, quantize: bool = False,
                 tracer=None, time_fn=time.time):
        assert capacity > 0, capacity
        assert -1.0 <= threshold <= 1.0, threshold
        # span sink (obs.trace.Tracer): batched lookups report a
        # "cache_lookup" span nested under the caller's ambient span
        self.tracer = tracer
        self.capacity = int(capacity)
        self.threshold = float(threshold)
        self.ttl_s = None if ttl_s is None else float(ttl_s)
        self.min_quality = float(min_quality)
        self.sketch_dims = int(sketch_dims)
        self.text_weight = float(text_weight)
        self.dim = int(dim) if dim is not None \
            else N_METRICS + self.sketch_dims
        self.use_kernel = use_kernel
        self._kernel_min_n = int(kernel_min_n)
        # mega-store knob: run the kernel lookup on the int8-quantized
        # store (4x fewer key bytes scanned; same bucketed executables)
        # — the threshold gate re-checks on the rescaled fp32 scores,
        # so quantization only perturbs scores near the threshold by
        # the ~1e-2 rounding bound of 8-bit rows
        self.quantize = bool(quantize)
        self._time = time_fn
        self._lock = make_lock("cache.semantic")
        C = self.capacity
        self.vecs = np.zeros((C, self.dim), np.float32)
        self.fps = np.zeros(C, np.int64)
        self.quality = np.zeros(C, np.float32)
        self.created = np.zeros(C, np.float64)
        self.last_used = np.zeros(C, np.int64)
        self.valid = np.zeros(C, bool)
        self.models: List[str] = [""] * C
        self.responses: List[Any] = [None] * C
        self.sigs: List[Optional[TaskSignature]] = [None] * C
        self._tick = 0
        self.counters: Dict[str, int] = {k: 0 for k in CACHE_KINDS}
        # evictions/expiries happen INSIDE lookup/put, invisible to the
        # caller's return value — they queue here until drain_events()
        # forwards them (to Telemetry.cache_funnel)
        self._unreported: Dict[str, int] = {}

    def __len__(self) -> int:
        with self._lock:
            return int(self.valid.sum())

    # ------------------------------------------------------------------
    # key construction bound to this cache's configuration
    # ------------------------------------------------------------------
    def keys_for(self, prefs_batch, texts: Sequence[str]) -> np.ndarray:
        """(B, dim) cache-key vectors: the explicit preference axes
        (the routing task-vector space) concatenated with the hashed
        text sketch at ``text_weight`` — exact repeats score cosine
        1.0, same-prefs-different-task queries fall off with sketch
        distance."""
        prefs = [resolve(p) for p in prefs_batch]
        if len(prefs) != len(texts):
            raise ValueError(f"{len(prefs)} prefs but {len(texts)} texts")
        W = np.stack([p.vector() for p in prefs]).astype(np.float32)
        S = self.text_weight * text_sketch(texts, self.sketch_dims)
        return np.concatenate([W, S], axis=1)

    def fingerprints(self, prefs_batch, extras=None) -> np.ndarray:
        """(B,) int64 prefs fingerprints for ``keys_for``'s batch.
        ``extras`` (B,) optionally mixes per-request exact-match
        parameters (e.g. the decoding budget) into each gate."""
        if extras is None:
            return np.array([prefs_fingerprint(p) for p in prefs_batch],
                            np.int64)
        if len(extras) != len(prefs_batch):
            raise ValueError(f"{len(prefs_batch)} prefs but "
                             f"{len(extras)} extras")
        return np.array([prefs_fingerprint(p, extra=e)
                         for p, e in zip(prefs_batch, extras)], np.int64)

    # ------------------------------------------------------------------
    # lookup
    # ------------------------------------------------------------------
    def _purge_expired_locked(self, now: float) -> None:
        if self.ttl_s is None:
            return
        dead = self.valid & (now - self.created > self.ttl_s)
        n = int(dead.sum())
        if n:
            self.valid[dead] = False
            for j in np.flatnonzero(dead):
                self.responses[j] = None
                self.sigs[j] = None
                self.models[j] = ""
            self.counters["expired"] += n
            self._unreported["expired"] = \
                self._unreported.get("expired", 0) + n

    def _lookup_locked(self, vecs: np.ndarray, fps: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        B = vecs.shape[0]
        assert vecs.shape == (B, self.dim), (vecs.shape, self.dim)
        assert fps.shape == (B,), fps.shape
        self._purge_expired_locked(self._time())
        mask = self.valid[None, :] & (fps[:, None] == self.fps[None, :])
        if not mask.any():
            sim = np.full(B, -np.inf, np.float32)
            slot = np.full(B, -1, np.int64)
            hit = np.zeros(B, bool)
        elif self.use_kernel and self.capacity >= self._kernel_min_n:
            # bucketed dispatch (the routing hot path's shape policy):
            # the store's capacity axis is already static, and padding
            # the query axis to its power-of-two bucket means a stream
            # of varying batch sizes replays ONE cached executable per
            # bucket instead of recompiling per batch size
            from repro.kernels import ops as K
            vals, idx = K.router_topk_bucketed(self.vecs, vecs, 1,
                                               mask=mask,
                                               min_score=self.threshold,
                                               quant=self.quantize)
            sim = np.asarray(vals)[:, 0]
            slot = np.asarray(idx)[:, 0].astype(np.int64)
            hit = np.isfinite(sim)
        else:
            # score live slots only: a mostly-empty store must not pay
            # a full-capacity matmul per batch on the serving hot path
            cols = np.flatnonzero(self.valid)
            live = self.vecs[cols]
            en = np.linalg.norm(live, axis=1) + 1e-9
            qn = np.linalg.norm(vecs, axis=1) + 1e-9
            sims = (vecs / qn[:, None]) @ (live / en[:, None]).T
            sims = np.where(mask[:, cols], sims, -np.inf)
            best = sims.argmax(axis=1)
            sim = sims[np.arange(B), best].astype(np.float32)
            slot = cols[best].astype(np.int64)
            hit = np.isfinite(sim) & (sim >= self.threshold)
        slot = np.where(hit, slot, -1)
        sim = np.where(hit, sim, -np.inf).astype(np.float32)
        for j in slot[hit]:
            self._tick += 1
            self.last_used[j] = self._tick
        nh = int(hit.sum())
        self.counters["hit"] += nh
        self.counters["miss"] += B - nh
        return hit, slot, sim

    def lookup(self, vecs: np.ndarray, fps: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched lookup: (hit (B,) bool, slot (B,) i64, sim (B,) f32).

        One fused similarity + top-1 pass over the packed store with
        the per-query fingerprint mask and the similarity threshold
        fused in; hits refresh LRU recency.  ``slot`` is -1 (and sim
        -inf) on misses.  A returned slot index is only stable until
        the next concurrent insert/expiry — concurrent callers should
        use ``lookup_entries``, which materializes under the lock.
        """
        with self._lock:
            return self._lookup_locked(np.asarray(vecs, np.float32),
                                       np.asarray(fps, np.int64))

    def lookup_entries(self, vecs: np.ndarray, fps: np.ndarray
                       ) -> Tuple[np.ndarray, list, np.ndarray]:
        """(hit (B,), entries (B,) list of CacheEntry|None, sim (B,)).

        Like ``lookup`` but hit rows are materialized under the SAME
        lock, so a concurrent put/eviction/expiry between lookup and
        get can never invalidate a hit mid-serve."""
        span = self.tracer.span("cache_lookup",
                                batch=int(np.asarray(vecs).shape[0])) \
            if self.tracer is not None else NOOP_SPAN
        with span:
            with self._lock:
                hit, slot, sim = self._lookup_locked(
                    np.asarray(vecs, np.float32),
                    np.asarray(fps, np.int64))
                entries = [self._entry_locked(int(s)) if h else None
                           for h, s in zip(hit, slot)]
            span.set(hits=int(np.asarray(hit).sum()))
        return hit, entries, sim

    def _entry_locked(self, slot: int) -> CacheEntry:
        assert 0 <= slot < self.capacity and self.valid[slot], slot
        return CacheEntry(model=self.models[slot],
                          response=self.responses[slot],
                          quality=float(self.quality[slot]),
                          sig=self.sigs[slot] or TaskSignature())

    def get(self, slot: int) -> CacheEntry:
        with self._lock:
            return self._entry_locked(slot)

    # ------------------------------------------------------------------
    # insert
    # ------------------------------------------------------------------
    def put(self, vec: np.ndarray, fp: int, model: str, response: Any,
            quality: float, sig: Optional[TaskSignature] = None) -> str:
        """Insert one validated response.  Returns the outcome kind:
        ``rejected`` (quality below the bar), ``stored`` (fresh slot or
        in-place refresh of a semantic duplicate), with ``evicted`` /
        ``expired`` counted internally when slots are reclaimed."""
        vec = np.asarray(vec, np.float32).reshape(self.dim)
        quality = float(quality)
        with self._lock:
            now = self._time()
            self._purge_expired_locked(now)
            if quality < self.min_quality:
                self.counters["rejected"] += 1
                return "rejected"
            self._tick += 1
            # semantic duplicate -> refresh in place (never two slots
            # answering the same query; keep the better response)
            live = self.valid & (self.fps == fp)
            j = -1
            if live.any():
                en = np.linalg.norm(self.vecs[live], axis=1) + 1e-9
                qn = float(np.linalg.norm(vec)) + 1e-9
                sims = (self.vecs[live] @ vec) / (en * qn)
                best = int(sims.argmax())
                if sims[best] >= self.threshold:
                    j = int(np.flatnonzero(live)[best])
                    if quality < self.quality[j]:
                        # keep the stronger stored response; still a
                        # store (recency refreshed, entry stays warm)
                        self.last_used[j] = self._tick
                        self.counters["stored"] += 1
                        return "stored"
            if j < 0:
                free = np.flatnonzero(~self.valid)
                if free.size:
                    j = int(free[0])
                else:                       # full: evict the LRU slot
                    j = int(np.argmin(np.where(self.valid, self.last_used,
                                               np.iinfo(np.int64).max)))
                    self.counters["evicted"] += 1
                    self._unreported["evicted"] = \
                        self._unreported.get("evicted", 0) + 1
            self.vecs[j] = vec
            self.fps[j] = int(fp)
            self.quality[j] = quality
            self.created[j] = now
            self.last_used[j] = self._tick
            self.valid[j] = True
            self.models[j] = str(model)
            self.responses[j] = response
            self.sigs[j] = sig
            self.counters["stored"] += 1
            return "stored"

    # ------------------------------------------------------------------
    # stats & persistence
    # ------------------------------------------------------------------
    def drain_events(self) -> Dict[str, int]:
        """Internal outcome counts (``evicted`` / ``expired``) accrued
        since the last drain — the serving layer forwards these to
        ``Telemetry.record_cache`` so the funnel sees capacity churn,
        not just hit/miss/store traffic."""
        with self._lock:
            out, self._unreported = self._unreported, {}
            return out

    def hit_rate(self) -> float:
        with self._lock:
            n = self.counters["hit"] + self.counters["miss"]
            return self.counters["hit"] / n if n else 0.0

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            n = self.counters["hit"] + self.counters["miss"]
            return {"entries": int(self.valid.sum()),
                    "capacity": self.capacity,
                    "hit_rate": self.counters["hit"] / n if n else 0.0,
                    **dict(self.counters)}

    def state(self) -> Dict[str, Any]:
        """Everything ``load_state`` needs to resume bit-exactly."""
        with self._lock:
            return {
                "vecs": self.vecs.copy(), "fps": self.fps.copy(),
                "quality": self.quality.copy(),
                "created": self.created.copy(),
                "last_used": self.last_used.copy(),
                "valid": self.valid.copy(), "tick": self._tick,
                "models": list(self.models),
                "responses": list(self.responses),
                "sigs": [None if s is None else
                         (s.task_type, s.domain, s.complexity,
                          s.confidence) for s in self.sigs],
            }

    def load_state(self, st: Dict[str, Any]) -> None:
        """Restore a ``state()`` snapshot into THIS cache's configured
        capacity: a same-size snapshot restores slot-for-slot
        (bit-exact); a differently-sized one has its live entries
        compacted into the configured arrays, so restoring an old
        snapshot never silently shrinks (or grows) a reconfigured
        cache.  Raises when the snapshot holds more live entries than
        the capacity can hold."""
        vecs = np.asarray(st["vecs"], np.float32)
        C, dim = vecs.shape
        if dim != self.dim:
            raise ValueError(f"cache dim mismatch: snapshot {dim}, "
                             f"cache {self.dim}")
        valid = np.asarray(st["valid"], bool)
        sigs = [None if s is None else
                TaskSignature(task_type=str(s[0]), domain=str(s[1]),
                              complexity=float(s[2]),
                              confidence=float(s[3]))
                for s in st["sigs"]]
        with self._lock:
            K = self.capacity
            src = np.arange(C) if C == K else np.flatnonzero(valid)
            if src.size > K:
                raise ValueError(f"snapshot holds {src.size} live "
                                 f"entries but cache capacity is {K}")
            n = src.size
            self.vecs = np.zeros((K, self.dim), np.float32)
            self.vecs[:n] = vecs[src]
            self.fps = np.zeros(K, np.int64)
            self.fps[:n] = np.asarray(st["fps"], np.int64)[src]
            self.quality = np.zeros(K, np.float32)
            self.quality[:n] = np.asarray(st["quality"], np.float32)[src]
            self.created = np.zeros(K, np.float64)
            self.created[:n] = np.asarray(st["created"], np.float64)[src]
            self.last_used = np.zeros(K, np.int64)
            self.last_used[:n] = np.asarray(st["last_used"],
                                            np.int64)[src]
            self.valid = np.zeros(K, bool)
            self.valid[:n] = valid[src]
            self._tick = int(st["tick"])
            models = list(st["models"])
            responses = list(st["responses"])
            self.models = [str(models[j]) for j in src] + \
                [""] * (K - n)
            self.responses = [responses[j] for j in src] + \
                [None] * (K - n)
            self.sigs = [sigs[j] for j in src] + [None] * (K - n)
