"""Semantic response cache: avoid the route -> generate path entirely
for repeated / near-duplicate queries (keyed on the routing task-vector
space, answered by the same fused Pallas top-k the router uses)."""
from repro.cache.semantic import (CACHE_KINDS, CacheEntry, SemanticCache,
                                  prefs_fingerprint, text_sketch)

__all__ = ["CACHE_KINDS", "CacheEntry", "SemanticCache",
           "prefs_fingerprint", "text_sketch"]
