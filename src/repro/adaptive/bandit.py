"""Contextual-bandit model-quality learner (PickLLM / RouteLLM-style
online routing, layered onto the paper's static MRES scores).

One linear-bandit posterior per catalog model, kept as PACKED arrays so
a whole batch learns in one fused pass:

  A      (N, D, D)  regularized scatter matrices  (lam * I prior)
  b      (N, D)     reward-weighted context sums
  theta  (N, D)     ridge estimates A^{-1} b      (cached)
  Ainv   (N, D, D)  precision inverses            (cached)

The context of a query is its (M,) routing task vector (user preference
weights with the accuracy axis raised to the analyzed complexity) plus
an intercept, so D = M + 1: the intercept learns each model's base
quality and the weight axes learn how quality co-varies with what the
user asked for.

Policies over the shared posterior:

  * ``linucb``   — score = x.theta + alpha * sqrt(x^T Ainv x)
  * ``thompson`` — score = x.theta~ with theta~ ~ N(theta, noise^2 Ainv)

Non-stationarity is handled by exponential forgetting (``forget`` < 1
decays A toward the lam*I prior and b toward 0 on every outcome batch),
so the posterior tracks drifting model quality instead of averaging
over it.

The hot path is array-first throughout: ``scores`` is two einsums,
``update`` one masked einsum pair, and ``update_and_score`` fuses the
rank-1 posterior updates with the next batch's UCB scoring matmul in a
single Pallas ``bandit_update`` kernel call (``use_kernel=True``), with
the numpy einsum path as the small-catalog / parity reference.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.preferences import N_METRICS

POLICIES = ("linucb", "thompson")


class LinearBandit:
    def __init__(self, n_models: int, context_dim: int = N_METRICS, *,
                 policy: str = "linucb", alpha: float = 0.8,
                 lam: float = 1.0, noise: float = 0.3,
                 forget: float = 1.0, seed: int = 0,
                 use_kernel: bool = False, kernel_min_n: int = 256):
        assert policy in POLICIES, policy
        assert 0.0 < forget <= 1.0, forget
        self.policy = policy
        self.alpha = float(alpha)
        self.lam = float(lam)
        self.noise = float(noise)
        self.forget = float(forget)
        self.use_kernel = use_kernel
        self._kernel_min_n = kernel_min_n
        self._rng = np.random.default_rng(seed)
        self.dim = context_dim + 1                     # + intercept
        self.n_models = 0
        self.A = np.zeros((0, self.dim, self.dim), np.float32)
        self.b = np.zeros((0, self.dim), np.float32)
        self.counts = np.zeros(0, np.int64)
        self._theta: Optional[np.ndarray] = None
        self._ainv: Optional[np.ndarray] = None
        self._zeros: Optional[Tuple[np.ndarray, ...]] = None
        self.ensure(n_models)

    # ---------------- capacity ----------------
    def ensure(self, n_models: int) -> None:
        """Grow to ``n_models`` arms (fresh lam*I priors for new ones) —
        keeps the bandit consistent when the catalog grows (merging)."""
        if n_models <= self.n_models:
            return
        grow = n_models - self.n_models
        eye = np.broadcast_to(self.lam * np.eye(self.dim, dtype=np.float32),
                              (grow, self.dim, self.dim))
        self.A = np.concatenate([self.A, eye.copy()], axis=0)
        self.b = np.concatenate(
            [self.b, np.zeros((grow, self.dim), np.float32)], axis=0)
        self.counts = np.concatenate(
            [self.counts, np.zeros(grow, np.int64)])
        self.n_models = n_models
        self._theta = self._ainv = None

    # ---------------- posterior ----------------
    def _ctx(self, X: np.ndarray) -> np.ndarray:
        """(B, M) task vectors -> (B, D) contexts with intercept."""
        X = np.asarray(X, np.float32)
        assert X.ndim == 2 and X.shape[1] == self.dim - 1, \
            (X.shape, self.dim)
        return np.concatenate(
            [X, np.ones((X.shape[0], 1), np.float32)], axis=1)

    def _refresh(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._theta is None or self._ainv is None:
            self._ainv = np.linalg.inv(self.A).astype(np.float32)
            self._theta = np.einsum("nde,ne->nd", self._ainv,
                                    self.b).astype(np.float32)
        return self._theta, self._ainv

    @property
    def theta(self) -> np.ndarray:
        """(N, D) current per-model reward estimates."""
        return self._refresh()[0]

    def posterior(self) -> Tuple[np.ndarray, np.ndarray]:
        """(theta (N, D), Ainv (N, D, D)) under the current posterior —
        the arrays the fused ``route_step`` program scores LinUCB
        against on device."""
        return self._refresh()

    def predict(self, X: np.ndarray) -> np.ndarray:
        """(B, N) posterior-mean reward estimates (no exploration)."""
        theta, _ = self._refresh()
        return self._ctx(X) @ theta.T

    def scores(self, X: np.ndarray) -> np.ndarray:
        """(B, N) policy scores for (B, M) task-vector contexts."""
        return self.scores_at(X, None)

    def scores_at(self, X: np.ndarray, cols: Optional[np.ndarray]
                  ) -> np.ndarray:
        """(B, C) policy scores restricted to the ``cols`` model subset
        (None = all models) — the routing hot path only needs the kNN
        candidate columns, so cost stays proportional to C, not N."""
        theta, ainv = self._refresh()
        ctx = self._ctx(X)
        if cols is not None:
            theta, ainv = theta[cols], ainv[cols]
        if self.policy == "thompson":
            # theta~ = theta + noise * L z,  L L^T = Ainv
            L = np.linalg.cholesky(
                ainv + 1e-6 * np.eye(self.dim, dtype=np.float32))
            z = self._rng.standard_normal(
                (theta.shape[0], self.dim)).astype(np.float32)
            theta = theta + self.noise * np.einsum("nde,ne->nd", L, z)
            return ctx @ theta.T
        mean = ctx @ theta.T                                    # (B, C)
        # x^T Ainv x over the flattened rank-1 layout: one BLAS matmul
        # (B, D^2) x (D^2, C), same shape the Pallas kernel uses
        xx = (ctx[:, :, None] * ctx[:, None, :]).reshape(ctx.shape[0], -1)
        var = xx @ ainv.reshape(theta.shape[0], -1).T
        return mean + self.alpha * np.sqrt(np.maximum(var, 0.0))

    # ---------------- learning ----------------
    def _choice_mask(self, chosen: np.ndarray, B: int) -> np.ndarray:
        chosen = np.asarray(chosen)
        assert chosen.shape == (B,), (chosen.shape, B)
        assert (chosen >= 0).all() and (chosen < self.n_models).all(), chosen
        w = np.zeros((B, self.n_models), np.float32)
        w[np.arange(B), chosen] = 1.0
        return w

    def _apply(self, dA: np.ndarray, db: np.ndarray,
               w: np.ndarray) -> None:
        if self.forget < 1.0:
            eye = self.lam * np.eye(self.dim, dtype=np.float32)
            self.A = self.forget * (self.A - eye) + eye
            self.b = self.forget * self.b
        self.A += dA
        self.b += db
        self.counts += w.sum(axis=0).astype(np.int64)
        self._theta = self._ainv = None

    def update(self, X: np.ndarray, chosen: np.ndarray,
               rewards: np.ndarray) -> None:
        """Fold one outcome batch into the posterior.

        X (B, M) task vectors; chosen (B,) catalog indices served;
        rewards (B,) shaped rewards observed.
        """
        ctx = self._ctx(X)
        B = ctx.shape[0]
        if B == 0:
            return
        r = np.asarray(rewards, np.float32)
        w = self._choice_mask(chosen, B)
        if (self.use_kernel and self.policy == "linucb"
                and self.n_models >= self._kernel_min_n):
            # Pallas path (the serving stack's learning step when
            # use_kernel is on): dA/db from the fused kernel with a
            # dummy scoring batch — theta/ainv only feed the discarded
            # ucb output, so cached zeros avoid a posterior refresh
            from repro.kernels import ops as K
            if self._zeros is None or self._zeros[1].shape[0] != \
                    self.n_models:
                self._zeros = (
                    np.zeros((1, self.dim), np.float32),
                    np.zeros((self.n_models, self.dim), np.float32),
                    np.zeros((self.n_models, self.dim, self.dim),
                             np.float32))
            zD, zN, zA = self._zeros
            dA, db, _ = K.bandit_update(ctx, w, r, zD, zN, zA, 0.0)
            self._apply(np.asarray(dA), np.asarray(db), w)
            return
        # rank-1 sums as flattened matmuls (the kernel's layout):
        # dA = W^T @ XX, db = W^T @ (r * X)
        xx = (ctx[:, :, None] * ctx[:, None, :]).reshape(B, -1)
        dA = (w.T @ xx).reshape(self.n_models, self.dim, self.dim)
        db = w.T @ (ctx * r[:, None])
        self._apply(dA, db, w)

    # ---------------- persistence (RouterState) ----------------
    def state(self) -> dict:
        """Sufficient statistics snapshot: (A, b, counts) determine the
        whole posterior (theta/Ainv are derived caches)."""
        return {"A": self.A.copy(), "b": self.b.copy(),
                "counts": self.counts.copy()}

    def load_state(self, state: dict) -> None:
        """Restore a ``state()`` snapshot, REPLACING the posterior."""
        A = np.asarray(state["A"], np.float32)
        if A.ndim != 3 or A.shape[1:] != (self.dim, self.dim):
            raise ValueError(f"bandit dim mismatch: snapshot {A.shape}, "
                             f"expected (*, {self.dim}, {self.dim})")
        self.A = A.copy()
        self.b = np.asarray(state["b"], np.float32).copy()
        self.counts = np.asarray(state["counts"], np.int64).copy()
        self.n_models = int(A.shape[0])
        self._theta = self._ainv = None
        self._zeros = None

    def update_and_score(self, X_up: np.ndarray, chosen: np.ndarray,
                         rewards: np.ndarray, X_score: np.ndarray
                         ) -> np.ndarray:
        """Serving-cadence fused step: score the incoming batch under
        the CURRENT posterior, then fold the finished batch's outcomes
        in.  On the kernel path both halves are one Pallas
        ``bandit_update`` call; the numpy path is decision-identical.
        Returns the (Bs, N) scores.
        """
        ctx_up = self._ctx(X_up)
        B = ctx_up.shape[0]
        w = self._choice_mask(chosen, B) if B else \
            np.zeros((0, self.n_models), np.float32)
        r = np.asarray(rewards, np.float32)
        if (self.use_kernel and self.policy == "linucb"
                and self.n_models >= self._kernel_min_n):
            from repro.kernels import ops as K
            theta, ainv = self._refresh()
            dA, db, ucb = K.bandit_update(
                ctx_up, w, r, self._ctx(X_score), theta, ainv, self.alpha)
            if B:                # empty batch: no update, no forgetting
                self._apply(np.asarray(dA), np.asarray(db), w)
            return np.asarray(ucb)
        s = self.scores(X_score)
        self.update(X_up, chosen, rewards)
        return s
