"""Online adaptive routing: contextual bandits that learn per-model
quality from live traffic and blend into the static MRES scores."""
from repro.adaptive.bandit import POLICIES, LinearBandit
from repro.adaptive.rewards import RewardConfig, RewardShaper

__all__ = ["LinearBandit", "POLICIES", "RewardConfig", "RewardShaper"]
