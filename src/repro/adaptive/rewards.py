"""Reward shaping for the adaptive routing loop.

The bandit should not maximize raw answer quality alone — the paper's
whole point is the performance/cost/ethics trade-off — so observed
quality is penalized by what serving the query actually cost:

  reward = quality
           - cost_weight    * normalized(cost of the serving model)
           - latency_weight * normalized(latency of the serving model)

Cost/latency default to the catalog's raw metrics (the same numbers
telemetry records as ``sim_cost`` per routed event), normalized min-max
across the catalog exactly like the MRES embeddings; callers with
realized telemetry (e.g. measured generate latency) can override
per-query.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np


@dataclass
class RewardConfig:
    cost_weight: float = 0.15
    latency_weight: float = 0.1
    clip: bool = True                 # clip shaped rewards into [-1, 1]


def _minmax(col: np.ndarray) -> np.ndarray:
    lo, hi = float(col.min()), float(col.max())
    if hi - lo < 1e-12:
        return np.zeros_like(col)
    return (col - lo) / (hi - lo)


class RewardShaper:
    """Per-model cost/latency penalties over an MRES catalog."""

    def __init__(self, mres, cfg: Optional[RewardConfig] = None):
        self.mres = mres
        self.cfg = cfg if cfg is not None else RewardConfig()
        self._n = -1
        self._penalty = np.zeros(0, np.float32)
        self.refresh()

    def refresh(self) -> None:
        """Rebuild the (N,) penalty vector from the catalog metrics."""
        entries = self.mres.entries
        if len(entries) == self._n:
            return
        cost = np.array([e.raw_metrics.get("cost_per_mtok", 0.0)
                         for e in entries], np.float64)
        lat = np.array([e.raw_metrics.get("latency_ms", 0.0)
                        for e in entries], np.float64)
        self._penalty = (self.cfg.cost_weight * _minmax(cost)
                         + self.cfg.latency_weight * _minmax(lat)
                         ).astype(np.float32)
        self._n = len(entries)

    def shape(self, qualities: Sequence[float], model_idx: np.ndarray,
              extra_penalty: Optional[np.ndarray] = None) -> np.ndarray:
        """(B,) shaped rewards for qualities observed on ``model_idx``.

        ``extra_penalty`` (B,) adds realized per-query penalties (e.g.
        normalized measured latency) on top of the catalog-derived ones.
        """
        self.refresh()
        r = (np.asarray(qualities, np.float32)
             - self._penalty[np.asarray(model_idx)])
        if extra_penalty is not None:
            r = r - np.asarray(extra_penalty, np.float32)
        return np.clip(r, -1.0, 1.0) if self.cfg.clip else r

    def penalty_row(self) -> np.ndarray:
        """(N,) catalog penalty vector (for oracle/regret accounting)."""
        self.refresh()
        return self._penalty.copy()
