"""Unified model: one composable implementation covering all six
architecture families (dense / moe / ssm / hybrid / encdec / vlm / audio).

Design:
  * params are nested dicts; per-layer params are STACKED along a
    leading ``n_layers`` axis and the stack runs under ``lax.scan``.
  * three entry points, all pure functions of (params, batch):
      - ``forward_full``  : full-sequence logits (training / prefill)
      - ``prefill``       : forward_full + build the decode cache
      - ``decode_step``   : one token against the cache
  * gemma2's local/global alternation is a scanned ``layer_kind`` array;
    local layers mask to the sliding window inside a uniform cache.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# activation sharding constraints
# ----------------------------------------------------------------------

def _act_constraint(x, *, vocab_axis: bool = False):
    """Pin activations to (batch over data axes, ..., vocab over model).

    Without explicit constraints GSPMD propagates the FSDP weight
    layouts into activations — at the LM head it gathered the FULL
    batch of f32 logits (67 GB/device for 256k vocabs; EXPERIMENTS
    §Perf, gemma2 hillclimb).  No-op outside a mesh context (plain
    jit in unit tests) and on non-divisible axes.
    """
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is not None:
        mesh = get_mesh()
    else:   # jax < 0.5: the context mesh lives in thread resources
        mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
    if mesh.empty or "data" not in mesh.axis_names:
        return x
    da = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    da_size = 1
    for a in da:
        da_size *= mesh.shape[a]
    b_ax = da if x.shape[0] % da_size == 0 else None
    spec = [b_ax] + [None] * (x.ndim - 1)
    if vocab_axis and x.shape[-1] % mesh.shape["model"] == 0:
        spec[-1] = "model"
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


# ======================================================================
# init
# ======================================================================

def _init_decoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {"ln_mix": jnp.zeros((cfg.d_model,), dt),
                 "ln_mlp": jnp.zeros((cfg.d_model,), dt)}
    if cfg.has_attention:
        p["attn"] = L.init_attention(ks[0], cfg)
    if cfg.has_ssm:
        p["ssm"] = L.init_ssm(ks[1], cfg)
    if cfg.arch_type == "hybrid":
        p["ln_attn_out"] = jnp.zeros((cfg.d_model,), dt)
        p["ln_ssm_out"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.is_moe:
        p["moe"] = L.init_moe(ks[2], cfg)
    elif cfg.d_ff > 0:
        p["mlp"] = L.init_mlp(ks[2], cfg)
    if cfg.is_encdec:
        p["cross"] = L.init_attention(ks[3], cfg, cross=True)
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dt)
    return p


def _init_encoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "attn": L.init_attention(ks[0], cfg),
        "mlp": L.init_mlp(ks[1], cfg),
        "ln_mix": jnp.zeros((cfg.d_model,), dt),
        "ln_mlp": jnp.zeros((cfg.d_model,), dt),
    }


def layer_kinds(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention kind: 0 = local/SWA, 1 = global/full."""
    if cfg.local_global_pattern:
        return (jnp.arange(cfg.n_layers) % 2).astype(jnp.int32)
    if cfg.sliding_window > 0:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    return jnp.ones((cfg.n_layers,), jnp.int32)


def init_params(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers, k_enc, k_front = jax.random.split(key, 4)
    params: Params = {
        "embed": L.dense_init(k_embed, (cfg.vocab_padded, cfg.d_model), dt, scale=0.02),
        "ln_f": jnp.zeros((cfg.d_model,), dt),
        "layers": jax.vmap(lambda k: _init_decoder_layer(k, cfg))(
            jax.random.split(k_layers, cfg.n_layers)),
    }
    if cfg.is_encdec:
        params["enc_layers"] = jax.vmap(lambda k: _init_encoder_layer(k, cfg))(
            jax.random.split(k_enc, cfg.n_enc_layers))
        params["ln_enc"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.frontend:
        params["front_proj"] = {
            "w": L.dense_init(k_front, (cfg.frontend_dim, cfg.d_model), dt),
            "b": jnp.zeros((cfg.d_model,), dt),
        }
    return params


# ======================================================================
# full-sequence forward (train / prefill)
# ======================================================================

def _mix_full(p, cfg: ModelConfig, x, positions, kind, long_mode: bool):
    """Sequence mixer (attention and/or SSM) over a full sequence.

    Returns (out, kv, ssd) — kv is (k, v) for cacheable attention,
    ssd is (final_state, conv_state) for SSM mixers; either may be None.
    """
    h = L.rms_norm(x, p["ln_mix"])
    kv = None
    ssd = None
    attn_out = None
    if cfg.has_attention:
        if cfg.attn_impl == "blocked":
            attn_out, kv = L.attention_blocked(p["attn"], cfg, h, positions,
                                               kind=kind, long_mode=long_mode)
        else:                                    # "naive" — paper baseline
            Lq = h.shape[1]
            iq = jnp.arange(Lq)[:, None]
            ik = jnp.arange(Lq)[None, :]
            causal = ik <= iq
            W = cfg.sliding_window
            if W and (not cfg.local_global_pattern or long_mode):
                mask = causal & (ik > iq - W)
            elif cfg.local_global_pattern:
                local = causal & (ik > iq - W)
                mask = jnp.where(kind == 0, local, causal)
            else:
                mask = causal
            attn_out, kv = _attention_full_masked(p["attn"], cfg, h,
                                                  positions, mask)
    if cfg.has_ssm:
        ssm_out, h_final, conv_state = L.ssd_chunked(p["ssm"], cfg, h)
        ssd = (h_final, conv_state)
        if attn_out is None:
            return ssm_out, kv, ssd
        # hybrid: per-branch output norm, then mean (Hymba-style fusion)
        fused = 0.5 * (L.rms_norm(attn_out, p["ln_attn_out"])
                       + L.rms_norm(ssm_out, p["ln_ssm_out"]))
        return fused, kv, ssd
    return attn_out, kv, ssd


def _attention_full_masked(p, cfg, h, positions, mask):
    """attention_full with an explicit (Lq, Lk) bool mask."""
    q = L._split_heads(h @ p["wq"] + p.get("bq", 0), cfg.n_heads, cfg.head_dim)
    k = L._split_heads(h @ p["wk"] + p.get("bk", 0), cfg.n_kv_heads, cfg.head_dim)
    v = L._split_heads(h @ p["wv"] + p.get("bv", 0), cfg.n_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    scores = L.gqa_scores(q, k).astype(jnp.float32)
    scores = L.softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    out = L.gqa_values(probs, v)
    out = out.reshape(out.shape[:2] + (cfg.q_dim,)) @ p["wo"]
    return out, (k, v)


def _ffn(p, cfg: ModelConfig, x):
    """Feed-forward half of the block. Returns (y, aux)."""
    h = L.rms_norm(x, p["ln_mlp"])
    if cfg.is_moe:
        y, aux = L.moe_block(p["moe"], cfg, h)
    elif cfg.d_ff > 0:
        y, aux = L.mlp(p["mlp"], h), 0.0
    else:
        return x, 0.0
    return x + y, aux


def _decoder_layer_full(p, cfg, x, positions, kind, enc_out, long_mode):
    mix, kv, ssd = _mix_full(p, cfg, x, positions, kind, long_mode)
    x = x + mix
    if cfg.is_encdec and enc_out is not None:
        h = L.rms_norm(x, p["ln_cross"])
        cross, cross_kv = L.attention_full(p["cross"], cfg, h, positions,
                                           kv_x=enc_out, causal=False, rope=False)
        x = x + cross
    else:
        cross_kv = None
    x, aux = _ffn(p, cfg, x)
    return x, kv, cross_kv, ssd, aux


def encode(params, cfg: ModelConfig, src_embeds):
    """Encoder stack over (projected) frontend embeddings."""
    x = _project_frontend(params, cfg, src_embeds)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])

    def body(x, p):
        h = L.rms_norm(x, p["ln_mix"])
        if cfg.attn_impl == "blocked":
            out, _ = L.attention_blocked(p["attn"], cfg, h, pos, causal=False)
        else:
            out, _ = L.attention_full(p["attn"], cfg, h, pos, causal=False)
        x = x + out
        x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln_mlp"]))
        x = _act_constraint(x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["ln_enc"])


def _project_frontend(params, cfg, embeds):
    fp = params["front_proj"]
    return (embeds.astype(jnp.dtype(cfg.compute_dtype)) @ fp["w"] + fp["b"])


def cast_params(params: Params, cfg: ModelConfig) -> Params:
    """Cast float params to the compute dtype (master weights stay f32)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    return jax.tree_util.tree_map(
        lambda p: p.astype(cdt) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params)


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Token (+ frontend) embedding. Returns (x, positions)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    tok = params["embed"][batch["tokens"]].astype(cdt)
    if cfg.frontend and not cfg.is_encdec:
        front = _project_frontend(params, cfg, batch["frontend"]).astype(cdt)
        x = jnp.concatenate([front, tok], axis=1)
    else:
        x = tok
    B, Ltot = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Ltot, dtype=jnp.int32)[None], (B, Ltot))
    return x, positions


def forward_full(params, cfg: ModelConfig, batch, *, long_mode: bool = False,
                 collect_cache: bool = False):
    """Full-sequence forward.

    batch: {"tokens": (B, L)} plus "frontend"/"src_embeds" as the family
    requires. Returns (logits, aux_loss, cache_parts_or_None).
    """
    params = cast_params(params, cfg)
    x, positions = _embed_inputs(params, cfg, batch)
    enc_out = encode(params, cfg, batch["src_embeds"]) if cfg.is_encdec else None
    kinds = layer_kinds(cfg)

    def body(carry, per):
        x, aux = carry
        p, kind = per
        x, kv, cross_kv, ssd, aux_i = _decoder_layer_full(p, cfg, x, positions,
                                                          kind, enc_out, long_mode)
        x = _act_constraint(x)
        ys = (kv, cross_kv, ssd) if collect_cache else (None, None, None)
        return (x, aux + aux_i), ys

    body_fn = jax.checkpoint(body) if (cfg.remat and not collect_cache) else body
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                                    (params["layers"], kinds))
    x = L.rms_norm(x, params["ln_f"])
    logits = x @ params["embed"].T.astype(x.dtype)
    logits = _act_constraint(logits, vocab_axis=True)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux, caches


# ======================================================================
# decode cache
# ======================================================================

def cache_len(cfg: ModelConfig, ctx_len: int, long_mode: bool = False) -> int:
    if not cfg.has_attention:
        return 0
    if cfg.sliding_window and (not cfg.local_global_pattern or long_mode):
        return min(cfg.sliding_window, ctx_len)
    return ctx_len


def init_cache(cfg: ModelConfig, batch_size: int, ctx_len: int, *,
               long_mode: bool = False, enc_len: int = 0,
               dtype: Optional[str] = None) -> Params:
    """Zero-initialized decode cache pytree (leading axis = n_layers)."""
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    nL, B = cfg.n_layers, batch_size
    cache: Params = {}
    C = cache_len(cfg, ctx_len, long_mode)
    int8 = cfg.kv_cache_dtype == "int8"
    if C:
        kv_dt = jnp.int8 if int8 else dt
        cache["k"] = jnp.zeros((nL, B, C, cfg.n_kv_heads, cfg.head_dim), kv_dt)
        cache["v"] = jnp.zeros((nL, B, C, cfg.n_kv_heads, cfg.head_dim), kv_dt)
        if int8:
            cache["k_scale"] = jnp.zeros((nL, B, C, cfg.n_kv_heads, 1),
                                         jnp.float32)
            cache["v_scale"] = jnp.zeros((nL, B, C, cfg.n_kv_heads, 1),
                                         jnp.float32)
    if cfg.has_ssm:
        cache["ssd"] = jnp.zeros((nL, B, cfg.ssm_heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        cache["conv"] = jnp.zeros((nL, B, cfg.ssm_conv_width - 1, conv_dim), dt)
    if cfg.is_encdec:
        cache["cross_k"] = jnp.zeros((nL, B, enc_len, cfg.n_kv_heads, cfg.head_dim), dt)
        cache["cross_v"] = jnp.zeros((nL, B, enc_len, cfg.n_kv_heads, cfg.head_dim), dt)
    return cache


def _mix_decode(p, cfg: ModelConfig, x, cache_slice, positions, kind, long_mode):
    """One-token mixer against this layer's cache slice."""
    h = L.rms_norm(x, p["ln_mix"])
    new_slice = dict(cache_slice)
    attn_out = None
    if cfg.has_attention and "k" in cache_slice:
        C = cache_slice["k"].shape[1]
        ring = bool(cfg.sliding_window) and (not cfg.local_global_pattern or long_mode)
        int8 = "k_scale" in cache_slice
        scales = ({"k_scale": cache_slice["k_scale"],
                   "v_scale": cache_slice["v_scale"]} if int8 else {})
        res = L.attention_decode(
            p["attn"], cfg, h, cache_slice["k"], cache_slice["v"], positions,
            window=C if ring else 0, attn_softcap=cfg.attn_softcap, **scales)
        if int8:
            out, k_new, v_new, ks_new, vs_new = res
            new_slice["k_scale"], new_slice["v_scale"] = ks_new, vs_new
        else:
            out, k_new, v_new = res
        if cfg.local_global_pattern and not long_mode and cfg.sliding_window:
            # local layers additionally mask to the window inside the full cache
            scales2 = ({"k_scale": new_slice["k_scale"],
                        "v_scale": new_slice["v_scale"]} if int8 else {})
            out_local = L.attention_decode(
                p["attn"], cfg, h, k_new, v_new, positions,
                window=0, attn_softcap=cfg.attn_softcap, update_cache=False,
                local_window=cfg.sliding_window, **scales2)[0]
            out = jnp.where(kind == 0, out_local, out)
        new_slice["k"], new_slice["v"] = k_new, v_new
        attn_out = out
    if cfg.has_ssm:
        ssm_out, h_new, conv_new = L.ssd_step(p["ssm"], cfg, h,
                                              cache_slice["ssd"], cache_slice["conv"])
        new_slice["ssd"], new_slice["conv"] = h_new, conv_new
        if attn_out is None:
            return ssm_out, new_slice
        fused = 0.5 * (L.rms_norm(attn_out, p["ln_attn_out"])
                       + L.rms_norm(ssm_out, p["ln_ssm_out"]))
        return fused, new_slice
    return attn_out, new_slice


def decode_step(params, cfg: ModelConfig, cache: Params, batch, *,
                long_mode: bool = False):
    """One decode step.

    batch: {"token": (B, 1) int32, "pos": (B,) int32}.
    Returns (logits (B, vocab_padded), new_cache).
    """
    params = cast_params(params, cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][batch["token"]].astype(cdt)
    positions = batch["pos"]
    kinds = layer_kinds(cfg)

    def body(x, per):
        p, kind, cache_slice = per
        mix, new_slice = _mix_decode(p, cfg, x, cache_slice, positions, kind, long_mode)
        x = x + mix
        if cfg.is_encdec:
            h = L.rms_norm(x, p["ln_cross"])
            out, _, _ = L.attention_decode(
                p["cross"], cfg, h, cache_slice["cross_k"], cache_slice["cross_v"],
                positions, rope=False, update_cache=False, full_valid=True)
            x = x + out
        x, _ = _ffn(p, cfg, x)
        return x, new_slice

    x, new_cache = jax.lax.scan(body, x, (params["layers"], kinds, cache))
    for key in ("cross_k", "cross_v"):
        if key in cache:
            new_cache[key] = cache[key]
    x = L.rms_norm(x, params["ln_f"])
    logits = x[:, 0] @ params["embed"].T.astype(x.dtype)
    logits = _act_constraint(logits, vocab_axis=True)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch, *, long_mode: bool = False,
            max_len: int = 0):
    """Full prefill.

    max_len: decode-cache capacity (>= prefill length); defaults to
    prefill length + 64 headroom for generated tokens.
    Returns (last_logits (B, V), cache, new_pos (B,)).
    """
    logits, _, caches = forward_full(params, cfg, batch, long_mode=long_mode,
                                     collect_cache=True)
    kv, cross_kv, ssd = caches
    ctx = batch["tokens"].shape[1]
    if cfg.frontend and not cfg.is_encdec:
        ctx += cfg.frontend_tokens if "frontend" not in batch else batch["frontend"].shape[1]
    B = batch["tokens"].shape[0]
    # explicit max_len is the cache capacity (must cover the prompt);
    # default: prompt + 64 decode headroom
    cap = max(max_len, ctx) if max_len else ctx + 64
    cache = init_cache(cfg, B, cap, long_mode=long_mode,
                       enc_len=(batch["src_embeds"].shape[1] if cfg.is_encdec else 0))
    if kv is not None and "k" in cache:
        k_all, v_all = kv       # (nL, B, Lctx, Hkv, hd)
        C = cache["k"].shape[2]
        Lctx = k_all.shape[2]
        int8 = "k_scale" in cache
        if int8:
            k_all, k_sc = L.quantize_kv(k_all)
            v_all, v_sc = L.quantize_kv(v_all)
        if C >= Lctx:
            cache["k"] = cache["k"].at[:, :, :Lctx].set(k_all.astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, :, :Lctx].set(v_all.astype(cache["v"].dtype))
            if int8:
                cache["k_scale"] = cache["k_scale"].at[:, :, :Lctx].set(k_sc)
                cache["v_scale"] = cache["v_scale"].at[:, :, :Lctx].set(v_sc)
        else:  # ring buffer: slot = pos % C
            shift = Lctx % C
            roll = lambda a: jnp.roll(a[:, :, -C:], shift, axis=2)
            cache["k"] = roll(k_all).astype(cache["k"].dtype)
            cache["v"] = roll(v_all).astype(cache["v"].dtype)
            if int8:
                cache["k_scale"] = roll(k_sc)
                cache["v_scale"] = roll(v_sc)
    if cross_kv is not None and cross_kv[0] is not None and cfg.is_encdec:
        cache["cross_k"] = cross_kv[0].astype(cache["cross_k"].dtype)
        cache["cross_v"] = cross_kv[1].astype(cache["cross_v"].dtype)
    if ssd is not None and ssd[0] is not None and cfg.has_ssm:
        cache["ssd"] = ssd[0]                               # (nL, B, H, P, N) f32
        cache["conv"] = ssd[1].astype(cache["conv"].dtype)
    last = logits[:, -1]
    new_pos = jnp.full((B,), logits.shape[1], jnp.int32)
    return last, cache, new_pos


# ======================================================================
# losses / steps
# ======================================================================

def lm_loss(logits, labels):
    """Cross-entropy with -1 = ignore. logits (B, L, V) f32, labels (B, L).

    The gold logit is picked with a one-hot CONTRACTION rather than
    take_along_axis: a gather along a vocab axis that is sharded over
    'model' forces GSPMD to re-shard the full (B, L, V) logits (a
    ~67 GB/device all-gather+all-reduce for 256k vocabs — EXPERIMENTS
    §Perf, gemma2 hillclimb); the contraction reduces locally and psums
    only (B, L) scalars.
    """
    V = logits.shape[-1]
    mask = labels >= 0
    labels_safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels_safe, V, dtype=logits.dtype)
    gold = jnp.einsum("blv,blv->bl", logits, onehot)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux, _ = forward_full(params, cfg, batch)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # frontend tokens prepended
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels], axis=1)
    loss = lm_loss(logits, labels)
    return loss + 0.01 * aux, (loss, aux)
