"""Pure-JAX building blocks shared by every architecture family.

Everything here is functional: params are plain dicts of jnp arrays,
layers are functions ``f(params, x, ...) -> y``.  Layer stacks are
``lax.scan`` over stacked parameters (MaxText-style) so 48-layer models
lower to a compact HLO.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ----------------------------------------------------------------------
# norms / activations
# ----------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, L, H, hd); positions: (B, L) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, L, hd/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention (GQA, optional bias / sliding window / softcap)
# ----------------------------------------------------------------------

def init_attention(key, cfg, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd), dt),
        "wk": dense_init(ks[1], (d, kvd), dt),
        "wv": dense_init(ks[2], (d, kvd), dt),
        "wo": dense_init(ks[3], (qd, d), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), dt)
        p["bk"] = jnp.zeros((kvd,), dt)
        p["bv"] = jnp.zeros((kvd,), dt)
    return p


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def gqa_scores(q, k):
    """q: (B, Lq, Hq, hd), k: (B, Lk, Hkv, hd) -> (B, Hkv, G, Lq, Lk)."""
    B, Lq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Lq, Hkv, G, hd)
    return jnp.einsum("blkgd,bmkd->bkglm", qg, k) / math.sqrt(hd)


def gqa_values(probs, v):
    """probs: (B, Hkv, G, Lq, Lk), v: (B, Lk, Hkv, hd) -> (B, Lq, Hq, hd)."""
    B, Hkv, G, Lq, Lk = probs.shape
    out = jnp.einsum("bkglm,bmkd->blkgd", probs, v)
    return out.reshape(B, Lq, Hkv * G, v.shape[-1])


def attention_full(p, cfg, x, positions, *, window: int = 0, causal: bool = True,
                   kv_x=None, rope: bool = True, attn_softcap: float = 0.0):
    """Full-sequence attention (train / prefill / encoder / cross).

    kv_x: source sequence for cross-attention (keys/values from there).
    window: sliding-window size (0 = unlimited).
    Returns (out, (k, v)) so prefill can keep the cache.
    """
    src = x if kv_x is None else kv_x
    q = _split_heads(x @ p["wq"] + p.get("bq", 0), cfg.n_heads, cfg.head_dim)
    k = _split_heads(src @ p["wk"] + p.get("bk", 0), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(src @ p["wv"] + p.get("bv", 0), cfg.n_kv_heads, cfg.head_dim)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kpos = positions if kv_x is None else jnp.broadcast_to(
            jnp.arange(src.shape[1], dtype=jnp.int32)[None], src.shape[:2])
        k = apply_rope(k, kpos, cfg.rope_theta)

    scores = gqa_scores(q, k).astype(jnp.float32)  # (B, Hkv, G, Lq, Lk)
    scores = softcap(scores, attn_softcap)
    Lq, Lk = scores.shape[-2], scores.shape[-1]
    if causal and kv_x is None:
        iq = jnp.arange(Lq)[:, None]
        ik = jnp.arange(Lk)[None, :]
        mask = ik <= iq
        if window:
            mask &= ik > iq - window
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = gqa_values(probs, v)
    out = out.reshape(out.shape[:2] + (cfg.q_dim,)) @ p["wo"]
    return out, (k, v)


def quantize_kv(x, axis: int = -1):
    """Symmetric int8 per-(token, head) quantization of K/V rows.
    Returns (q int8, scale f32 with a size-1 axis in place of ``axis``)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                    keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def attention_decode(p, cfg, x, k_cache, v_cache, positions, *,
                     window: int = 0, rope: bool = True,
                     attn_softcap: float = 0.0, update_cache: bool = True,
                     local_window: int = 0, full_valid: bool = False,
                     k_scale=None, v_scale=None):
    """Single-token decode against a KV cache.

    x: (B, 1, d); k_cache/v_cache: (B, C, Hkv, hd); positions: (B,) int32 —
    the index of the *current* token.  For sliding-window layers the
    cache is a ring buffer of size C == window and slots are written at
    ``position % window``; otherwise slots are written at ``position``.
    Keys are stored post-RoPE so decode never re-rotates the cache.

    int8 cache (EXPERIMENTS §Perf): if k_scale/v_scale are given the
    cache is int8 with per-(slot, head) scales; new entries are
    quantized on write and the scores/values dequantize on read (fused
    into the attention einsums on TPU).
    Returns (out, new_k_cache, new_v_cache, new_k_scale, new_v_scale).
    """
    B, _, _ = x.shape
    C = k_cache.shape[1]
    int8_cache = k_scale is not None
    q = _split_heads(x @ p["wq"] + p.get("bq", 0), cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"] + p.get("bk", 0), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"] + p.get("bv", 0), cfg.n_kv_heads, cfg.head_dim)
    if rope:
        pos2 = positions[:, None]
        q = apply_rope(q, pos2, cfg.rope_theta)
        k = apply_rope(k, pos2, cfg.rope_theta)

    slot = positions % C if window else positions
    if update_cache:
        # scatter ONE slot per sequence (in-place with donated buffers)
        # instead of a one-hot read-modify-write of the whole cache
        rows = jnp.arange(B)
        if int8_cache:
            kq, ks = quantize_kv(k[:, 0])
            vq, vs = quantize_kv(v[:, 0])
            k_cache = k_cache.at[rows, slot].set(kq)
            v_cache = v_cache.at[rows, slot].set(vq)
            k_scale = k_scale.at[rows, slot].set(ks)
            v_scale = v_scale.at[rows, slot].set(vs)
        else:
            k_cache = k_cache.at[rows, slot].set(k[:, 0].astype(k_cache.dtype))
            v_cache = v_cache.at[rows, slot].set(v[:, 0].astype(v_cache.dtype))

    if int8_cache:
        kf = k_cache.astype(jnp.bfloat16) * k_scale.astype(jnp.bfloat16)
        vf = v_cache.astype(jnp.bfloat16) * v_scale.astype(jnp.bfloat16)
    else:
        kf, vf = k_cache, v_cache
    scores = gqa_scores(q, kf).astype(jnp.float32)  # (B, Hkv, G, 1, C)
    scores = softcap(scores, attn_softcap)
    idx = jnp.arange(C)[None, :]
    pos = positions[:, None]
    if full_valid:
        valid = jnp.ones((B, C), bool)
    elif window:  # ring buffer: every slot valid once position >= C
        valid = (idx <= pos) | (pos >= C)
    else:
        valid = idx <= pos
        if local_window:  # windowed view inside a full cache (gemma2 local)
            valid &= idx > pos - local_window
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = gqa_values(probs, vf.astype(x.dtype) if int8_cache else vf)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"]
    if int8_cache:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


def attention_blocked(p, cfg, x, positions, *, kind=None,
                      long_mode: bool = False, causal: bool = True):
    """Blocked full-sequence attention (flash-style; EXPERIMENTS §Perf).

    Scans over query blocks so the live score tensor is
    O(blk_q * Lk) — or O(blk_q * (W + blk_q)) on uniform-SWA archs,
    where the key BAND for each query block is sliced out — instead of
    the naive O(Lq * Lk) materialization.  Row softmax is exact (the
    full valid key range of every query row is present in its block).

    kind: per-layer 0/1 (local/global) for gemma2-style alternation —
    the mask switches, the (full-range) block shape stays static.
    Returns (out, (k, v)) like attention_full.
    """
    B, L, d = x.shape
    W = cfg.sliding_window
    banded = causal and bool(W) and (not cfg.local_global_pattern
                                     or long_mode)
    blk = min(cfg.attn_block_q, L)
    nq = -(-L // blk)
    Lp = nq * blk

    q = _split_heads(x @ p["wq"] + p.get("bq", 0), cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"] + p.get("bk", 0), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"] + p.get("bv", 0), cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kv = (k, v)                                  # prefill cache (pre-pad)

    qp = jnp.pad(q, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
    if banded:       # prepend a W-sized zero margin; slice [start, start+W+blk)
        kp = jnp.pad(k, ((0, 0), (W, Lp - L), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (W, Lp - L), (0, 0), (0, 0)))
        band = W + blk
    else:
        kp = jnp.pad(k, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        band = Lp

    def block(ib):
        q0 = ib * blk
        qb = jax.lax.dynamic_slice_in_dim(qp, q0, blk, axis=1)
        if banded:
            kb = jax.lax.dynamic_slice_in_dim(kp, q0, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, q0, band, axis=1)
            k0 = q0 - W                          # global index of band col 0
        else:
            kb, vb, k0 = kp, vp, 0
        s = gqa_scores(qb, kb)                   # already 1/sqrt(hd)-scaled
        s = softcap(s.astype(jnp.float32), cfg.attn_softcap)
        iq = q0 + jnp.arange(blk)[:, None]
        ik = k0 + jnp.arange(band)[None, :]
        valid = (ik >= 0) & (ik < L) & (iq < L)
        if causal:
            valid &= ik <= iq
            if banded:
                valid &= ik > iq - W
            elif W and cfg.local_global_pattern:
                local = valid & (ik > iq - W)
                valid = jnp.where(kind == 0, local, valid)
        s = jnp.where(valid[None, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        return gqa_values(pr, vb)                # (B, blk, Hq, hd)

    outs = jax.lax.map(block, jnp.arange(nq))    # (nq, B, blk, Hq, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Lp, cfg.n_heads, cfg.head_dim)
    out = out[:, :L].reshape(B, L, cfg.q_dim) @ p["wo"]
    return out, kv


# ----------------------------------------------------------------------
# MLP / MoE
# ----------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], (d, f), dt),
        "wg": dense_init(ks[1], (d, f), dt),
        "wo": dense_init(ks[2], (f, d), dt),
    }


def mlp(p, x):
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dt, scale=0.02),
        "wi": dense_init(ks[1], (E, d, f), dt),
        "wg": dense_init(ks[2], (E, d, f), dt),
        "wo": dense_init(ks[3], (E, f, d), dt),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg)
    return p


def moe_gate(logits, top_k: int):
    """Top-k gating. logits: (..., E) -> (weights (..., E), aux_loss scalar).

    Weights are zero outside the top-k and renormalized inside it.
    aux_loss is the standard load-balance loss (mean_prob * mean_assignment * E).
    """
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, _ = jax.lax.top_k(probs, top_k)
    thresh = top_vals[..., -1:]
    mask = probs >= thresh
    gated = probs * mask
    gated = gated / (jnp.sum(gated, axis=-1, keepdims=True) + 1e-9)
    # load-balance auxiliary loss
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(mask.reshape(-1, E).astype(jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * E
    return gated.astype(logits.dtype), aux


def moe_block(p, cfg, x):
    """Capacity-based one-hot-dispatch MoE (T5X/Switch style einsums).

    Tokens are grouped along the sequence (group size ``cfg.moe_group``);
    each group dispatches its tokens to experts with per-group capacity
    C = ceil(g * top_k / E * capacity_factor).  Overflowing tokens are
    dropped (residual passes through).  All dataflow is einsum-based so
    GSPMD shards it (experts over the 'model' axis → all-to-all).

    x: (B, L, d) -> (y, aux_loss).
    """
    B, Lx, d = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    g = min(cfg.moe_group, Lx)
    ng = Lx // g
    rem = Lx - ng * g
    if rem:  # trailing partial group handled by a recursive tail call
        y_head, aux_h = moe_block(p, cfg, x[:, :ng * g])
        y_tail, aux_t = moe_block(p, cfg, x[:, ng * g:])
        return jnp.concatenate([y_head, y_tail], axis=1), aux_h + aux_t
    C = max(1, math.ceil(g * K / E * cfg.moe_capacity_factor))

    xg = x.reshape(B * ng, g, d)
    logits = xg @ p["router"]                                  # (G, g, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (G, g, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # load-balance auxiliary loss over the full group
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    assign = jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2)
    ce = jnp.mean(assign, axis=(0, 1))
    aux = jnp.sum(me * ce) * E

    dispatch = jnp.zeros((B * ng, g, E, C), x.dtype)
    combine = jnp.zeros((B * ng, g, E, C), jnp.float32)
    running = jnp.zeros((B * ng, E), jnp.float32)
    for k in range(K):
        eh = jax.nn.one_hot(gate_idx[:, :, k], E, dtype=jnp.float32)   # (G, g, E)
        pos = jnp.cumsum(eh, axis=1) - eh + running[:, None, :]
        keep = (pos < C) * eh                                   # (G, g, E)
        poh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        slot = poh * keep[..., None]                            # (G, g, E, C)
        dispatch = dispatch + slot.astype(x.dtype)
        combine = combine + slot * gate_vals[:, :, k, None, None]
        running = running + jnp.sum(eh, axis=1)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wg"])) * \
        jnp.einsum("gecd,edf->gecf", xin, p["wi"])
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), out)
    y = y.reshape(B, Lx, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y, aux


# ----------------------------------------------------------------------
# Mamba2 / SSD
# ----------------------------------------------------------------------

def init_ssm(key, cfg):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N  # groups = 1
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), dt, scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.zeros((H,), dt),
        "norm": jnp.zeros((di,), dt),
        "out_proj": dense_init(ks[3], (di, d), dt),
    }


def _ssm_split(p, cfg, u):
    """Project + split. u: (B, L, d) -> z, xBC, dt."""
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = u @ p["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt_raw = zxbcdt[..., di + di + 2 * N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xBC, dt


def _causal_conv(xBC, w, state=None):
    """Depthwise causal conv. xBC: (B, L, C); w: (K, C).

    state: (B, K-1, C) previous inputs (decode) or None (zero history).
    Returns (y, new_state).
    """
    K = w.shape[0]
    B, L, Cc = xBC.shape
    if state is None:
        state = jnp.zeros((B, K - 1, Cc), xBC.dtype)
    xpad = jnp.concatenate([state, xBC], axis=1)           # (B, K-1+L, C)
    y = sum(xpad[:, i:i + L, :] * w[i][None, None, :] for i in range(K))
    new_state = xpad[:, -(K - 1):, :]
    return jax.nn.silu(y), new_state


def ssd_chunked(p, cfg, u, h0=None, conv_state=None):
    """SSD forward over a full sequence (train / prefill), chunked scan.

    u: (B, L, d). L must be a multiple of cfg.ssm_chunk.
    Returns (y (B, L, d), final_state (B, H, P, N), conv_state).
    """
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Bb, L, _ = u.shape
    c = cfg.ssm_chunk
    z, xBC, dt = _ssm_split(p, cfg, u)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], conv_state)
    Lp = ((L + c - 1) // c) * c
    if Lp != L:
        # pad with dt=0 (decay 1, zero input) so the final state is exact
        pad = [(0, 0), (0, Lp - L), (0, 0)]
        xBC = jnp.pad(xBC, pad)
        dt = jnp.pad(dt, pad[:2] + [(0, 0)] if dt.ndim == 3 else pad)
    x = xBC[..., :di].reshape(Bb, Lp, H, P)
    Bm = xBC[..., di:di + N]                                # (B, Lp, N) groups=1
    Cm = xBC[..., di + N:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,)
    dA = dt * A[None, None, :]                              # (B, Lp, H) log-decay <= 0

    nc = Lp // c
    xs = (
        x.reshape(Bb, nc, c, H, P),
        Bm.reshape(Bb, nc, c, N),
        Cm.reshape(Bb, nc, c, N),
        dt.reshape(Bb, nc, c, H),
        dA.reshape(Bb, nc, c, H),
    )
    xs = jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 1, 0), xs)  # (nc, B, c, ...)

    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    # mixed precision (EXPERIMENTS §Perf): the quadratic intra/inter-chunk
    # einsums run in the compute dtype (bf16 on TPU) — they dominate the
    # HLO byte traffic; the carried state and decay math stay f32.
    cdt = u.dtype

    def chunk_step(h, inp):
        xc, bc, cc, dtc, dac = inp
        la = jnp.cumsum(dac, axis=1)                        # (B, c, H)
        # inter-chunk: y_i += C_i . (h * exp(la_i))
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cc.astype(cdt),
                             h.astype(cdt),
                             jnp.exp(la).astype(cdt)).astype(jnp.float32)
        # intra-chunk
        cb = jnp.einsum("bin,bjn->bij", cc.astype(cdt), bc.astype(cdt))
        iidx = jnp.arange(c)
        causal = (iidx[:, None] >= iidx[None, :])[None, :, :, None]
        # mask the exponent BEFORE exp: non-causal (i<j) args are positive
        # and overflow in the backward pass if only the output is masked
        arg = jnp.where(causal, la[:, :, None, :] - la[:, None, :, :], 0.0)
        w = jnp.where(causal, jnp.exp(arg), 0.0) * dtc[:, None, :, :]
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb.astype(cdt),
                             w.astype(cdt),
                             xc.astype(cdt)).astype(jnp.float32)
        # state update (f32)
        xc = xc.astype(jnp.float32)
        bc = bc.astype(jnp.float32)
        last = la[:, -1:, :]                                # (B, 1, H)
        contrib = jnp.exp(last - la) * dtc                  # (B, c, H)
        h_new = h * jnp.exp(last)[:, 0, :, None, None] + jnp.einsum(
            "bjn,bjhp,bjh->bhpn", bc, xc, contrib)
        return h_new, (y_inter + y_intra)

    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, Lp, H, P)
    y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bb, Lp, di)[:, :L].astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], h_final, conv_state


def ssd_step(p, cfg, u, h, conv_state):
    """Single-token SSD decode. u: (B, 1, d); h: (B, H, P, N)."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Bb = u.shape[0]
    z, xBC, dt = _ssm_split(p, cfg, u)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], conv_state)
    x = xBC[:, 0, :di].reshape(Bb, H, P).astype(jnp.float32)
    Bm = xBC[:, 0, di:di + N].astype(jnp.float32)
    Cm = xBC[:, 0, di + N:].astype(jnp.float32)
    dt1 = dt[:, 0, :]                                       # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt1 * A[None, :])                           # (B, H)
    h = h * a[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bm, x, dt1)
    y = jnp.einsum("bn,bhpn->bhp", Cm, h)
    y = y + x * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bb, 1, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], h, conv_state
