"""Task Analyzer (paper §3.2).

A low-footprint instruction-tuned LM that predicts the implicit user
preferences — ``{task_type, domain, complexity}`` — from the raw query
at run time.  The paper uses a ~400M FLAN-T5; here it is a miniature
pure-JAX transformer encoder (the substrate scales to the paper's size
by config) trained on the synthetic query logs in ``repro.data``.

Also implements the paper's two analyzer-latency optimizations:
  * long-query pruning: first-n + last-n words + a random sample of the
    middle (task descriptions live at the edges);
  * int8 weight quantization (symmetric per-channel) as a config flag.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.preferences import DOMAINS, TASK_TYPES, TaskSignature
from repro.data.tokenizer import HashTokenizer
from repro.data.workload import QueryRecord, make_workload
from repro.kernels import ops
# the traced encoder lives with the kernels now (it runs inside the
# fused analyze->route program); re-exported here for existing callers
from repro.kernels.analyze_step import (_ln, _maybe_deq,  # noqa: F401
                                        analyzer_forward)
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

N_TT = len(TASK_TYPES)
N_DM = len(DOMAINS)


@dataclass(frozen=True)
class AnalyzerConfig:
    vocab_size: int = 4096
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256
    max_len: int = 96
    # pruning (paper: "first n and last n words ... random sample of middle")
    prune_head: int = 40
    prune_tail: int = 24
    prune_mid: int = 16
    quantize_int8: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# ----------------------------------------------------------------------
# query pruning
# ----------------------------------------------------------------------

def prune_text(cfg: AnalyzerConfig, text: str, seed: int = 0) -> str:
    """Edge-preserving pruning of long queries (deterministic).

    Reference implementation — ``prune_texts`` is the vectorized batch
    twin used on the hot path (property-tested equivalent)."""
    words = text.split()
    budget = cfg.prune_head + cfg.prune_tail + cfg.prune_mid
    if len(words) <= budget:
        return text
    head = words[: cfg.prune_head]
    tail = words[-cfg.prune_tail:]
    middle = words[cfg.prune_head: -cfg.prune_tail]
    rng = np.random.default_rng(seed + len(words))
    pick = sorted(rng.choice(len(middle), size=cfg.prune_mid, replace=False))
    mid = [middle[i] for i in pick]
    return " ".join(head + mid + tail)


def prune_texts(cfg: AnalyzerConfig, texts: Sequence[str],
                seed: int = 0) -> List[str]:
    """Batch ``prune_text``: short queries pass through untouched
    (the overwhelmingly common case — one split and a length check),
    long ones build the keep-index set with numpy fancy indexing
    instead of per-word Python slicing/comprehension.
    """
    budget = cfg.prune_head + cfg.prune_tail + cfg.prune_mid
    out = list(texts)
    for i, text in enumerate(texts):
        words = text.split()
        n = len(words)
        if n <= budget:
            continue
        # identical draw to prune_text: same rng seed, same choice call
        rng = np.random.default_rng(seed + n)
        pick = np.sort(rng.choice(n - cfg.prune_head - cfg.prune_tail,
                                  size=cfg.prune_mid, replace=False))
        keep = np.concatenate([
            np.arange(cfg.prune_head),
            pick + cfg.prune_head,
            np.arange(n - cfg.prune_tail, n)])
        out[i] = " ".join(np.asarray(words, object)[keep].tolist())
    return out


# ----------------------------------------------------------------------
# model
# ----------------------------------------------------------------------

def init_analyzer(key, cfg: AnalyzerConfig) -> Dict:
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    ks = jax.random.split(key, 6 + cfg.n_layers)

    def mat(k, shape, scale=None):
        std = scale if scale else 1.0 / math.sqrt(shape[0])
        return jax.random.normal(k, shape, jnp.float32) * std

    def layer(k):
        kk = jax.random.split(k, 7)
        return {
            "wq": mat(kk[0], (d, d)), "wk": mat(kk[1], (d, d)),
            "wv": mat(kk[2], (d, d)), "wo": mat(kk[3], (d, d)),
            "wi": mat(kk[4], (d, f)), "wp": mat(kk[5], (f, d)),
            "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
        }

    return {
        "embed": mat(ks[0], (V, d), scale=0.05),
        "pos": mat(ks[1], (cfg.max_len, d), scale=0.02),
        "layers": [layer(ks[2 + i]) for i in range(cfg.n_layers)],
        "ln_f": jnp.ones((cfg.d_model,)),
        "head_tt": mat(ks[-3], (d, N_TT), scale=0.02),
        "head_dm": mat(ks[-2], (d, N_DM), scale=0.02),
        "head_cx": mat(ks[-1], (d, 1), scale=0.02),
    }


# ----------------------------------------------------------------------
# int8 quantization (paper §3.2 latency optimization)
# ----------------------------------------------------------------------

def quantize_int8(params: Dict) -> Dict:
    """Symmetric per-output-channel int8 for every 2-D matrix."""
    def q(w):
        if isinstance(w, jnp.ndarray) and w.ndim == 2:
            s = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-12
            return (jnp.round(w / s).astype(jnp.int8), s.astype(jnp.float32))
        return w

    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return q(node)

    return walk(params)


# ----------------------------------------------------------------------
# training (instruction-tuning stand-in) & inference
# ----------------------------------------------------------------------

def _labels(records: Sequence[QueryRecord]) -> Dict[str, np.ndarray]:
    return {
        "tt": np.array([TASK_TYPES.index(r.sig.task_type) for r in records]),
        "dm": np.array([DOMAINS.index(r.sig.domain) for r in records]),
        "cx": np.array([r.sig.complexity for r in records], np.float32),
    }


def analyzer_loss(params, cfg, tokens, labels):
    tt, dm, cx = analyzer_forward(params, cfg, tokens)
    ce = lambda lg, y: -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), y])
    l_tt = ce(tt, labels["tt"])
    l_dm = ce(dm, labels["dm"])
    l_cx = jnp.mean((cx - labels["cx"]) ** 2)
    return l_tt + l_dm + 4.0 * l_cx, (l_tt, l_dm, l_cx)


class TaskAnalyzer:
    """Trainable analyzer with the paper's predict-json contract."""

    # marker the orchestrator checks before fusing analyze into the
    # routing dispatch (stub/oracle analyzers lack params/cfg)
    supports_fused_route = True

    def __init__(self, cfg: AnalyzerConfig = AnalyzerConfig(), seed: int = 0):
        self.cfg = cfg
        self.tok = HashTokenizer(cfg.vocab_size)
        self.params = init_analyzer(jax.random.PRNGKey(seed), cfg)
        self._fwd = jax.jit(
            lambda p, t: analyzer_forward(p, self.cfg, t))
        # wired by the orchestrator so analyzer dispatches land in the
        # same observability stream as route_step
        self.telemetry = None
        self.tracer = None

    # -------------------------- training --------------------------
    def train(self, n_samples: int = 4096, steps: int = 300,
              batch_size: int = 128, seed: int = 0, lr: float = 3e-3,
              log_every: int = 0, long_frac: float = 0.3
              ) -> Dict[str, float]:
        # long_frac of training queries are inflated to long-context
        # shape so the prune-path (first-n/last-n/sampled-middle) is
        # in-distribution (paper: queries range 50 .. 10k+ words)
        records = make_workload(n_samples, seed=seed, long_frac=long_frac)
        toks = self._encode([r.text for r in records])
        labels = _labels(records)
        opt_cfg = AdamWConfig(lr=lr, warmup_steps=20, weight_decay=0.01)
        opt = init_opt_state(self.params)
        rng = np.random.default_rng(seed)

        @jax.jit
        def step(params, opt, tokens, tt, dm, cx):
            (tot, parts), grads = jax.value_and_grad(
                analyzer_loss, has_aux=True)(
                    params, self.cfg, tokens, {"tt": tt, "dm": dm, "cx": cx})
            params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
            return params, opt, tot

        params = self.params
        last = 0.0
        for i in range(steps):
            sel = rng.integers(0, n_samples, batch_size)
            params, opt, tot = step(params, opt, jnp.asarray(toks[sel]),
                                    jnp.asarray(labels["tt"][sel]),
                                    jnp.asarray(labels["dm"][sel]),
                                    jnp.asarray(labels["cx"][sel]))
            last = float(tot)
            if log_every and i % log_every == 0:
                print(f"[analyzer] step {i} loss {last:.4f}")
        self.params = params
        return self.evaluate(seed=seed + 1)

    def evaluate(self, n: int = 512, seed: int = 1) -> Dict[str, float]:
        records = make_workload(n, seed=seed)
        toks = jnp.asarray(self._encode([r.text for r in records]))
        labels = _labels(records)
        tt, dm, cx = self._fwd(self.params, toks)
        return {
            "task_type_acc": float(np.mean(np.argmax(tt, 1) == labels["tt"])),
            "domain_acc": float(np.mean(np.argmax(dm, 1) == labels["dm"])),
            "complexity_mae": float(np.mean(np.abs(np.asarray(cx) - labels["cx"]))),
        }

    # -------------------------- inference --------------------------
    def encode_batch(self, texts: Sequence[str]) -> np.ndarray:
        """Prune + tokenize: (B, max_len) int32 token ids.

        Public because the fused routing path feeds these token ids
        straight into the single analyze->route device program."""
        pruned = prune_texts(self.cfg, texts)
        return self.tok.encode_batch(pruned, self.cfg.max_len)

    # old private name, kept for callers/tests that use it
    _encode = encode_batch

    def quantize(self) -> None:
        self.params = quantize_int8(self.params)

    def analyze_batch(self, texts: Sequence[str]) -> List[TaskSignature]:
        if len(texts) == 0:
            # fast path: never pad an empty batch up to a bucket of 1
            # and run the forward on a garbage row
            return []
        return self.analyze_tokens(self.encode_batch(texts))

    def analyze_tokens(self, tokens: np.ndarray) -> List[TaskSignature]:
        """Tokens -> signatures: the staged half of the decision path
        (``route_tokens_batch`` fuses this stage into the route
        dispatch instead of materializing signatures on the host)."""
        if len(tokens) == 0:
            return []
        # ops.analyze_step buckets the batch dim to powers of two (one
        # compile per bucket) and runs the softmax/argmax/confidence
        # epilogue on device — the host sees four (B,) arrays, and
        # bucket-padding rows are sliced off before this loop
        out = ops.analyze_step(self.params, self.cfg, tokens,
                               telemetry=self.telemetry,
                               tracer=self.tracer)
        return [TaskSignature(task_type=TASK_TYPES[ti],
                              domain=DOMAINS[di],
                              complexity=cx, confidence=conf)
                for ti, di, cx, conf in zip(
                    out["tt_idx"].tolist(), out["dm_idx"].tolist(),
                    out["cx"].tolist(), out["conf"].tolist())]

    def analyze(self, text: str) -> TaskSignature:
        return self.analyze_batch([text])[0]

    def to_json(self, sig: TaskSignature) -> Dict:
        """The paper's structured-json analyzer contract (Fig 3)."""
        return {"task_type": sig.task_type, "domain": sig.domain,
                "complexity": round(sig.complexity, 3),
                "confidence": round(sig.confidence, 3)}


class OracleAnalyzer:
    """Ground-truth analyzer (reads the workload's true signature).

    Used by benchmarks to isolate routing quality from analyzer error.
    """

    def analyze_record(self, rec: QueryRecord) -> TaskSignature:
        return rec.sig
