"""User preferences (paper §3.1, Table 1).

Explicit preferences are 0-1 weights over functional metrics (accuracy,
latency, cost) and non-functional metrics (helpfulness, honesty,
harmlessness, steerability, creativity).  Implicit preferences
(task type, domain, complexity) are inferred by the Task Analyzer.

Profiles encapsulate weight presets for non-expert users
("cost-effective", "ethically-aligned", "latency-first", ...).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from types import MappingProxyType
from typing import Dict, Optional, Tuple

import numpy as np

# Order defines the metric axes of the routing space (MRES embeddings
# and task vectors share it).  All metrics are normalized so 1 = better
# (latency/cost are inverted into speed/cheapness at normalization).
METRICS: Tuple[str, ...] = (
    "accuracy", "speed", "cheapness",
    "helpfulness", "harmlessness", "honesty",
    "steerability", "creativity",
)
N_METRICS = len(METRICS)

TASK_TYPES: Tuple[str, ...] = (
    "chat", "code", "reasoning", "summarization", "classification",
    "translation", "transcription", "vqa", "captioning",
    "creative-writing", "long-context",
)
DOMAINS: Tuple[str, ...] = (
    "general", "software", "finance", "legal", "healthcare", "multilingual",
)


@dataclass(frozen=True)
class UserPreferences:
    """Explicit 0-1 weights per metric. Missing metrics default to 0.25.

    Immutable: ``weights`` is frozen into a read-only mapping at
    construction (use ``with_weight`` to derive variants), which makes
    the memoized ``vector()`` sound."""
    weights: Dict[str, float] = field(default_factory=dict)
    profile: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "weights",
                           MappingProxyType(dict(self.weights)))

    def vector(self) -> np.ndarray:
        """Weight vector over METRICS, memoized (the routing hot path
        re-reads it constantly).  Treat the returned array as frozen —
        copy before mutating."""
        v = self.__dict__.get("_vec")
        if v is None:
            w = np.array([float(self.weights.get(m, 0.25)) for m in METRICS],
                         dtype=np.float32)
            v = np.clip(w, 0.0, 1.0)
            object.__setattr__(self, "_vec", v)
        return v

    def with_weight(self, metric: str, value: float) -> "UserPreferences":
        assert metric in METRICS, metric
        w = dict(self.weights)
        w[metric] = float(value)
        return replace(self, weights=w)

    def validate(self) -> "UserPreferences":
        for k, v in self.weights.items():
            if k not in METRICS:
                raise ValueError(f"unknown metric {k!r}; known: {METRICS}")
            if not (0.0 <= float(v) <= 1.0):
                raise ValueError(f"weight {k}={v} outside [0, 1]")
        return self


PROFILES: Dict[str, UserPreferences] = {
    "cost-effective": UserPreferences(
        weights=dict(cheapness=1.0, speed=0.6, accuracy=0.4, helpfulness=0.3,
                     harmlessness=0.3, honesty=0.3, steerability=0.1,
                     creativity=0.1),
        profile="cost-effective"),
    "ethically-aligned": UserPreferences(
        weights=dict(harmlessness=1.0, honesty=1.0, helpfulness=0.9,
                     accuracy=0.6, cheapness=0.2, speed=0.2, steerability=0.4,
                     creativity=0.2),
        profile="ethically-aligned"),
    "latency-first": UserPreferences(
        weights=dict(speed=1.0, cheapness=0.5, accuracy=0.4, helpfulness=0.3,
                     harmlessness=0.3, honesty=0.3, steerability=0.1,
                     creativity=0.1),
        profile="latency-first"),
    "accuracy-first": UserPreferences(
        weights=dict(accuracy=1.0, helpfulness=0.7, honesty=0.6, speed=0.2,
                     cheapness=0.1, harmlessness=0.5, steerability=0.3,
                     creativity=0.3),
        profile="accuracy-first"),
    "balanced": UserPreferences(
        weights={m: 0.5 for m in METRICS}, profile="balanced"),
}


def resolve(prefs_or_profile) -> UserPreferences:
    """Accepts a UserPreferences, a profile name, or a weights dict."""
    if isinstance(prefs_or_profile, UserPreferences):
        return prefs_or_profile.validate()
    if isinstance(prefs_or_profile, str):
        if prefs_or_profile not in PROFILES:
            raise KeyError(f"unknown profile {prefs_or_profile!r}; "
                           f"known: {sorted(PROFILES)}")
        return PROFILES[prefs_or_profile]
    if isinstance(prefs_or_profile, dict):
        return UserPreferences(weights=prefs_or_profile).validate()
    raise TypeError(type(prefs_or_profile))


def resolve_batch(prefs_batch, batch_size: int) -> "list[UserPreferences]":
    """Resolve a batch of preferences for the array-first routing path.

    Accepts a single prefs/profile-name/weights-dict (broadcast to the
    whole batch) or a sequence with one element per query.
    """
    if isinstance(prefs_batch, (UserPreferences, str, dict)):
        return [resolve(prefs_batch)] * batch_size
    return [resolve(p) for p in prefs_batch]


@dataclass(frozen=True)
class TaskSignature:
    """Implicit preferences inferred by the Task Analyzer (paper Fig 2)."""
    task_type: str = "chat"
    domain: str = "general"
    complexity: float = 0.5          # 0 (trivial) .. 1 (hard)
    confidence: float = 1.0          # analyzer confidence for filtering

    def validate(self) -> "TaskSignature":
        assert self.task_type in TASK_TYPES, self.task_type
        assert self.domain in DOMAINS, self.domain
        assert 0.0 <= self.complexity <= 1.0
        return self
