"""Model Registry and Evaluation Store (paper §3.3).

An in-memory vector store of model entries.  Each entry carries raw
evaluation metrics (accuracy %, latency ms, cost $ / 1M tok, ethics
scores, ...), task-type/domain tags and a handle to the runnable model.
Raw metrics are min-max normalized across the catalog into [0, 1]
(1 = better; latency and cost are inverted) — the normalized vectors are
the embeddings the Routing Engine searches.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.preferences import DOMAINS, METRICS, N_METRICS, TASK_TYPES

# raw metric names -> (embedding axis, higher_is_better)
RAW_TO_AXIS = {
    "accuracy": ("accuracy", True),
    "latency_ms": ("speed", False),
    "cost_per_mtok": ("cheapness", False),
    "helpfulness": ("helpfulness", True),
    "harmlessness": ("harmlessness", True),
    "honesty": ("honesty", True),
    "steerability": ("steerability", True),
    "creativity": ("creativity", True),
}


@dataclass
class ModelEntry:
    name: str
    raw_metrics: Dict[str, float]
    task_types: Tuple[str, ...] = ("chat",)
    domains: Tuple[str, ...] = ("general",)
    family: str = "dense"
    n_params: int = 0
    generalist: bool = False          # fallback-eligible (paper §3.4)
    runner: Any = None                # handle to the servable model
    meta: Dict[str, Any] = field(default_factory=dict)

    def validate(self) -> "ModelEntry":
        for t in self.task_types:
            assert t in TASK_TYPES, (self.name, t)
        for d in self.domains:
            assert d in DOMAINS, (self.name, d)
        for k in RAW_TO_AXIS:
            assert k in self.raw_metrics, (self.name, f"missing metric {k}")
        return self


def normalize_catalog(entries: Sequence[ModelEntry]) -> np.ndarray:
    """Min-max normalize raw metrics into the (n_models, N_METRICS)
    embedding matrix. 1 = better on every axis (inversions applied).

    Scale-invariant: multiplying any raw metric column by c > 0 leaves
    the result unchanged. Single-model catalogs normalize to 1.0.
    """
    n = len(entries)
    emb = np.zeros((n, N_METRICS), np.float32)
    for j, raw_name in enumerate(RAW_TO_AXIS):
        axis_name, hib = RAW_TO_AXIS[raw_name]
        ax = METRICS.index(axis_name)
        col = np.array([float(e.raw_metrics[raw_name]) for e in entries],
                       np.float64)
        lo, hi = col.min(), col.max()
        if hi - lo < 1e-12:
            norm = np.ones_like(col)
        else:
            norm = (col - lo) / (hi - lo)
        if not hib:
            norm = 1.0 - norm
        emb[:, ax] = norm.astype(np.float32)
    return emb


class MRES:
    """In-memory vector store over the model catalog. Thread-safe for the
    serving engine's concurrent route/feedback calls."""

    def __init__(self):
        self._entries: List[ModelEntry] = []
        self._emb: Optional[np.ndarray] = None
        self._dirty = True
        self._lock = threading.Lock()

    # ---------------- registry ----------------
    def register(self, entry: ModelEntry) -> None:
        with self._lock:
            entry.validate()
            existing = {e.name for e in self._entries}
            if entry.name in existing:
                raise ValueError(f"duplicate model {entry.name!r}")
            self._entries.append(entry)
            self._dirty = True

    def update_metrics(self, name: str, **raw_metrics: float) -> None:
        with self._lock:
            e = self._by_name(name)
            e.raw_metrics.update(raw_metrics)
            self._dirty = True

    def _by_name(self, name: str) -> ModelEntry:
        for e in self._entries:
            if e.name == name:
                return e
        raise KeyError(name)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> List[ModelEntry]:
        return list(self._entries)

    def entry(self, name: str) -> ModelEntry:
        with self._lock:
            return self._by_name(name)

    # ---------------- embeddings ----------------
    def embeddings(self) -> np.ndarray:
        """(n_models, N_METRICS) normalized metric matrix."""
        with self._lock:
            if self._dirty or self._emb is None:
                self._emb = normalize_catalog(self._entries)
                self._dirty = False
            return self._emb

    def masks(self, task_type: Optional[str], domain: Optional[str]
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Hierarchical filter masks (task-type mask, domain mask)."""
        tt = np.array([task_type in e.task_types if task_type else True
                       for e in self._entries], bool)
        dm = np.array([domain in e.domains if domain else True
                       for e in self._entries], bool)
        return tt, dm

    def generalist_mask(self) -> np.ndarray:
        return np.array([e.generalist for e in self._entries], bool)
