"""Model Registry and Evaluation Store (paper §3.3).

An in-memory vector store of model entries.  Each entry carries raw
evaluation metrics (accuracy %, latency ms, cost $ / 1M tok, ethics
scores, ...), task-type/domain tags and a handle to the runnable model.
Raw metrics are min-max normalized across the catalog into [0, 1]
(1 = better; latency and cost are inverted) — the normalized vectors are
the embeddings the Routing Engine searches.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.preferences import DOMAINS, METRICS, N_METRICS, TASK_TYPES
from repro.analysis.sanitize import make_lock

# Layout of the fused routing matrix (see MRES docstring): normalized
# metric embeddings, then one-hot task-type bonus columns (+ an
# all-types row), one-hot domain bonus columns (+ an all-domains row),
# then a constant bias column.  A query one-hots its task type and
# domain at MASK_BONUS weight and puts -2 * MASK_BONUS in the bias
# column, so rows passing BOTH filters score bonus 0 (pure cosine) and
# filtered-out rows drop by >= MASK_BONUS — fusing the hierarchical
# masks into the kNN matmul exactly like the Pallas kernel fuses its
# mask in-register.
TT_COL = N_METRICS
DM_COL = TT_COL + len(TASK_TYPES) + 1
BIAS_COL = DM_COL + len(DOMAINS) + 1
ROUTE_COLS = BIAS_COL + 1
MASK_BONUS = 8.0          # > 2 + |cosine| margin, keeps stages separable

# raw metric names -> (embedding axis, higher_is_better)
RAW_TO_AXIS = {
    "accuracy": ("accuracy", True),
    "latency_ms": ("speed", False),
    "cost_per_mtok": ("cheapness", False),
    "helpfulness": ("helpfulness", True),
    "harmlessness": ("harmlessness", True),
    "honesty": ("honesty", True),
    "steerability": ("steerability", True),
    "creativity": ("creativity", True),
}


@dataclass
class IVFIndex:
    """Two-level pruned-search index over the catalog (mega-catalog
    path): spherical k-means centroids over the UNIT-normalized metric
    embeddings and each entry's cell assignment.  Consumed by
    ``kernels/ops.route_step(ivf=(centroids, cell_of), nprobe=...)``
    — only the top-``nprobe`` cells per query are scanned, so recall
    versus the exhaustive search is the ``nprobe`` knob."""
    centroids: np.ndarray             # (C, N_METRICS) f32 unit rows
    cell_of: np.ndarray               # (n,) i32 cell per catalog row
    n_cells: int

    def as_tuple(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.centroids, self.cell_of


def build_ivf(emb: np.ndarray, n_cells: int, *, seed: int = 0,
              iters: int = 5) -> IVFIndex:
    """Spherical k-means over unit-normalized embedding rows.

    Deterministic (fixed ``seed``), a handful of Lloyd iterations —
    routing embeddings are low-dimensional and heavily clustered by
    construction (min-max normalized metric profiles), so cheap
    centroids already give high recall at small ``nprobe``.  Empty
    cells keep their previous centroid (their slots simply stay dead
    in the packed layout).
    """
    n = emb.shape[0]
    C = max(1, min(int(n_cells), n))
    embf = emb.astype(np.float32)
    embn = embf / (np.linalg.norm(embf, axis=1, keepdims=True) + 1e-9)
    rng = np.random.default_rng(seed)
    cent = embn[rng.choice(n, C, replace=False)].copy()
    for _ in range(max(0, int(iters))):
        cell = (embn @ cent.T).argmax(axis=1)
        sums = np.zeros_like(cent)
        np.add.at(sums, cell, embn)
        cnt = np.bincount(cell, minlength=C)
        nz = cnt > 0
        cent[nz] = sums[nz] / (
            np.linalg.norm(sums[nz], axis=1, keepdims=True) + 1e-9)
    cell = (embn @ cent.T).argmax(axis=1).astype(np.int32)
    return IVFIndex(cent.astype(np.float32), cell, C)


def default_n_cells(n: int) -> int:
    """~sqrt(N) cells: balances coarse-scan cost (C per query) against
    fine-scan cost (nprobe * N / C per query)."""
    return max(1, int(round(float(n) ** 0.5)))


@dataclass
class ModelEntry:
    name: str
    raw_metrics: Dict[str, float]
    task_types: Tuple[str, ...] = ("chat",)
    domains: Tuple[str, ...] = ("general",)
    family: str = "dense"
    n_params: int = 0
    generalist: bool = False          # fallback-eligible (paper §3.4)
    runner: Any = None                # handle to the servable model
    meta: Dict[str, Any] = field(default_factory=dict)

    def validate(self) -> "ModelEntry":
        for t in self.task_types:
            assert t in TASK_TYPES, (self.name, t)
        for d in self.domains:
            assert d in DOMAINS, (self.name, d)
        for k in RAW_TO_AXIS:
            assert k in self.raw_metrics, (self.name, f"missing metric {k}")
        return self


def normalize_catalog(entries: Sequence[ModelEntry]) -> np.ndarray:
    """Min-max normalize raw metrics into the (n_models, N_METRICS)
    embedding matrix. 1 = better on every axis (inversions applied).

    Scale-invariant: multiplying any raw metric column by c > 0 leaves
    the result unchanged. Single-model catalogs normalize to 1.0.
    """
    n = len(entries)
    emb = np.zeros((n, N_METRICS), np.float32)
    for j, raw_name in enumerate(RAW_TO_AXIS):
        axis_name, hib = RAW_TO_AXIS[raw_name]
        ax = METRICS.index(axis_name)
        col = np.array([float(e.raw_metrics[raw_name]) for e in entries],
                       np.float64)
        lo, hi = col.min(), col.max()
        if hi - lo < 1e-12:
            norm = np.ones_like(col)
        else:
            norm = (col - lo) / (hi - lo)
        if not hib:
            norm = 1.0 - norm
        emb[:, ax] = norm.astype(np.float32)
    return emb


class MRES:
    """In-memory vector store over the model catalog. Thread-safe for the
    serving engine's concurrent route/feedback calls.

    Besides the normalized embedding matrix, the store caches the
    hierarchical-filter structure as stacked boolean matrices —
    ``(n_task_types + 1, N)`` and ``(n_domains + 1, N)`` (the extra final
    row is all-True for "no filter") — so the batched routing path turns
    per-query mask construction into plain row lookups.  All caches share
    one dirty flag and rebuild together on the next read."""

    def __init__(self):
        self._entries: List[ModelEntry] = []
        self._names: set = set()
        self._emb: Optional[np.ndarray] = None
        self._tt_matrix: Optional[np.ndarray] = None
        self._dm_matrix: Optional[np.ndarray] = None
        self._gmask: Optional[np.ndarray] = None
        self._route_mat: Optional[np.ndarray] = None
        self._name_list: List[str] = []
        self._ivf: Optional[IVFIndex] = None
        self._dirty = True
        self._lock = make_lock("core.mres")

    # ---------------- registry ----------------
    def register(self, entry: ModelEntry) -> None:
        with self._lock:
            self._register_locked(entry)

    def register_many(self, entries: Sequence[ModelEntry]) -> None:
        """Bulk registration (one lock + one cache invalidation).

        Atomic: the whole list is validated and duplicate-checked
        before anything is committed, so a bad entry leaves the
        catalog untouched."""
        entries = list(entries)
        with self._lock:
            seen = set(self._names)
            for entry in entries:
                entry.validate()
                if entry.name in seen:
                    raise ValueError(f"duplicate model {entry.name!r}")
                seen.add(entry.name)
            self._names = seen
            self._entries.extend(entries)
            self._dirty = True

    def _register_locked(self, entry: ModelEntry) -> None:
        entry.validate()
        if entry.name in self._names:
            raise ValueError(f"duplicate model {entry.name!r}")
        self._names.add(entry.name)
        self._entries.append(entry)
        self._dirty = True

    def update_metrics(self, name: str, **raw_metrics: float) -> None:
        with self._lock:
            e = self._by_name(name)
            e.raw_metrics.update(raw_metrics)
            self._dirty = True

    def _by_name(self, name: str) -> ModelEntry:
        for e in self._entries:
            if e.name == name:
                return e
        raise KeyError(name)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def entries(self) -> List[ModelEntry]:
        return list(self._entries)

    def entry(self, name: str) -> ModelEntry:
        with self._lock:
            return self._by_name(name)

    # ---------------- embeddings & mask caches ----------------
    def _refresh_locked(self) -> None:
        if not (self._dirty or self._emb is None):
            return
        entries = self._entries
        n = len(entries)
        self._emb = normalize_catalog(entries)
        self._name_list = [e.name for e in entries]
        tt = np.zeros((len(TASK_TYPES) + 1, n), bool)
        for j, t in enumerate(TASK_TYPES):
            tt[j] = [t in e.task_types for e in entries]
        tt[-1] = True                          # "no task-type filter" row
        dm = np.zeros((len(DOMAINS) + 1, n), bool)
        for j, d in enumerate(DOMAINS):
            dm[j] = [d in e.domains for e in entries]
        dm[-1] = True                          # "no domain filter" row
        self._tt_matrix, self._dm_matrix = tt, dm
        self._gmask = np.array([e.generalist for e in entries], bool)
        A = np.zeros((n, ROUTE_COLS), np.float32)
        if n:
            en = np.sqrt(np.einsum("nm,nm->n", self._emb, self._emb)) + 1e-9
            A[:, :N_METRICS] = self._emb / en[:, None]
            A[:, TT_COL:DM_COL] = MASK_BONUS * tt.T
            A[:, DM_COL:BIAS_COL] = MASK_BONUS * dm.T
            A[:, BIAS_COL] = 1.0
        self._route_mat = A
        self._ivf = None            # rebuilt lazily on next ivf_index()
        self._dirty = False

    def embeddings(self) -> np.ndarray:
        """(n_models, N_METRICS) normalized metric matrix."""
        with self._lock:
            self._refresh_locked()
            return self._emb

    def snapshot(self) -> Tuple[np.ndarray, List[str], np.ndarray,
                                np.ndarray, np.ndarray, np.ndarray]:
        """One consistent view for the batched router:
        (embeddings, names, task-type matrix, domain matrix,
        generalist mask, fused routing matrix) — all under one lock."""
        with self._lock:
            self._refresh_locked()
            return (self._emb, self._name_list, self._tt_matrix,
                    self._dm_matrix, self._gmask, self._route_mat)

    def masks(self, task_type: Optional[str], domain: Optional[str]
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Hierarchical filter masks (task-type mask, domain mask) —
        row lookups into the cached stacked matrices."""
        with self._lock:
            self._refresh_locked()
            ti = TASK_TYPES.index(task_type) if task_type else -1
            di = DOMAINS.index(domain) if domain else -1
            return self._tt_matrix[ti].copy(), self._dm_matrix[di].copy()

    def generalist_mask(self) -> np.ndarray:
        with self._lock:
            self._refresh_locked()
            return self._gmask

    def ivf_index(self, n_cells: Optional[int] = None) -> IVFIndex:
        """The catalog's IVF pruned-search index (built lazily, cached
        until the next registration/metric update dirties the store —
        i.e. rebuilt at ``register_many`` granularity, not per query).
        ``n_cells`` defaults to ~sqrt(N); passing a different value
        rebuilds."""
        with self._lock:
            self._refresh_locked()
            n = len(self._entries)
            if n == 0:
                raise RuntimeError("empty MRES catalog")
            want = default_n_cells(n) if n_cells is None else \
                max(1, min(int(n_cells), n))
            if self._ivf is None or self._ivf.n_cells != want:
                self._ivf = build_ivf(self._emb, want)
            return self._ivf
