"""OptiRoute end-to-end orchestrator (paper Fig 1, §3).

Ties together: user preferences -> Task Analyzer -> Routing Engine over
the MRES -> (optional) model-merging fallback -> inference execution ->
feedback loop.  Three operating modes:

  * interactive — every query is analyzed and routed individually;
  * batched per-query (``route_all``) — the whole request batch is
    analyzed in one analyzer forward and routed in one vectorized
    ``route_many`` pass, each query still getting its own decision
    (the serving engine's default path);
  * batch       — a ~2% sample of the batch is analyzed, the aggregate
                  signature routes the WHOLE batch to one model
                  (amortizes the analyzer; paper §3).
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.analyzer import TaskAnalyzer
from repro.core.feedback import FeedbackStore
from repro.core.merging import ModelMerger
from repro.core.mres import MRES, ModelEntry
from repro.core.preferences import (TaskSignature, UserPreferences,
                                    resolve_batch)
from repro.core.routing import RoutingDecision, RoutingEngine
from repro.obs.trace import NOOP_SPAN


class RoutedQuery:
    """One routed query (text, signature, decision, timings).

    The decision is either eager (single-query ``route``) or LAZY: a
    query routed through the array-first ``route_many_batch`` path
    carries only a (RoutingBatch, row) handle, and the full
    ``RoutingDecision`` object (candidate tuple list, stage_sizes
    dict) materializes on first ``.decision`` access.  The hot-path
    facts — ``model``, ``fallback_kind``, ``task_vector`` — read the
    batch arrays directly, so serving/telemetry/observe never pay the
    Python object loop for queries nobody inspects in depth.

    ``cache_key``/``cache_fp`` are the semantic-cache write-back key,
    stamped by the serving engine at submit time (a cache MISS that
    later validates well becomes the entry that answers the next
    near-duplicate).  ``cache_written`` tracks write-back separately
    from ``observed``: an auto-observing reward_fn marks queries
    observed BEFORE the engine stamps keys, and that must not starve
    the cache of the post-generation write-back.
    """
    __slots__ = ("text", "analyzer_s", "route_s", "response",
                 "observed", "cache_key", "cache_fp", "cache_written",
                 "_sig", "_decision", "_batch", "_bidx")

    def __init__(self, text: str, sig: Optional[TaskSignature] = None,
                 decision: Optional[RoutingDecision] = None,
                 analyzer_s: float = 0.0, route_s: float = 0.0,
                 response: Any = None, batch=None, batch_idx: int = -1):
        assert decision is not None or batch is not None
        self.text = text
        self.analyzer_s = analyzer_s
        self.route_s = route_s
        self.response = response
        self.observed = False         # reward already fed to the bandit
        self.cache_key: Optional[np.ndarray] = None
        self.cache_fp = 0
        self.cache_written = False
        self._sig = sig
        self._decision = decision
        self._batch = batch
        self._bidx = batch_idx

    @property
    def sig(self) -> TaskSignature:
        """Task signature — eager on the staged path, materialized
        lazily from the fused batch's analyzer arrays otherwise."""
        if self._sig is None:
            self._sig = self._batch.signature(self._bidx)
        return self._sig

    @property
    def decision(self) -> RoutingDecision:
        """Full decision object (materialized lazily, memoized)."""
        if self._decision is None:
            self._decision = self._batch.decision(self._bidx)
        return self._decision

    @property
    def model(self) -> str:
        """Chosen model name without materializing the decision."""
        if self._decision is not None:
            return self._decision.model
        return self._batch.model(self._bidx)

    @property
    def fallback_kind(self) -> str:
        if self._decision is not None:
            return self._decision.fallback_kind
        return self._batch.fallback_kind(self._bidx)

    @property
    def task_vector(self) -> np.ndarray:
        if self._decision is not None:
            return self._decision.task_vector
        return self._batch.task_vectors[self._bidx]


class OptiRoute:
    """The deployable facade:
    ``route`` / ``route_all`` / ``route_batch`` / ``serve``."""

    def __init__(self, mres: MRES, analyzer: TaskAnalyzer, *,
                 feedback: Optional[FeedbackStore] = None,
                 knn_k: int = 8, merge_threshold: Optional[float] = None,
                 batch_sample_frac: float = 0.02,
                 use_kernel: bool = False, feedback_weight: float = 0.5,
                 telemetry=None, tracer=None, adaptive=None,
                 adaptive_weight: float = 0.0, reward_fn=None,
                 reward_shaper=None, load=None, load_weight: float = 0.0,
                 cache=None):
        self.mres = mres
        self.analyzer = analyzer
        self.feedback = feedback if feedback is not None else FeedbackStore()
        self.engine = RoutingEngine(mres, self.feedback, knn_k=knn_k,
                                    use_kernel=use_kernel,
                                    feedback_weight=feedback_weight,
                                    adaptive=adaptive,
                                    adaptive_weight=adaptive_weight,
                                    load=load, load_weight=load_weight,
                                    telemetry=telemetry, tracer=tracer)
        self.merger = (ModelMerger(mres, merge_threshold)
                       if merge_threshold is not None else None)
        self.batch_sample_frac = batch_sample_frac
        self.telemetry = telemetry
        # span sink (obs.trace.Tracer): analyze/route/observe stages
        # report nested spans, propagated down to the fused dispatch
        self.tracer = tracer
        # adaptive loop: bandit + (optional) automatic reward emission.
        # ``reward_fn(rq) -> quality in [0, 1]`` makes ``route_all``
        # close the loop itself; without it, call ``observe`` explicitly.
        self.adaptive = adaptive
        self.reward_fn = reward_fn
        self.reward_shaper = reward_shaper
        # load-aware loop: live per-model capacity state the serving
        # engine maintains and route_many penalizes at ``load_weight``
        self.load = load
        # semantic response cache (repro.cache): the serving engine
        # consults it before routing; ``observe`` writes validated
        # responses back so future near-duplicates short-circuit
        self.cache = cache
        # analyzer dispatches report into the same telemetry/trace
        # stream as route_step (fused path and batched analyze alike)
        if getattr(analyzer, "supports_fused_route", False):
            if analyzer.telemetry is None:
                analyzer.telemetry = telemetry
            if analyzer.tracer is None:
                analyzer.tracer = tracer

    # ------------------------- interactive -------------------------
    def route(self, text: str, prefs) -> RoutedQuery:
        """Single-query routing — B=1 wrapper over ``route_all``.

        Sharing the batched entry means a lone interactive query rides
        the same shape-bucketed (and, when eligible, fused) device
        program as serving batches: the B=1 dispatch reuses the
        8-row-floor bucket instead of compiling its own shape."""
        return self.route_all([text], prefs)[0]

    def _record(self, rq: RoutedQuery) -> None:
        if self.telemetry is not None:
            entry = self.mres.entry(rq.model)
            self.telemetry.record_decision(
                rq, sim_cost=entry.raw_metrics.get("cost_per_mtok", 0.0))

    def _fully_fused_ok(self) -> bool:
        """Whether the single analyze->route device program can serve
        this configuration: a fusable engine (no Thompson bandit, no
        mesh sharding, no IVF pruning — those keep the staged analyze),
        no merger (it needs eager scores and may grow the catalog
        mid-pass), and an analyzer exposing its params/config for
        in-program execution (stub/oracle analyzers do not)."""
        return (self.merger is None
                and getattr(self.analyzer, "supports_fused_route", False)
                and self.engine._fused_ok()
                and self.engine.mesh is None
                and not self.engine.ivf)

    # --------------------- batched per-query ---------------------
    def route_all(self, texts: Sequence[str], prefs) -> List[RoutedQuery]:
        """Analyze and route every query in one vectorized pass.

        Unlike ``route_batch`` (sample-and-aggregate, one decision for
        the whole batch), every query gets its own signature and
        decision; the analyzer runs as one batched forward and the
        Routing Engine as ONE fused ``route_many_batch`` device
        dispatch (per-query decisions materialize lazily off the
        returned ``RoutingBatch``; a merger — which needs eager scores
        and may grow the catalog mid-pass — or a non-fusable engine
        config takes the staged object path).  ``prefs`` is a single
        prefs/profile (broadcast) or one per query.  Reported
        per-query timings are the batch cost amortized over B.
        """
        if len(texts) == 0:
            return []
        B = len(texts)
        prefs_list = resolve_batch(prefs, B)
        if len(prefs_list) != B:
            raise ValueError(f"prefs batch size {len(prefs_list)} != "
                             f"text batch size {B}")
        tr = self.tracer
        if self._fully_fused_ok():
            # ONE device program from token ids to model choice: the
            # "analyze" span covers only host-side prune+tokenize (the
            # encoder itself runs inside the fused dispatch, which
            # emits its own route_step span with path="fused")
            an = self.analyzer
            t0 = time.time()
            if tr is not None:
                with tr.span("analyze", path="fused", batch=B):
                    toks = an.encode_batch(list(texts))
            else:
                toks = an.encode_batch(list(texts))
            t1 = time.time()
            batch = self.engine.route_tokens_batch(
                an.params, an.cfg, toks, prefs_list)
            t2 = time.time()
            out = [RoutedQuery(text=t, batch=batch, batch_idx=i,
                               analyzer_s=(t1 - t0) / B,
                               route_s=(t2 - t1) / B)
                   for i, t in enumerate(texts)]
            for rq in out:
                self._record(rq)
            if self.adaptive is not None and self.reward_fn is not None:
                self.observe(out)
            return out
        t0 = time.time()
        if tr is not None:
            with tr.span("analyze", batch=B):
                sigs = self.analyzer.analyze_batch(list(texts))
        else:
            sigs = self.analyzer.analyze_batch(list(texts))
        t1 = time.time()
        if self.merger is None and self.engine._fused_ok():
            batch = self.engine.route_many_batch(prefs_list, sigs)
            t2 = time.time()
            out = [RoutedQuery(text=t, sig=s, batch=batch, batch_idx=i,
                               analyzer_s=(t1 - t0) / B,
                               route_s=(t2 - t1) / B)
                   for i, (t, s) in enumerate(zip(texts, sigs))]
        else:
            if tr is not None:
                with tr.span("route_step", path="staged", batch=B):
                    decisions = self.engine.route_many(prefs_list, sigs)
            else:
                decisions = self.engine.route_many(prefs_list, sigs)
            if self.merger is not None:
                low = [i for i, d in enumerate(decisions)
                       if d.score < self.merger.score_threshold]
                grew = False
                for i in low:
                    if self.merger.maybe_merge(
                            prefs_list[i], sigs[i],
                            decisions[i].score) is not None:
                        grew = True
                if grew:               # re-route low scorers in one pass
                    redo = self.engine.route_many(
                        [prefs_list[i] for i in low],
                        [sigs[i] for i in low])
                    for j, i in enumerate(low):
                        decisions[i] = redo[j]
            t2 = time.time()
            out = [RoutedQuery(text=t, sig=s, decision=d,
                               analyzer_s=(t1 - t0) / B,
                               route_s=(t2 - t1) / B)
                   for t, s, d in zip(texts, sigs, decisions)]
        for rq in out:
            self._record(rq)
        if self.adaptive is not None and self.reward_fn is not None:
            self.observe(out)
        return out

    # ----------------------- adaptive loop -----------------------
    def observe(self, rqs: Sequence[RoutedQuery],
                qualities: Optional[Sequence[float]] = None,
                extra_penalty=None) -> Optional[np.ndarray]:
        """Close the adaptive loop for a routed batch.

        Emits one reward observation per query into the bandit: quality
        (from ``qualities`` or ``reward_fn``) shaped by the per-model
        cost/latency penalties of ``reward_shaper`` (plus any realized
        ``extra_penalty`` from telemetry), against the decision's task
        vector as context.  When a semantic cache is attached, each
        newly-observed query whose serving-time cache key is stamped
        also writes its validated (response, RAW quality) back — the
        cache gates on its own ``min_quality`` bar, so only responses
        the quality loop vouches for are ever replayed.  Each query is
        observed AT MOST ONCE (so an auto-observing ``reward_fn`` plus
        an explicit post-generation ``observe`` never double-count an
        outcome, and a response is never cache-written twice).  Returns
        the shaped rewards of the newly-observed queries, or None when
        neither a bandit nor a cache is attached / no quality source
        exists / nothing is new.
        """
        if (self.adaptive is None and self.cache is None) or not rqs:
            return None
        if qualities is None and self.reward_fn is None:
            return None
        if qualities is not None and len(qualities) != len(rqs):
            raise ValueError(f"{len(rqs)} routed queries but "
                             f"{len(qualities)} qualities — observations "
                             "must align one-to-one")
        if extra_penalty is not None and len(extra_penalty) != len(rqs):
            raise ValueError(f"{len(rqs)} routed queries but "
                             f"{len(extra_penalty)} extra penalties")
        # bandit-fresh and cache-unwritten are tracked SEPARATELY: an
        # auto-observing reward_fn consumes bandit freshness inside
        # route_all, before the serving engine has stamped cache keys —
        # the later post-generation observe() must still write back.
        # Quality is only evaluated for queries that need it (quality
        # evaluation can be expensive in real deployments).
        fresh = [] if self.adaptive is None else \
            [i for i, rq in enumerate(rqs) if not rq.observed]
        cacheable = [] if self.cache is None else \
            [i for i, rq in enumerate(rqs)
             if rq.cache_key is not None and not rq.cache_written]
        todo = sorted(set(fresh) | set(cacheable))
        if not todo:
            return None
        span = self.tracer.span("observe", batch=len(rqs),
                                fresh=len(fresh),
                                cacheable=len(cacheable)) \
            if self.tracer is not None else NOOP_SPAN
        with span:
            if qualities is None:
                qual = {i: float(self.reward_fn(rqs[i])) for i in todo}
            else:
                qual = {i: float(qualities[i]) for i in todo}
            # cache write-back takes RAW quality: the cache's admission
            # bar is about answer trustworthiness, not the
            # cost/latency-shaped bandit reward
            for i in cacheable:
                rq = rqs[i]
                kind = self.cache.put(rq.cache_key, rq.cache_fp,
                                      rq.model, rq.response,
                                      qual[i], sig=rq.sig)
                rq.cache_written = True
                if self.telemetry is not None:
                    self.telemetry.record_cache(kind)
            if cacheable and self.telemetry is not None:
                # inserts can evict/expire internally; surface the churn
                for kind, n in self.cache.drain_events().items():
                    self.telemetry.record_cache(kind, n)
            if self.adaptive is None or not fresh:
                for i in fresh:
                    rqs[i].observed = True
                return None
            sub = [rqs[i] for i in fresh]
            sub_q = [qual[i] for i in fresh]
            sub_ep = None if extra_penalty is None else \
                np.asarray(extra_penalty, np.float32)[fresh]
            names = self.mres.snapshot()[1]
            col = {m: j for j, m in enumerate(names)}
            midx = np.array([col[rq.model] for rq in sub])
            X = np.stack([rq.task_vector for rq in sub])
            if self.reward_shaper is not None:
                rewards = self.reward_shaper.shape(sub_q, midx, sub_ep)
            else:
                rewards = np.asarray(sub_q, np.float32)
                if sub_ep is not None:
                    rewards = rewards - sub_ep
            self.adaptive.ensure(len(names))
            self.adaptive.update(X, midx, rewards)
            for rq in sub:
                rq.observed = True
            return rewards

    # --------------------------- batch ---------------------------
    def route_batch(self, texts: Sequence[str], prefs, *,
                    seed: int = 0) -> Tuple[RoutingDecision, List[TaskSignature], Dict]:
        """Sample-analyze-aggregate-route (paper batch mode).

        Returns (one decision for the whole batch, sampled signatures,
        stats).  The aggregate signature takes the majority task type /
        domain and the MAX complexity of the sample (the chosen model
        must handle the hardest sampled query).
        """
        n = len(texts)
        if n == 0:
            raise ValueError("route_batch requires at least one text; "
                             "got an empty batch")
        k = max(1, int(round(n * self.batch_sample_frac)))
        rng = np.random.default_rng(seed)
        pick = rng.choice(n, size=min(k, n), replace=False)
        t0 = time.time()
        sigs = self.analyzer.analyze_batch([texts[i] for i in pick])
        t1 = time.time()
        tt = Counter(s.task_type for s in sigs).most_common(1)[0][0]
        dm = Counter(s.domain for s in sigs).most_common(1)[0][0]
        agg = TaskSignature(
            task_type=tt, domain=dm,
            complexity=max(s.complexity for s in sigs),
            confidence=float(np.mean([s.confidence for s in sigs])))
        decision = self.engine.route(prefs, agg)
        stats = {"batch": n, "sampled": len(pick),
                 "analyzer_s": t1 - t0, "route_s": time.time() - t1,
                 "aggregate_sig": agg}
        return decision, sigs, stats

    # -------------------------- serving --------------------------
    def serve(self, text: str, prefs, tokens: np.ndarray,
              max_new: int = 8) -> RoutedQuery:
        """Route + execute on the selected entry's runner."""
        rq = self.route(text, prefs)
        entry = self.mres.entry(rq.model)
        if entry.runner is not None:
            rq.response = entry.runner.generate(tokens, max_new=max_new)
        return rq

    def give_feedback(self, rq: RoutedQuery, thumbs_up: bool) -> float:
        if self.telemetry is not None:
            self.telemetry.attach_thumbs(rq.model, thumbs_up)
        return self.feedback.record(rq.sig, rq.model, thumbs_up)
