"""OptiRoute core: the paper's contribution (preferences, analyzer,
MRES, routing engine, feedback, merging, orchestrator)."""
