"""Routing Engine (paper §3.4).

Pipeline per query:
  1. task vector  = user preference weights, with the accuracy axis
     raised to the analyzer's complexity estimate (harder task => demand
     more capable models);
  2. kNN stage    = cosine-similarity top-k against the MRES embedding
     matrix (Pallas ``router_topk`` kernel for large catalogs, numpy for
     small ones);
  3. hierarchical filtering = task-type mask, then domain mask (only
     applied when the analyzer is confident);
  4. scoring      = user-weighted sum of normalized metrics + feedback
     bias; argmax wins;
  5. fallback     = if filters empty the candidate set: widen kNN to the
     whole catalog -> drop the domain filter -> generalist models.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.mres import MRES
from repro.core.preferences import (METRICS, TaskSignature, UserPreferences,
                                    resolve)

_ACC = METRICS.index("accuracy")


def cosine_sim(emb: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Cosine similarity of each row of emb against task vector t."""
    en = np.linalg.norm(emb, axis=1) + 1e-9
    tn = np.linalg.norm(t) + 1e-9
    return (emb @ t) / (en * tn)


@dataclass
class RoutingDecision:
    model: str
    score: float
    task_vector: np.ndarray
    similarity: float
    candidates: List[Tuple[str, float]]
    used_fallback: bool = False
    fallback_kind: str = ""
    stage_sizes: Dict[str, int] = field(default_factory=dict)


class RoutingEngine:
    def __init__(self, mres: MRES, feedback=None, *, knn_k: int = 8,
                 confidence_threshold: float = 0.3,
                 feedback_weight: float = 0.5,
                 use_kernel: bool = False, kernel_min_n: int = 1024,
                 use_complexity: bool = True):
        self.mres = mres
        self.feedback = feedback
        self.knn_k = knn_k
        self.confidence_threshold = confidence_threshold
        self.feedback_weight = feedback_weight
        self.use_kernel = use_kernel
        self._kernel_min_n = kernel_min_n
        self._kernel_fn = None
        self.use_complexity = use_complexity   # ablation knob

    # ------------------------------------------------------------------
    def task_vector(self, prefs: UserPreferences, sig: TaskSignature
                    ) -> np.ndarray:
        v = prefs.vector().copy()
        if getattr(self, "use_complexity", True):
            v[_ACC] = max(v[_ACC], float(sig.complexity))
        return v

    # ------------------------------------------------------------------
    def _knn(self, emb: np.ndarray, t: np.ndarray, k: int) -> np.ndarray:
        """Indices of the k most cosine-similar catalog rows."""
        if self.use_kernel and emb.shape[0] >= self._kernel_min_n:
            from repro.kernels import ops as K
            if self._kernel_fn is None:
                self._kernel_fn = K.router_topk
            _, idx = self._kernel_fn(emb, t[None], k)
            return np.asarray(idx[0])
        sims = cosine_sim(emb, t)
        return np.argsort(-sims)[:k]

    # ------------------------------------------------------------------
    def route(self, prefs_or_profile, sig: TaskSignature) -> RoutingDecision:
        prefs = resolve(prefs_or_profile)
        sig = sig.validate()
        emb = self.mres.embeddings()
        n = emb.shape[0]
        if n == 0:
            raise RuntimeError("empty MRES catalog")
        t = self.task_vector(prefs, sig)
        sims = cosine_sim(emb, t)
        stage: Dict[str, int] = {"catalog": n}

        k = min(self.knn_k, n)
        knn_idx = self._knn(emb, t, k)
        stage["knn"] = len(knn_idx)

        confident = sig.confidence >= self.confidence_threshold
        tt_mask, dm_mask = self.mres.masks(
            sig.task_type if confident else None,
            sig.domain if confident else None)

        kind = ""
        cand = [i for i in knn_idx if tt_mask[i] and dm_mask[i]]
        stage["filtered"] = len(cand)
        if not cand:
            # fallback 1: widen the kNN to the whole catalog
            kind = "widened-knn"
            cand = [i for i in range(n) if tt_mask[i] and dm_mask[i]]
        if not cand:
            # fallback 2: drop the domain filter
            kind = "task-type-only"
            cand = [i for i in range(n) if tt_mask[i]]
        if not cand:
            # fallback 3: generalist models (paper §3.4)
            kind = "generalist"
            gmask = self.mres.generalist_mask()
            cand = [i for i in range(n) if gmask[i]]
        if not cand:
            kind = "any"
            cand = list(range(n))
        stage["candidates"] = len(cand)

        names = [self.mres.entries[i].name for i in cand]
        w = prefs.vector()
        scores = emb[cand] @ w
        if self.feedback is not None:
            bias = self.feedback.bias(sig, names)
            scores = scores + self.feedback_weight * bias
        order = np.argsort(-scores)
        best = int(order[0])
        ranked = [(names[i], float(scores[i])) for i in order[: max(5, k)]]
        return RoutingDecision(
            model=names[best],
            score=float(scores[best]),
            task_vector=t,
            similarity=float(sims[cand[best]]),
            candidates=ranked,
            used_fallback=bool(kind),
            fallback_kind=kind,
            stage_sizes=stage,
        )
