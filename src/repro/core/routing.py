"""Routing Engine (paper §3.4) — batched, array-first.

The hot path is ``route_many``: every query in a batch flows through the
same vectorized pipeline, and ``route`` is the B=1 wrapper around it.

Batched pipeline (B queries, N catalog entries, M metric axes):
  1. task vectors  = (B, M) array of user preference weights, with the
     accuracy axis raised to the analyzer's complexity estimate (harder
     task => demand more capable models);
  2. kNN stage     = one batched cosine-similarity top-k against the
     MRES embedding matrix with the hierarchical task-type/domain
     filter masks fused into the search (a single Pallas ``router_topk``
     kernel call with a per-query (B, N) mask for large catalogs, a
     masked numpy top-k for small ones).  Per-query masks are row
     lookups into the MRES's cached stacked mask matrices;
  3. fallback      = staged boolean masks evaluated per row:
     fused-kNN -> widened-kNN (all rows passing both filters) ->
     task-type-only -> generalist (paper §3.4) -> any.  The first
     non-empty stage becomes the candidate set;
  4. scoring       = one (B, M) x (M, N) matmul of user weights against
     the normalized metric embeddings plus a vectorized (B, N) feedback
     bias; when an adaptive bandit is attached (``repro.adaptive``) its
     learned reward estimates join the blend at ``adaptive_weight``
     (scored only at the candidate columns, cost ~ k not N); when a
     ``LoadTracker`` is attached its saturating expected-wait penalty
     joins at ``load_weight`` the same way; per-row argmax over the
     candidate mask wins.

The load penalty joins the blend at the candidate-scoring stage ONLY —
it is deliberately NOT fused into the kNN similarity search.  Fusing it
there (as an earlier revision did via the kernel's ``row_bias``
operand) applies the penalty twice: once on the cosine-similarity
scale, where a modest penalty dwarfs the similarity spread and crowds a
loaded model out of the candidate set entirely (an unbounded penalty),
and once in the blend.  The penalty must affect the final score exactly
once, so candidate selection stays pure-cosine and fused-kNN decisions
match an unfused scorer bit-for-bit.

Filters only apply when the analyzer is confident (per query).  With the
masks fused into the kNN, the candidate set is the k best models *among
those passing the filters*, so the widened-kNN stage only fires as a
safety net when the fused search returns nothing usable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.mres import (BIAS_COL, DM_COL, MASK_BONUS, MRES, ROUTE_COLS,
                             TT_COL)
from repro.core.preferences import (DOMAINS, METRICS, TASK_TYPES,
                                    TaskSignature, UserPreferences, resolve,
                                    resolve_batch)

_ACC = METRICS.index("accuracy")
_TT_IDX = {t: j for j, t in enumerate(TASK_TYPES)}
_DM_IDX = {d: j for j, d in enumerate(DOMAINS)}
_TT_ANY = len(TASK_TYPES)        # the matrices' all-True "no filter" row
_DM_ANY = len(DOMAINS)

# fallback ladder stage names, in the order the stages are tried
FALLBACK_LADDER = ("", "widened-knn", "task-type-only", "generalist", "any")


def cosine_sim(emb: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Cosine similarity of each row of emb against task vector t."""
    en = np.linalg.norm(emb, axis=1) + 1e-9
    tn = np.linalg.norm(t) + 1e-9
    return (emb @ t) / (en * tn)


def _topk_two_level(ms: np.ndarray, k: int, chunk: int = 128
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Row-wise top-k of a (B, N) score matrix by chunked argmax.

    One full pass computes per-chunk maxima, then each of the k
    extraction rounds only touches (B,) chunk maxima plus one (B, chunk)
    gather — O(B (N + k * chunk)) instead of introselect's per-row
    partition, and measurably faster for small k at serving batch
    sizes.  Operates on an internal copy: the caller's matrix is never
    mutated (the extraction rounds pop winners in place, so without
    the copy the mutation would leak — and only on chunk-aligned N,
    since the pad branch already copied).  Returns (vals, idx) with
    vals descending.
    """
    B, n = ms.shape
    C = -(-n // chunk)
    if C * chunk != n:                   # pad the tail chunk
        padded = np.full((B, C * chunk), -np.inf, np.float32)
        padded[:, :n] = ms
        ms = padded
    else:
        ms = ms.copy()
    m3 = ms.reshape(B, C, chunk)
    mx = m3.max(axis=2)                              # (B, C)
    rows = np.arange(B)
    vals = np.empty((B, k), np.float32)
    idx = np.empty((B, k), np.int64)
    for j in range(k):
        cj = np.argmax(mx, axis=1)                   # (B,)
        block = m3[rows, cj]                         # (B, chunk) copy
        aj = block.argmax(axis=1)
        vals[:, j] = block[rows, aj]
        idx[:, j] = cj * chunk + aj
        m3[rows, cj, aj] = -np.inf                   # pop the winner
        block[rows, aj] = -np.inf
        mx[rows, cj] = block.max(axis=1)
    return vals, idx


@dataclass
class RoutingDecision:
    model: str
    score: float
    task_vector: np.ndarray
    similarity: float
    candidates: List[Tuple[str, float]]
    used_fallback: bool = False
    fallback_kind: str = ""
    stage_sizes: Dict[str, int] = field(default_factory=dict)


@dataclass
class RoutingBatch:
    """Struct-of-arrays result of one fused routing step.

    The serving hot path only needs model indices and fallback stages;
    building B ``RoutingDecision`` objects (candidate tuple lists,
    stage_sizes dicts) per batch was the single largest cost of the
    staged path.  ``RoutingBatch`` keeps everything as packed arrays
    and materializes a ``RoutingDecision`` lazily per row, memoized —
    callers that never touch ``decision(b)`` never pay the Python
    object loop.

    ``stage`` indexes ``FALLBACK_LADDER`` (0 = primary fused-kNN hit).
    ``cand_idx``/``cand_score`` are ranked by blended score, padded
    with (-1, -inf) beyond each row's live candidates.
    """
    names: List[str]                  # catalog names (shared, not copied)
    model_idx: np.ndarray             # (B,) i32 chosen catalog rows
    score: np.ndarray                 # (B,) f32 blended winning scores
    stage: np.ndarray                 # (B,) i32 FALLBACK_LADDER index
    similarity: np.ndarray            # (B,) f32 winner's cosine similarity
    task_vectors: np.ndarray          # (B, M) f32
    cand_idx: np.ndarray              # (B, R) i32 ranked candidates
    cand_score: np.ndarray            # (B, R) f32 ranked blended scores
    n_filtered: np.ndarray            # (B,) i32 finite kNN hits (0 = fb)
    n_candidates: np.ndarray          # (B,) i32 per-row candidate count
    catalog_n: int
    knn_k: int
    r: int                            # max candidates per decision
    # analyzer outputs, present only on the fused tokens->decision path
    # (``route_tokens_batch``): TaskSignature materialization is lazy —
    # callers that never read ``signature(b)`` never pay the object loop
    tt_idx: Optional[np.ndarray] = None   # (B,) i32 raw head argmax
    dm_idx: Optional[np.ndarray] = None   # (B,) i32 raw head argmax
    cx: Optional[np.ndarray] = None       # (B,) f32 complexity, [0, 1]
    conf: Optional[np.ndarray] = None     # (B,) f32 min softmax max
    _cache: Optional[List[Optional[RoutingDecision]]] = field(
        default=None, repr=False, compare=False)
    _sigs: Optional[List[Optional[TaskSignature]]] = field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        if self._cache is None:
            self._cache = [None] * int(self.model_idx.shape[0])
        if self._sigs is None:
            self._sigs = [None] * int(self.model_idx.shape[0])

    def signature(self, b: int) -> TaskSignature:
        """Materialize (and memoize) row ``b``'s TaskSignature from the
        fused program's analyzer outputs."""
        if self.tt_idx is None:
            raise ValueError("no analyzer outputs on this batch — "
                             "signatures exist only on the fused "
                             "route_tokens_batch path")
        s = self._sigs[b]
        if s is None:
            s = TaskSignature(
                task_type=TASK_TYPES[int(self.tt_idx[b])],
                domain=DOMAINS[int(self.dm_idx[b])],
                complexity=float(self.cx[b]),
                confidence=float(self.conf[b]))
            self._sigs[b] = s
        return s

    def signatures(self) -> List[TaskSignature]:
        return [self.signature(b) for b in range(len(self))]

    def __len__(self) -> int:
        return int(self.model_idx.shape[0])

    def model(self, b: int) -> str:
        return self.names[int(self.model_idx[b])]

    def models(self) -> List[str]:
        """Chosen model names, no decision materialization."""
        return [self.names[j] for j in self.model_idx.tolist()]

    def fallback_kind(self, b: int) -> str:
        return FALLBACK_LADDER[int(self.stage[b])]

    def decision(self, b: int) -> RoutingDecision:
        """Materialize (and memoize) row ``b`` as a RoutingDecision."""
        d = self._cache[b]
        if d is not None:
            return d
        stage = int(self.stage[b])
        cs = self.cand_score[b]
        fin = np.isfinite(cs)
        cand = [(self.names[j], s) for j, s in
                zip(self.cand_idx[b][fin].tolist(), cs[fin].tolist())]
        nf = int(self.n_filtered[b])
        if stage == 0:
            sizes = {"catalog": self.catalog_n, "knn": self.knn_k,
                     "filtered": nf, "candidates": nf}
        else:
            sizes = {"catalog": self.catalog_n, "knn": self.knn_k,
                     "filtered": 0,
                     "candidates": int(self.n_candidates[b])}
        d = RoutingDecision(
            model=self.names[int(self.model_idx[b])],
            score=float(self.score[b]),
            task_vector=self.task_vectors[b],
            similarity=float(self.similarity[b]),
            candidates=cand[:self.r],
            used_fallback=stage > 0,
            fallback_kind=FALLBACK_LADDER[stage],
            stage_sizes=sizes)
        self._cache[b] = d
        return d

    def decisions(self) -> List[RoutingDecision]:
        return [self.decision(b) for b in range(len(self))]



def _prefs_matrix(prefs_list) -> np.ndarray:
    """(B, M) preference-weight rows.  ``resolve_batch`` broadcasts a
    single prefs/profile as B references to ONE object — tile its
    memoized vector instead of restacking B identical rows (a
    measurable slice of the per-batch host budget at B=256)."""
    first = prefs_list[0]
    if all(p is first for p in prefs_list):
        return np.tile(first.vector(), (len(prefs_list), 1))
    return np.stack([p.vector() for p in prefs_list])

class RoutingEngine:
    def __init__(self, mres: MRES, feedback=None, *, knn_k: int = 8,
                 confidence_threshold: float = 0.3,
                 feedback_weight: float = 0.5,
                 use_kernel: bool = False, kernel_min_n: int = 1024,
                 use_complexity: bool = True,
                 adaptive=None, adaptive_weight: float = 0.0,
                 load=None, load_weight: float = 0.0,
                 fused: bool = True, telemetry=None, tracer=None,
                 mesh=None, quantize: bool = False,
                 ivf: bool = False, nprobe: int = 8,
                 ivf_min_n: int = 4096):
        self.mres = mres
        self.feedback = feedback
        self.knn_k = knn_k
        self.confidence_threshold = confidence_threshold
        self.feedback_weight = feedback_weight
        self.use_kernel = use_kernel
        self._kernel_min_n = kernel_min_n
        self._kernel_fn = None
        self.use_complexity = use_complexity   # ablation knob
        # fused single-dispatch hot path (kernels/route_step): one
        # jitted device program per routed batch; ``fused=False`` (or a
        # non-fusable config, e.g. a Thompson-sampling bandit) falls
        # back to the staged numpy reference path
        self.fused = fused
        # dispatch/compile counter sink (Telemetry), set by OptiRoute
        self.telemetry = telemetry
        # span sink (obs.trace.Tracer): the fused dispatch reports a
        # "route_step" span with path/bucket/compile attributes
        self.tracer = tracer
        # online-learning layer (repro.adaptive): learned per-model
        # reward estimates blended into the static scores at weight
        # ``adaptive_weight`` (the preference knob; 0 = static routing)
        self.adaptive = adaptive
        self.adaptive_weight = float(adaptive_weight)
        # load-aware layer (repro.serving.load): live expected-wait
        # penalties blended into the candidate scores at ``load_weight``
        # (0 = load-blind routing), counted exactly once
        self.load = load
        self.load_weight = float(load_weight)
        # mega-catalog serving knobs (kernels/ops.route_step):
        #   mesh     — 1-D device mesh with a "catalog" axis
        #              (launch.make_routing_mesh); the fused program
        #              shards the catalog axis across it, bit-identical
        #              to single-device at fp32
        #   quantize — serve from the int8 row-quantized catalog
        #   ivf      — two-level pruned search via MRES.ivf_index(),
        #              scanning the top-``nprobe`` cells per query
        #              (recall knob); only engages at catalogs >=
        #              ``ivf_min_n`` where pruning pays for the coarse
        #              pass, and is not yet composed with ``mesh``
        self.mesh = mesh
        self.quantize = bool(quantize)
        self.ivf = bool(ivf)
        self.nprobe = int(nprobe)
        self.ivf_min_n = int(ivf_min_n)

    # ------------------------------------------------------------------
    def task_vector(self, prefs: UserPreferences, sig: TaskSignature
                    ) -> np.ndarray:
        v = prefs.vector().copy()
        if getattr(self, "use_complexity", True):
            v[_ACC] = max(v[_ACC], float(sig.complexity))
        return v

    # ------------------------------------------------------------------
    def _knn_batch(self, T: np.ndarray, k: int, ti: np.ndarray,
                   di: np.ndarray, snap, bias: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Mask-fused batched kNN: (vals (B, k), idx (B, k)).

        Rows failing the hierarchical filters surface as vals == -inf.
        Large catalogs go through one Pallas ``router_topk`` call with a
        per-query (B, N) mask; the numpy path fuses the masks into a
        single matmul against the MRES's augmented routing matrix (see
        ``repro.core.mres``) — valid rows score their pure cosine,
        filtered rows drop below -2 — then top-k selects per row.

        ``bias`` (N,) is an optional additive per-catalog-row term
        applied to VALID rows only, fused into the matmul on both
        backends.  The load-aware path does NOT pass it (the penalty
        joins the blend exactly once, at the candidate columns); it
        stays available for callers that want a true selection-stage
        prior.
        """
        emb, _, tt_matrix, dm_matrix, _, route_mat = snap
        B = T.shape[0]
        if self.use_kernel and emb.shape[0] >= self._kernel_min_n:
            from repro.kernels import ops as K
            if self._kernel_fn is None:
                self._kernel_fn = K.router_topk
            valid = tt_matrix[ti] & dm_matrix[di]             # (B, N)
            vals, idx = self._kernel_fn(emb, T, k, mask=valid,
                                        row_bias=bias)
            return np.asarray(vals), np.asarray(idx)
        # fused matmul: [T/|T|, onehot(tt), onehot(dm), -2b] @ A^T
        tn = np.sqrt(np.einsum("bm,bm->b", T, T)) + 1e-9
        Q = np.zeros((B, ROUTE_COLS), np.float32)
        Q[:, :T.shape[1]] = T / tn[:, None]
        rows = np.arange(B)
        Q[rows, TT_COL + ti] = 1.0
        Q[rows, DM_COL + di] = 1.0
        Q[:, BIAS_COL] = -2.0 * MASK_BONUS
        ms = Q @ route_mat.T                                  # (B, N)
        n = ms.shape[1]
        if bias is not None:
            # resolve validity BEFORE the bias shifts scores (a large
            # penalty must not be confused with a failed filter)
            ms = np.where(ms > -2.0, ms + bias[None, :].astype(np.float32),
                          -np.inf)
        if B >= 4 and k <= 16 and n >= 1024:
            vals, idx = _topk_two_level(ms, k)
        else:
            # argpartition on the LAST k cols avoids negating the matrix
            idx = (np.argpartition(ms, n - k, axis=1)[:, n - k:] if k < n
                   else np.broadcast_to(np.arange(n), ms.shape))
            vals = np.take_along_axis(ms, idx, axis=1)
        if bias is not None:
            return vals, idx
        return np.where(vals > -2.0, vals, -np.inf), idx

    # ------------------------------------------------------------------
    def route(self, prefs_or_profile, sig: TaskSignature) -> RoutingDecision:
        """Single-query routing — thin B=1 wrapper over ``route_many``."""
        return self.route_many([prefs_or_profile], [sig])[0]

    # ------------------------------------------------------------------
    def _prepare_batch(self, prefs_batch, sigs: Sequence[TaskSignature]):
        """Validate + vectorize one batch for either routing backend:
        (sigs, prefs_list, W (B, M), T (B, M), ti (B,), di (B,))."""
        sigs = [s.validate() for s in sigs]
        B = len(sigs)
        prefs_list = resolve_batch(prefs_batch, B)
        if len(prefs_list) != B:
            raise ValueError(f"prefs batch size {len(prefs_list)} != "
                             f"signature batch size {B}")
        if B == 0:
            return sigs, prefs_list, None, None, None, None
        # (B, M) scoring weights and task vectors (one vector() pass)
        W = _prefs_matrix(prefs_list)
        T = W.copy()
        if getattr(self, "use_complexity", True):
            cx = np.array([s.complexity for s in sigs], np.float32)
            T[:, _ACC] = np.maximum(T[:, _ACC], cx)
        # per-query hierarchical filter rows of the cached mask matrices
        # (the all-True row when the analyzer is not confident)
        thr = self.confidence_threshold
        ti = np.array([_TT_IDX[s.task_type] if s.confidence >= thr
                       else _TT_ANY for s in sigs], np.int32)
        di = np.array([_DM_IDX[s.domain] if s.confidence >= thr
                       else _DM_ANY for s in sigs], np.int32)
        return sigs, prefs_list, W, T, ti, di

    def _fused_ok(self) -> bool:
        """Whether the fused single-dispatch path can serve this
        configuration (a Thompson bandit samples host-side RNG per
        score, which cannot live inside a cached device program)."""
        if not getattr(self, "fused", True):
            return False
        if self.adaptive is not None and self.adaptive_weight != 0.0:
            return (getattr(self.adaptive, "policy", "") == "linucb"
                    and hasattr(self.adaptive, "posterior"))
        return True

    # ------------------------------------------------------------------
    def route_many(self, prefs_batch, sigs: Sequence[TaskSignature]
                   ) -> List[RoutingDecision]:
        """Route a batch of queries in one vectorized pass.

        ``prefs_batch`` is either one prefs/profile/dict applied to every
        query or a sequence of them (one per signature).  Returns one
        ``RoutingDecision`` per signature, decision-identical to calling
        ``route`` per query.  The hot path is ``route_many_batch`` (one
        fused device program, array-first); this wrapper materializes
        its decisions for callers that want the object view.
        """
        if not self._fused_ok():
            return self.route_many_staged(prefs_batch, sigs)
        return self.route_many_batch(prefs_batch, sigs).decisions()

    # ------------------------------------------------------------------
    def route_many_batch(self, prefs_batch,
                         sigs: Sequence[TaskSignature]) -> RoutingBatch:
        """Array-first batched routing: ONE fused device program.

        The whole per-batch pipeline — mask-fused kNN, feedback bias,
        bandit LinUCB estimates, load penalty, the final score blend,
        the candidate argmax, and the staged fallback ladder as masked
        re-scores — executes as a single jitted ``ops.route_step``
        dispatch behind recompile-free shape buckets (power-of-two Q,
        128-aligned catalog).  Returns a ``RoutingBatch`` whose
        per-query ``RoutingDecision`` objects materialize lazily.
        """
        if not self._fused_ok():
            # fail loud: silently scoring a Thompson-sampling bandit
            # with the program's deterministic LinUCB formula (or
            # bypassing an explicit fused=False) would change routing
            # behavior for direct callers of this method
            raise ValueError(
                "engine configuration is not fusable (Thompson-policy "
                "bandit or fused=False) — use route_many / "
                "route_many_staged")
        sigs, prefs_list, W, T, ti, di = self._prepare_batch(
            prefs_batch, sigs)
        B = len(sigs)
        if B == 0:
            # an empty batch is fine even against an empty catalog —
            # same contract as the staged path, which returns before
            # ever snapshotting (nothing to route, nothing to refresh)
            z = np.zeros(0, np.int32)
            zf = np.zeros(0, np.float32)
            return RoutingBatch(
                names=[], model_idx=z, score=zf, stage=z,
                similarity=zf, task_vectors=np.zeros((0, len(METRICS)),
                                                     np.float32),
                cand_idx=np.zeros((0, 1), np.int32),
                cand_score=np.zeros((0, 1), np.float32),
                n_filtered=z, n_candidates=z,
                catalog_n=0, knn_k=0, r=0)
        snap = self.mres.snapshot()
        emb, names, tt_matrix, dm_matrix, gmask, _ = snap
        n = emb.shape[0]
        if n == 0:
            raise RuntimeError("empty MRES catalog")
        k = min(self.knn_k, n)
        r = min(max(5, k), n)

        theta = ainv = None
        alpha = ad_w = 0.0
        if self.adaptive is not None and self.adaptive_weight != 0.0:
            self.adaptive.ensure(n)
            theta, ainv = self.adaptive.posterior()
            alpha = float(self.adaptive.alpha)
            ad_w = self.adaptive_weight
        lpen = None
        if self.load is not None and self.load_weight != 0.0:
            self.load.ensure(n)
            # slice to the catalog: a tracker pre-sized for growth may
            # carry more arms than this snapshot has rows
            lpen = self.load_weight * self.load.penalty()[:n]
        fb = None
        if self.feedback is not None and self.feedback.has_bias():
            fb = self.feedback.bias_batch(sigs, names)

        ivf = None
        if self.ivf and self.mesh is None and n >= self.ivf_min_n:
            ivf = self.mres.ivf_index().as_tuple()

        from repro.kernels import ops as K
        out = K.route_step(
            emb, tt_matrix, dm_matrix, gmask, T, W, ti, di, k=k, r=r,
            fb=fb, fb_weight=self.feedback_weight,
            theta=theta, ainv=ainv, alpha=alpha, ad_weight=ad_w,
            lpen=lpen,
            use_pallas=self.use_kernel and n >= self._kernel_min_n,
            quant=self.quantize, mesh=self.mesh, ivf=ivf,
            nprobe=self.nprobe,
            telemetry=self.telemetry, tracer=self.tracer)
        return RoutingBatch(
            names=names, model_idx=out["model_idx"],
            score=out["score"], stage=out["stage"],
            similarity=out["similarity"], task_vectors=T,
            cand_idx=out["cand_idx"], cand_score=out["cand_score"],
            n_filtered=out["n_filtered"],
            n_candidates=out["n_candidates"],
            catalog_n=n, knn_k=k, r=r)

    # ------------------------------------------------------------------
    def route_tokens_batch(self, params, cfg, tokens,
                           prefs_batch) -> RoutingBatch:
        """Fused tokens->decision routing: ONE device program.

        ``tokens`` is the analyzer's (B, L) int32 token batch
        (``TaskAnalyzer.encode_batch``); ``params``/``cfg`` its weights
        and config.  The analyzer encoder, softmax heads, complexity
        clamp, confidence thresholding, task-vector build, feedback
        gather, and the whole ``route_step`` pipeline execute as a
        single jitted ``ops.analyze_route_step`` dispatch — no
        intermediate touches the host.  Dense single-device only (the
        sharded/IVF mega-catalog paths keep the staged analyze).

        Returns a ``RoutingBatch`` carrying the analyzer outputs;
        ``TaskSignature`` objects materialize lazily via
        ``signature(b)``.
        """
        if not self._fused_ok():
            raise ValueError(
                "engine configuration is not fusable (Thompson-policy "
                "bandit or fused=False) — analyze + route_many_staged")
        if self.mesh is not None:
            raise ValueError("route_tokens_batch is single-device only "
                             "(mesh-sharded catalogs keep the staged "
                             "analyze)")
        tokens = np.asarray(tokens, np.int32)
        B = tokens.shape[0]
        prefs_list = resolve_batch(prefs_batch, B)
        if len(prefs_list) != B:
            raise ValueError(f"prefs batch size {len(prefs_list)} != "
                             f"token batch size {B}")
        if B == 0:
            z = np.zeros(0, np.int32)
            zf = np.zeros(0, np.float32)
            return RoutingBatch(
                names=[], model_idx=z, score=zf, stage=z,
                similarity=zf, task_vectors=np.zeros((0, len(METRICS)),
                                                     np.float32),
                cand_idx=np.zeros((0, 1), np.int32),
                cand_score=np.zeros((0, 1), np.float32),
                n_filtered=z, n_candidates=z,
                catalog_n=0, knn_k=0, r=0,
                tt_idx=z, dm_idx=z, cx=zf, conf=zf)
        snap = self.mres.snapshot()
        emb, names, tt_matrix, dm_matrix, gmask, _ = snap
        n = emb.shape[0]
        if n == 0:
            raise RuntimeError("empty MRES catalog")
        if self.ivf and n >= self.ivf_min_n:
            raise ValueError("route_tokens_batch does not compose with "
                             "IVF pruning — use the staged analyze")
        k = min(self.knn_k, n)
        r = min(max(5, k), n)
        W = _prefs_matrix(prefs_list)

        theta = ainv = None
        alpha = ad_w = 0.0
        if self.adaptive is not None and self.adaptive_weight != 0.0:
            self.adaptive.ensure(n)
            theta, ainv = self.adaptive.posterior()
            alpha = float(self.adaptive.alpha)
            ad_w = self.adaptive_weight
        lpen = None
        if self.load is not None and self.load_weight != 0.0:
            self.load.ensure(n)
            lpen = self.load_weight * self.load.penalty()[:n]
        fb_table = None
        if self.feedback is not None and self.feedback.has_bias():
            # dense per-cluster table, identity-stable per store
            # version, so its padded device copy caches in ops
            fb_table = self.feedback.bias_table(names)

        from repro.kernels import ops as K
        out = K.analyze_route_step(
            params, cfg, tokens, emb, tt_matrix, dm_matrix, gmask, W,
            k=k, r=r, threshold=self.confidence_threshold, acc_col=_ACC,
            use_complexity=getattr(self, "use_complexity", True),
            fb_table=fb_table, fb_weight=self.feedback_weight,
            theta=theta, ainv=ainv, alpha=alpha, ad_weight=ad_w,
            lpen=lpen,
            use_pallas=self.use_kernel and n >= self._kernel_min_n,
            quant=self.quantize,
            telemetry=self.telemetry, tracer=self.tracer)
        return RoutingBatch(
            names=names, model_idx=out["model_idx"],
            score=out["score"], stage=out["stage"],
            similarity=out["similarity"],
            task_vectors=out["task_vectors"],
            cand_idx=out["cand_idx"], cand_score=out["cand_score"],
            n_filtered=out["n_filtered"],
            n_candidates=out["n_candidates"],
            catalog_n=n, knn_k=k, r=r,
            tt_idx=out["tt_idx"], dm_idx=out["dm_idx"],
            cx=out["cx"], conf=out["conf"])

    # ------------------------------------------------------------------
    def route_many_staged(self, prefs_batch, sigs: Sequence[TaskSignature]
                          ) -> List[RoutingDecision]:
        """Staged numpy reference path (pre-fusion semantics).

        Kept as the semantic oracle the fused ``route_many_batch`` is
        pinned against (and as the serving path for configurations the
        fused program cannot express, e.g. Thompson sampling).  Several
        numpy/device passes per batch + eager decision objects.
        """
        sigs, prefs_list, W, T, ti, di = self._prepare_batch(
            prefs_batch, sigs)
        B = len(sigs)
        if B == 0:
            return []
        snap = self.mres.snapshot()
        emb, names, tt_matrix, dm_matrix, gmask, _ = snap
        n = emb.shape[0]
        if n == 0:
            raise RuntimeError("empty MRES catalog")

        # adaptive layer: learned reward estimates join the blend below,
        # restricted to the kNN candidate columns (cost ~ k, not N)
        adaptive_on = (self.adaptive is not None
                       and self.adaptive_weight != 0.0)
        if adaptive_on:
            self.adaptive.ensure(n)

        # load-aware layer: one (N,) expected-wait penalty snapshot per
        # batch, subtracted from the candidate scores below at
        # ``load_weight`` — exactly once.  It is NOT fused into the kNN
        # selection: on the cosine scale the penalty would crowd loaded
        # models out of the candidate set (a second, unbounded
        # application of the same term; see the module docstring)
        load_on = self.load is not None and self.load_weight != 0.0
        lpen = None
        if load_on:
            self.load.ensure(n)
            # slice to the catalog: a tracker pre-sized for growth (or
            # shared) may carry more arms than this snapshot has rows
            lpen = self.load_weight * self.load.penalty()[:n]  # (N,)

        # stage 1: batched kNN with the filter masks fused in
        k = min(self.knn_k, n)
        vals, idx = self._knn_batch(T, k, ti, di, snap)
        finite = np.isfinite(vals) & (idx >= 0)
        idx = np.where(finite, idx, 0)        # safe gather index
        has_primary = finite.any(axis=1)                          # (B,)

        # score ONLY the <=k fused-kNN candidates: a (B, k, M) gather +
        # einsum instead of a full (B, N) matmul, and a (B, k) feedback
        # gather instead of the full (B, N) bias matrix — rows that
        # fell off the ladder (no valid candidate at all) take the
        # per-row slow path below, which is exercised a handful of
        # times per batch at most.
        cscores = np.einsum("bm,bkm->bk", W, emb[idx])            # (B, k)
        if self.feedback is not None:
            cscores = cscores + self.feedback_weight * \
                self.feedback.bias_for(sigs, names, idx)
        if adaptive_on:
            # bandit scores only at the union of candidate columns:
            # (B, C) with C <= B*k, instead of the full (B, N) matrix
            cols, inv = np.unique(idx, return_inverse=True)
            asub = self.adaptive.scores_at(T, cols)               # (B, C)
            cscores = cscores + self.adaptive_weight * \
                np.take_along_axis(asub, inv.reshape(idx.shape), axis=1)
        if lpen is not None:
            # saturated candidates lose up to load_weight (the penalty
            # saturates in [0, 1)), again only at the candidate columns
            cscores = cscores - lpen[idx]
        cscores = np.where(finite, cscores, -np.inf)
        order = np.argsort(-cscores, axis=1, kind="stable")       # (B, k)
        knn_found = finite.sum(axis=1).tolist()

        # sort the per-row candidate arrays once, then build decisions
        # from plain python lists (cheap scalar access)
        idx_s = np.take_along_axis(idx, order, axis=1).tolist()
        sc_s = np.take_along_axis(cscores, order, axis=1).tolist()
        fin_s = np.take_along_axis(finite, order, axis=1).tolist()
        # the kNN vals are pure cosine (no load bias), so the reported
        # similarity needs no correction under the load knob
        sim_s = np.take_along_axis(vals, order, axis=1)[:, 0].tolist()

        r = min(max(5, k), n)
        out: List[Optional[RoutingDecision]] = [None] * B
        for b in np.flatnonzero(has_primary):
            ranked = [(names[j], s) for j, s, f in
                      zip(idx_s[b], sc_s[b], fin_s[b]) if f]
            out[b] = RoutingDecision(
                model=names[idx_s[b][0]],
                score=sc_s[b][0],
                task_vector=T[b],
                similarity=sim_s[b],
                used_fallback=False, fallback_kind="",
                candidates=ranked[:r],
                stage_sizes={"catalog": n, "knn": k,
                             "filtered": knn_found[b],
                             "candidates": knn_found[b]})

        # fallback ladder as staged boolean masks (per affected row):
        # widened-kNN (all rows passing both filters) -> task-type-only
        # -> generalist -> any.  Mask rows (and the full per-row
        # feedback bias) are materialized lazily here because the fast
        # path above never needs them.  With the filters fused into the
        # kNN the widened-kNN rung is a pure safety net — any row
        # passing both filters already surfaced in the top-k — but it
        # stays in the ladder to keep fallback totality independent of
        # the kNN backend's numerics.
        for b in np.flatnonzero(~has_primary):
            tt_b = tt_matrix[ti[b]]
            bias_b = (self.feedback.bias(sigs[b], names)
                      if self.feedback is not None else None)
            out[b] = self._route_fallback(
                b, emb, names, T, W,
                (tt_b & dm_matrix[di[b]], tt_b, gmask), bias_b,
                adaptive_on, lpen, sigs[b], n, k, r)
        return out                      # type: ignore[return-value]

    # ------------------------------------------------------------------
    def _route_fallback(self, b: int, emb, names, T, W, ladder, bias_row,
                        adaptive_on: bool, lpen, sig: TaskSignature,
                        n: int, k: int, r: int) -> RoutingDecision:
        """Fallback ladder for one row whose fused kNN came up empty."""
        for kind, mask in zip(FALLBACK_LADDER[1:], ladder):
            if mask.any():
                break
        else:
            kind, mask = FALLBACK_LADDER[-1], np.ones(n, bool)
        cidx = np.flatnonzero(mask)
        scores = emb[cidx] @ W[b]
        if bias_row is not None:
            scores = scores + self.feedback_weight * bias_row[cidx]
        if adaptive_on:
            scores = scores + self.adaptive_weight * \
                self.adaptive.scores_at(T[b:b + 1], cidx)[0]
        if lpen is not None:
            scores = scores - lpen[cidx]
        order = np.argsort(-scores, kind="stable")
        best = int(cidx[order[0]])
        sim = float(cosine_sim(emb[best:best + 1], T[b])[0])
        return RoutingDecision(
            model=names[best],
            score=float(scores[order[0]]),
            task_vector=T[b],
            similarity=sim,
            candidates=[(names[int(cidx[j])], float(scores[j]))
                        for j in order[:r]],
            used_fallback=True, fallback_kind=kind,
            stage_sizes={"catalog": n, "knn": k, "filtered": 0,
                         "candidates": int(len(cidx))})
