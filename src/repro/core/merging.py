"""Model merging fallback (paper §5 — built as promised in DESIGN.md).

When no catalog entry meets the user criteria, OptiRoute synthesizes a
new entry by model-soup weight averaging (Wortsman et al. 2022) of
same-family checkpoints that each partially meet the criteria.  The
merged entry's metrics are the (weight-)interpolation of the parents'
metrics, which is exactly the first-order model-soup prediction.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mres import MRES, ModelEntry, RAW_TO_AXIS
from repro.core.preferences import METRICS, TaskSignature, UserPreferences


def soup(param_trees: Sequence, weights: Optional[Sequence[float]] = None):
    """Weighted average of same-structure parameter pytrees."""
    n = len(param_trees)
    assert n >= 1
    w = np.full(n, 1.0 / n) if weights is None else np.asarray(weights, np.float64)
    assert len(w) == n and abs(float(w.sum()) - 1.0) < 1e-6, w

    def avg(*leaves):
        acc = leaves[0].astype(jnp.float32) * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            acc = acc + leaf.astype(jnp.float32) * wi
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(avg, *param_trees)


def mergeable(a: ModelEntry, b: ModelEntry) -> bool:
    """Soups only make sense within a family (same param structure)."""
    return (a.family == b.family and a.n_params == b.n_params
            and a.name != b.name)


def merged_metrics(parents: Sequence[ModelEntry],
                   weights: Sequence[float]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for raw in RAW_TO_AXIS:
        out[raw] = float(sum(
            w * float(p.raw_metrics[raw]) for w, p in zip(weights, parents)))
    return out


class ModelMerger:
    """Creates on-the-fly soup entries when routing scores fall short.

    ``maybe_merge`` is called by the orchestrator when the best routed
    score is below ``score_threshold``: it searches same-family pairs,
    predicts the merged entry's user-weighted score by metric
    interpolation, and if some pair beats the incumbent it registers the
    soup (averaging the actual runner params when both are loaded).
    """

    def __init__(self, mres: MRES, score_threshold: float = 0.0,
                 grid: int = 5):
        self.mres = mres
        self.score_threshold = score_threshold
        self.grid = grid
        self.created: List[str] = []

    def candidate_pairs(self) -> List[Tuple[ModelEntry, ModelEntry]]:
        entries = self.mres.entries
        return [(a, b) for i, a in enumerate(entries)
                for b in entries[i + 1:] if mergeable(a, b)]

    def predict_score(self, metrics: Dict[str, float],
                      prefs: UserPreferences) -> float:
        """User-weighted score of a hypothetical entry, against the
        current catalog normalization."""
        entries = self.mres.entries
        w = prefs.vector()
        score = 0.0
        for raw, (axis, hib) in RAW_TO_AXIS.items():
            col = np.array([float(e.raw_metrics[raw]) for e in entries])
            lo, hi = col.min(), col.max()
            x = float(metrics[raw])
            norm = 1.0 if hi - lo < 1e-12 else float(np.clip((x - lo) / (hi - lo), 0, 1))
            if not hib:
                norm = 1.0 - norm
            score += w[METRICS.index(axis)] * norm
        return score

    def maybe_merge(self, prefs: UserPreferences, sig: TaskSignature,
                    incumbent_score: float) -> Optional[ModelEntry]:
        """The soup must beat the INCUMBENT's score (``score_threshold``
        only gates whether the orchestrator attempts a merge at all)."""
        best = None
        best_score = incumbent_score
        for a, b in self.candidate_pairs():
            for i in range(1, self.grid):
                alpha = i / self.grid
                metrics = merged_metrics([a, b], [alpha, 1 - alpha])
                s = self.predict_score(metrics, prefs)
                if s > best_score + 1e-9:
                    best, best_score, best_alpha = (a, b), s, alpha
        if best is None:
            return None
        a, b = best
        name = f"soup:{a.name}+{b.name}@{best_alpha:.2f}"
        runner = None
        if a.runner is not None and b.runner is not None:
            try:
                runner = a.runner.merged_with(b.runner, best_alpha)
            except (AttributeError, AssertionError):
                runner = None
        entry = ModelEntry(
            name=name,
            raw_metrics=merged_metrics([a, b], [best_alpha, 1 - best_alpha]),
            task_types=tuple(sorted(set(a.task_types) | set(b.task_types))),
            domains=tuple(sorted(set(a.domains) | set(b.domains))),
            family=a.family, n_params=a.n_params,
            generalist=a.generalist or b.generalist,
            runner=runner,
            meta={"soup_parents": (a.name, b.name), "alpha": best_alpha},
        )
        self.mres.register(entry)
        self.created.append(name)
        return entry
