"""User feedback loop (paper §3.5).

Thumbs-up/down per (task cluster, model) maintained as a bounded
exponential moving average in [-1, 1].  The Routing Engine adds
``feedback_weight * bias`` at scoring time, so positive feedback
reinforces a routing path and negative feedback depresses it.

A task cluster is (task_type, domain, complexity bucket) — the
granularity at which the paper's policy review operates.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.preferences import DOMAINS, TASK_TYPES, TaskSignature
from repro.analysis.sanitize import make_lock

Cluster = Tuple[str, str, int]


def cluster_of(sig: TaskSignature, buckets: int = 4) -> Cluster:
    b = min(int(sig.complexity * buckets), buckets - 1)
    return (sig.task_type, sig.domain, b)


@dataclass
class FeedbackEvent:
    cluster: Cluster
    model: str
    thumbs_up: bool


class FeedbackStore:
    def __init__(self, alpha: float = 0.3):
        self.alpha = float(alpha)
        self._bias: Dict[Tuple[Cluster, str], float] = {}
        self._count: Dict[Tuple[Cluster, str], int] = {}
        self._log: List[FeedbackEvent] = []
        self._version = 0
        self._tables: Dict[Tuple, np.ndarray] = {}
        self._lock = make_lock("core.feedback")

    def record(self, sig: TaskSignature, model: str, thumbs_up: bool) -> float:
        """EMA update; returns the new bias (always within [-1, 1])."""
        c = cluster_of(sig)
        key = (c, model)
        target = 1.0 if thumbs_up else -1.0
        with self._lock:
            old = self._bias.get(key, 0.0)
            new = (1 - self.alpha) * old + self.alpha * target
            self._bias[key] = float(np.clip(new, -1.0, 1.0))
            self._count[key] = self._count.get(key, 0) + 1
            self._log.append(FeedbackEvent(c, model, thumbs_up))
            self._version += 1
            self._tables.clear()
            return self._bias[key]

    def version(self) -> int:
        """Monotonic mutation counter — bumped by ``record`` and
        ``load_state``.  Lets callers detect staleness of anything
        derived from the bias map without diffing it."""
        with self._lock:
            return self._version

    def bias_table(self, models: Sequence[str],
                   buckets: int = 4) -> np.ndarray:
        """Dense (len(TASK_TYPES) * len(DOMAINS) * buckets, N) bias
        table for the fused routing path: row
        ``(tt_idx * len(DOMAINS) + dm_idx) * buckets + cb`` holds the
        per-model biases of cluster ``(TASK_TYPES[tt_idx],
        DOMAINS[dm_idx], cb)`` — the same raw-predicted cluster
        ``cluster_of`` keys on (confidence thresholding never enters
        the feedback cluster).

        Memoized per (version, models, buckets): the returned array's
        *identity* is stable until feedback actually changes, so the
        device-side padded copy in ``kernels.ops`` caches on ``id()``
        and steady-state serving re-ships nothing.
        """
        with self._lock:
            key = (self._version, tuple(models), int(buckets))
            table = self._tables.get(key)
            if table is not None:
                return table
            n_tt, n_dm = len(TASK_TYPES), len(DOMAINS)
            table = np.zeros((n_tt * n_dm * buckets, len(models)),
                             np.float32)
            if self._bias:
                tt_row = {t: i for i, t in enumerate(TASK_TYPES)}
                dm_row = {d: i for i, d in enumerate(DOMAINS)}
                name_col = {m: j for j, m in enumerate(models)}
                for ((t, d, cb), m), v in self._bias.items():
                    ti, di, j = tt_row.get(t), dm_row.get(d), \
                        name_col.get(m)
                    if ti is None or di is None or j is None \
                            or not 0 <= cb < buckets:
                        continue
                    table[(ti * n_dm + di) * buckets + cb, j] = v
            if len(self._tables) >= 4:
                self._tables.clear()
            self._tables[key] = table
            return table

    def has_bias(self) -> bool:
        """True when ANY (cluster, model) bias is recorded — the fused
        routing path skips shipping a (B, N) zero matrix to the device
        while the store is empty (the common cold-start state)."""
        with self._lock:
            return bool(self._bias)

    def bias(self, sig: TaskSignature, models: Sequence[str]) -> np.ndarray:
        c = cluster_of(sig)
        with self._lock:
            return np.array([self._bias.get((c, m), 0.0) for m in models],
                            np.float32)

    def bias_batch(self, sigs: Sequence[TaskSignature],
                   models: Sequence[str]) -> np.ndarray:
        """(B, N) bias matrix for the batched routing path.

        Cost is O(B + |store| + unique_clusters * hits) — rows sharing a
        task cluster are filled once and broadcast, and clusters with no
        recorded feedback stay at the zero default.
        """
        out = np.zeros((len(sigs), len(models)), np.float32)
        clusters: Dict[Cluster, List[int]] = {}
        for i, s in enumerate(sigs):
            clusters.setdefault(cluster_of(s), []).append(i)
        with self._lock:
            if not self._bias:
                return out
            name_col = {m: j for j, m in enumerate(models)}
            hits: Dict[Cluster, List[Tuple[int, float]]] = {}
            for (c, m), v in self._bias.items():
                j = name_col.get(m)
                if j is not None and c in clusters:
                    hits.setdefault(c, []).append((j, v))
        for c, rows in clusters.items():
            pairs = hits.get(c)
            if not pairs:
                continue
            row = np.zeros(len(models), np.float32)
            for j, v in pairs:
                row[j] = v
            out[rows] = row
        return out

    def bias_for(self, sigs: Sequence[TaskSignature],
                 models: Sequence[str], idx: np.ndarray) -> np.ndarray:
        """(B, k) bias at the candidate columns ``idx`` (B, k).

        The routing hot path only scores <= k candidates per query, so
        this gathers B * k dict entries instead of materializing the
        full (B, N) matrix ``bias_batch`` builds.
        """
        out = np.zeros(idx.shape, np.float32)
        with self._lock:
            if not self._bias:
                return out
            get = self._bias.get
            for b, (sig, row) in enumerate(zip(sigs, idx.tolist())):
                c = cluster_of(sig)
                out[b] = [get((c, models[j]), 0.0) for j in row]
        return out

    def events(self) -> List[FeedbackEvent]:
        with self._lock:
            return list(self._log)

    # ---- persistence (part of the production story) ----
    def state(self) -> List[Dict]:
        """JSON-able snapshot of every (cluster, model) bias — the
        payload ``save`` writes and ``RouterState`` embeds."""
        with self._lock:
            return [{"cluster": list(k[0]), "model": k[1], "bias": v,
                     "count": self._count.get(k, 0)}
                    for k, v in self._bias.items()]

    def load_state(self, data: List[Dict]) -> None:
        """Restore a ``state()`` snapshot, REPLACING in-memory biases
        (same replace semantics as ``load``)."""
        bias = {}
        count = {}
        for row in data:
            key = (tuple(row["cluster"]), row["model"])
            bias[key] = float(row["bias"])
            count[key] = int(row["count"])
        with self._lock:
            self._bias = bias
            self._count = count
            self._version += 1
            self._tables.clear()

    def save(self, path: str) -> None:
        """Atomic snapshot: a crash or a concurrent reader never sees a
        partially-written file (write-temp + rename)."""
        data = self.state()
        d = os.path.dirname(os.path.abspath(path))
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".feedback-",
                                   suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f)
            # mkstemp creates 0600; keep the target's mode (or the
            # umask default) so external readers stay able to read it
            try:
                mode = os.stat(path).st_mode & 0o777
            except FileNotFoundError:
                um = os.umask(0)
                os.umask(um)
                mode = 0o666 & ~um
            os.chmod(tmp, mode)
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise

    def load(self, path: str) -> None:
        """Restore a ``save`` snapshot, REPLACING any in-memory state
        (loading into a live store must not splice stale entries into
        the snapshot's)."""
        with open(path) as f:
            self.load_state(json.load(f))
