"""User feedback loop (paper §3.5).

Thumbs-up/down per (task cluster, model) maintained as a bounded
exponential moving average in [-1, 1].  The Routing Engine adds
``feedback_weight * bias`` at scoring time, so positive feedback
reinforces a routing path and negative feedback depresses it.

A task cluster is (task_type, domain, complexity bucket) — the
granularity at which the paper's policy review operates.
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.preferences import TaskSignature

Cluster = Tuple[str, str, int]


def cluster_of(sig: TaskSignature, buckets: int = 4) -> Cluster:
    b = min(int(sig.complexity * buckets), buckets - 1)
    return (sig.task_type, sig.domain, b)


@dataclass
class FeedbackEvent:
    cluster: Cluster
    model: str
    thumbs_up: bool


class FeedbackStore:
    def __init__(self, alpha: float = 0.3):
        self.alpha = float(alpha)
        self._bias: Dict[Tuple[Cluster, str], float] = {}
        self._count: Dict[Tuple[Cluster, str], int] = {}
        self._log: List[FeedbackEvent] = []
        self._lock = threading.Lock()

    def record(self, sig: TaskSignature, model: str, thumbs_up: bool) -> float:
        """EMA update; returns the new bias (always within [-1, 1])."""
        c = cluster_of(sig)
        key = (c, model)
        target = 1.0 if thumbs_up else -1.0
        with self._lock:
            old = self._bias.get(key, 0.0)
            new = (1 - self.alpha) * old + self.alpha * target
            self._bias[key] = float(np.clip(new, -1.0, 1.0))
            self._count[key] = self._count.get(key, 0) + 1
            self._log.append(FeedbackEvent(c, model, thumbs_up))
            return self._bias[key]

    def bias(self, sig: TaskSignature, models: Sequence[str]) -> np.ndarray:
        c = cluster_of(sig)
        with self._lock:
            return np.array([self._bias.get((c, m), 0.0) for m in models],
                            np.float32)

    def events(self) -> List[FeedbackEvent]:
        with self._lock:
            return list(self._log)

    # ---- persistence (part of the production story) ----
    def save(self, path: str) -> None:
        with self._lock:
            data = [{"cluster": list(k[0]), "model": k[1], "bias": v,
                     "count": self._count.get(k, 0)}
                    for k, v in self._bias.items()]
        with open(path, "w") as f:
            json.dump(data, f)

    def load(self, path: str) -> None:
        with open(path) as f:
            data = json.load(f)
        with self._lock:
            for row in data:
                key = (tuple(row["cluster"]), row["model"])
                self._bias[key] = float(row["bias"])
                self._count[key] = int(row["count"])
