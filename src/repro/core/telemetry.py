"""Routing telemetry (production observability for the MLaaS use-case).

A thread-safe ledger the orchestrator/engine writes one event per
routed request into: model chosen, fallback kind, analyzer/route
latencies, simulated serving cost.  Exposes per-model aggregates,
fallback rates, stage-funnel statistics and a rolling-window QPS view —
what an operator needs to see that the router behaves in production.

Memory is FIXED no matter how long the process serves (PR 7): raw
events sit in a bounded ring (newest ``max_events`` kept, for
debugging/attribution), while everything reported — funnels, per-model
aggregates, latency/cost distributions, QPS — is maintained
incrementally in monotonic counters and fixed-bucket log histograms
(``obs.metrics.LogHistogram``).  ``summary()`` therefore reflects ALL
events ever recorded, not just the retained window, and no view
re-scans or re-quantiles raw lists under the lock.  Thumbs feedback
attaches O(1) via per-model pending stacks instead of an O(n) reverse
scan.  ``summary()`` takes ONE consistent snapshot under the lock.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass
from typing import Any, Deque, Dict, Optional

from repro.obs.metrics import LogHistogram
from repro.analysis.sanitize import make_lock

# latency histograms cover 10us .. 100s at ~0.54% relative resolution;
# cost histograms cover 1e-3 .. 1e4 simulated-cost units
_LAT_RANGE = dict(lo=1e-5, hi=1e2, per_octave=128)
_COST_RANGE = dict(lo=1e-3, hi=1e4, per_octave=128)


@dataclass
class RouteEvent:
    ts: float
    model: str
    task_type: str
    domain: str
    complexity: float
    fallback: str = ""
    analyzer_s: float = 0.0
    route_s: float = 0.0
    sim_cost: float = 0.0
    thumbs: Optional[bool] = None


def _new_model_agg() -> Dict[str, Any]:
    return dict(requests=0, fallbacks=0, cost=0.0, route_s=0.0,
                thumbs_up=0, thumbs_down=0)


class Telemetry:
    def __init__(self, window_s: float = 60.0, max_events: int = 8192,
                 max_pending_thumbs: int = 512):
        self.window_s = window_s
        self.max_events = int(max_events)
        self.max_pending_thumbs = int(max_pending_thumbs)
        # bounded retention of raw events (debugging / attribution);
        # aggregates below are monotonic and cover ALL events
        self._events: Deque[RouteEvent] = \
            collections.deque(maxlen=self.max_events)
        self._events_total = 0
        self._fallbacks_total = 0
        self._fallback_funnel: Dict[str, int] = {}
        self._per_model: Dict[str, Dict[str, Any]] = {}
        self._model_lat: Dict[str, LogHistogram] = {}
        self._lat_hist = LogHistogram(**_LAT_RANGE)
        self._cost_hist = LogHistogram(**_COST_RANGE)
        # model -> stack of unrated events; thumbs pop the most recent
        self._pending: Dict[str, Deque[RouteEvent]] = {}
        # event timestamps for the rolling QPS window (pruned at read;
        # hard cap keeps memory bounded even if qps() is never called)
        self._qps_ts: Deque[float] = collections.deque(maxlen=65536)
        self._admissions: Dict[str, int] = {}
        # tenant -> {kind -> count}: the per-tenant admission funnel
        # (fairness dashboards key on this; bounded by tenant count)
        self._tenant_admissions: Dict[str, Dict[str, int]] = {}
        self._cache: Dict[str, int] = {}
        self._route_step: Dict[str, int] = {"dispatches": 0,
                                            "compiles": 0}
        self._analyze_step: Dict[str, int] = {"dispatches": 0,
                                              "compiles": 0}
        self._sharding: Dict[str, int] = {"silent_replications": 0}
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._lock = make_lock("core.telemetry")

    # ------------------------------------------------------------------
    def record(self, event: RouteEvent) -> None:
        with self._lock:
            self._events.append(event)
            self._events_total += 1
            self._fallbacks_total += bool(event.fallback)
            self._fallback_funnel[event.fallback] = \
                self._fallback_funnel.get(event.fallback, 0) + 1
            a = self._per_model.get(event.model)
            if a is None:
                a = self._per_model[event.model] = _new_model_agg()
                self._model_lat[event.model] = LogHistogram(**_LAT_RANGE)
                self._pending[event.model] = collections.deque(
                    maxlen=self.max_pending_thumbs)
            a["requests"] += 1
            a["fallbacks"] += bool(event.fallback)
            a["cost"] += event.sim_cost
            a["route_s"] += event.route_s
            lat = event.analyzer_s + event.route_s
            self._model_lat[event.model].record(lat)
            self._lat_hist.record(lat)
            if event.sim_cost:
                self._cost_hist.record(event.sim_cost)
            self._pending[event.model].append(event)
            self._qps_ts.append(event.ts)

    def record_decision(self, rq, *, sim_cost: float = 0.0) -> None:
        """Convenience: log an orchestrator RoutedQuery.

        Reads the cheap array-backed accessors (``rq.model`` /
        ``rq.fallback_kind``) rather than ``rq.decision`` so logging a
        lazily-materialized batch row does not force the full decision
        object into existence."""
        self.record(RouteEvent(
            ts=time.time(), model=rq.model,
            task_type=rq.sig.task_type, domain=rq.sig.domain,
            complexity=rq.sig.complexity,
            fallback=rq.fallback_kind,
            analyzer_s=rq.analyzer_s, route_s=rq.route_s,
            sim_cost=sim_cost))

    def record_route_step(self, *, dispatches: int = 0,
                          compiles: int = 0) -> None:
        """Count fused routing-step device activity: ``dispatches`` is
        one per routed batch; ``compiles`` counts jit-cache misses of
        the bucketed executable (see ``kernels/ops.route_step``).  A
        healthy steady-state serving stream shows dispatches growing
        linearly and compiles FLAT after the warmup batches."""
        with self._lock:
            self._route_step["dispatches"] += int(dispatches)
            self._route_step["compiles"] += int(compiles)

    def route_step_stats(self) -> Dict[str, int]:
        """Fused-dispatch counters: {dispatches, compiles}."""
        with self._lock:
            return dict(self._route_step)

    def record_analyze_step(self, *, dispatches: int = 0,
                            compiles: int = 0) -> None:
        """Count analyzer-stage device activity: one dispatch per
        analyzed batch, whether the analyzer ran alone
        (``ops.analyze_step``) or inside the fused analyze->route
        program (``ops.analyze_route_step``, which feeds BOTH counter
        families from its single dispatch).  Same health read as
        ``record_route_step``: compiles must go FLAT after warmup."""
        with self._lock:
            self._analyze_step["dispatches"] += int(dispatches)
            self._analyze_step["compiles"] += int(compiles)

    def analyze_step_stats(self) -> Dict[str, int]:
        """Analyzer-dispatch counters: {dispatches, compiles}."""
        with self._lock:
            return dict(self._analyze_step)

    def record_sharding(self, *, silent_replications: int = 0) -> None:
        """Count partition-spec fallbacks: ``silent_replications`` is
        how many times ``sharding.rules.maybe()`` quietly replicated a
        tensor because its named axis was absent from the mesh.  A
        non-zero steady-state value means a layout the operator thinks
        is sharded is actually N copies — surfaced loudly by
        ``launch/dryrun.py`` and here for dashboards."""
        with self._lock:
            self._sharding["silent_replications"] += \
                int(silent_replications)

    def sharding_stats(self) -> Dict[str, int]:
        """Partition-spec fallback counters: {silent_replications}."""
        with self._lock:
            return dict(self._sharding)

    def record_admission(self, kind: str, count: int = 1, *,
                         tenant: Optional[str] = None) -> None:
        """Count one admission outcome (``admitted`` / ``rerouted`` /
        ``shed`` / ``failed`` — see ``repro.serving.load``).  ``tenant``
        additionally attributes the outcome to a per-tenant funnel so
        fairness (who gets shed when the system saturates) is
        observable per customer, not just in aggregate."""
        with self._lock:
            self._admissions[kind] = self._admissions.get(kind, 0) + count
            if tenant is not None:
                t = self._tenant_admissions.setdefault(tenant, {})
                t[kind] = t.get(kind, 0) + count

    def admission_funnel(self) -> Dict[str, int]:
        """Admission outcome counts: how much traffic was admitted as
        routed, rerouted to a lower-ranked candidate to make its SLO,
        shed as a guaranteed miss, or failed at generation time."""
        with self._lock:
            return dict(self._admissions)

    def admission_by_tenant(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant admission funnels: ``{tenant: {kind: count}}``
        for every outcome recorded with a tenant attribution."""
        with self._lock:
            return {t: dict(k) for t, k in self._tenant_admissions.items()}

    def record_cache(self, kind: str, count: int = 1) -> None:
        """Count one semantic-cache outcome (``hit`` / ``miss`` at
        lookup, ``stored`` / ``rejected`` at write-back — see
        ``repro.cache.CACHE_KINDS``)."""
        with self._lock:
            self._cache[kind] = self._cache.get(kind, 0) + count

    def cache_funnel(self) -> Dict[str, int]:
        """Semantic-cache outcome counts with a STABLE key set: every
        kind in ``repro.cache.CACHE_KINDS`` is always present (zeroed
        on an empty engine), so dashboards and tests can key into the
        funnel without existence checks."""
        from repro.cache import CACHE_KINDS
        with self._lock:
            return {k: self._cache.get(k, 0) for k in CACHE_KINDS}

    def inc(self, name: str, amount: float = 1.0) -> None:
        """Bump a generic monotonic counter (exported as-is)."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + amount

    def set_gauge(self, name: str, value: float) -> None:
        """Set a generic point-in-time gauge (exported as-is)."""
        with self._lock:
            self._gauges[name] = float(value)

    def attach_thumbs(self, model: str, thumbs_up: bool) -> None:
        """Attach feedback to the MOST RECENT unrated event for
        ``model``.  O(1): each model keeps a bounded stack of unrated
        events, so feedback on a long history never rescans the ring."""
        with self._lock:
            stack = self._pending.get(model)
            if not stack:
                return
            e = stack.pop()
            e.thumbs = thumbs_up
            a = self._per_model[model]
            if thumbs_up:
                a["thumbs_up"] += 1
            else:
                a["thumbs_down"] += 1

    # ------------------------------------------------------------------
    # views (all incremental — no event rescans)
    # ------------------------------------------------------------------
    def _per_model_locked(self) -> Dict[str, Dict[str, float]]:
        agg: Dict[str, Dict[str, float]] = {}
        for m, a in self._per_model.items():
            out = dict(a)
            out["fallback_rate"] = out["fallbacks"] / max(
                out["requests"], 1)
            n_fb = out["thumbs_up"] + out["thumbs_down"]
            out["satisfaction"] = (out["thumbs_up"] / n_fb) \
                if n_fb else None
            # per-model routing-latency distribution, not just means:
            # operators alarm on tails, and means hide queueing spikes
            h = self._model_lat[m]
            out["latency_p50_s"] = h.quantile(0.5)
            out["latency_p99_s"] = h.quantile(0.99)
            agg[m] = out
        return agg

    def per_model(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            return self._per_model_locked()

    def fallback_rate(self) -> float:
        with self._lock:
            if not self._events_total:
                return 0.0
            return self._fallbacks_total / self._events_total

    def fallback_funnel(self) -> Dict[str, int]:
        """Routed-request counts per fallback ladder stage.

        Keys follow ``routing.FALLBACK_LADDER`` ('' = primary fused-kNN
        hit); only stages that occurred appear.  The operator's view of
        how far down the ladder traffic is falling."""
        with self._lock:
            return dict(self._fallback_funnel)

    def qps(self, now: Optional[float] = None) -> float:
        """Requests/s over ``(now - window_s, now]``.  Prunes the
        timestamp deque as it reads (assumes ``now`` values are
        non-decreasing across calls, which wall clocks are)."""
        now = now if now is not None else time.time()
        cutoff = now - self.window_s
        with self._lock:
            ts = self._qps_ts
            while ts and ts[0] <= cutoff:
                ts.popleft()
            n = len(ts)
        return n / self.window_s

    def _latency_percentiles_locked(self, q) -> Dict[str, float]:
        return {f"p{int(x * 100)}": self._lat_hist.quantile(x)
                for x in q}

    def latency_percentiles(self, q=(0.5, 0.9, 0.99)) -> Dict[str, float]:
        with self._lock:
            return self._latency_percentiles_locked(q)

    def latency_totals(self) -> Dict[str, float]:
        """{count, sum, min, max} of the route latency distribution."""
        with self._lock:
            return self._lat_hist.snapshot()

    def cost_totals(self) -> Dict[str, float]:
        """{count, sum, min, max} of the per-request simulated cost."""
        with self._lock:
            return self._cost_hist.snapshot()

    def summary(self) -> Dict[str, Any]:
        """ONE consistent snapshot of every view, taken under the lock
        (a concurrent ``record`` lands either wholly before or wholly
        after it — funnels, totals and per-model counts always agree)."""
        from repro.cache import CACHE_KINDS
        with self._lock:
            lat_p = self._latency_percentiles_locked((0.5, 0.9, 0.99))
            return {
                "events": self._events_total,
                "fallback_rate": (self._fallbacks_total
                                  / self._events_total
                                  if self._events_total else 0.0),
                "fallback_funnel": dict(self._fallback_funnel),
                "admission_funnel": dict(self._admissions),
                "admission_by_tenant": {
                    t: dict(k)
                    for t, k in self._tenant_admissions.items()},
                "cache_funnel": {k: self._cache.get(k, 0)
                                 for k in CACHE_KINDS},
                "route_step": dict(self._route_step),
                "analyze_step": dict(self._analyze_step),
                "sharding": dict(self._sharding),
                "latency": lat_p,
                "latency_percentiles": lat_p,
                "latency_totals": self._lat_hist.snapshot(),
                "cost_totals": self._cost_hist.snapshot(),
                "qps": len(self._qps_ts) / self.window_s,
                "per_model": self._per_model_locked(),
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
            }
