"""Routing telemetry (production observability for the MLaaS use-case).

A thread-safe ledger the orchestrator/engine writes one event per
routed request into: model chosen, fallback kind, analyzer/route
latencies, simulated serving cost.  Exposes per-model aggregates,
fallback rates, stage-funnel statistics and a rolling-window QPS view —
what an operator needs to see that the router behaves in production.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional


@dataclass
class RouteEvent:
    ts: float
    model: str
    task_type: str
    domain: str
    complexity: float
    fallback: str = ""
    analyzer_s: float = 0.0
    route_s: float = 0.0
    sim_cost: float = 0.0
    thumbs: Optional[bool] = None


class Telemetry:
    def __init__(self, window_s: float = 60.0):
        self.window_s = window_s
        self._events: List[RouteEvent] = []
        self._admissions: Dict[str, int] = {}
        self._cache: Dict[str, int] = {}
        self._route_step: Dict[str, int] = {"dispatches": 0,
                                            "compiles": 0}
        self._sharding: Dict[str, int] = {"silent_replications": 0}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def record(self, event: RouteEvent) -> None:
        with self._lock:
            self._events.append(event)

    def record_decision(self, rq, *, sim_cost: float = 0.0) -> None:
        """Convenience: log an orchestrator RoutedQuery.

        Reads the cheap array-backed accessors (``rq.model`` /
        ``rq.fallback_kind``) rather than ``rq.decision`` so logging a
        lazily-materialized batch row does not force the full decision
        object into existence."""
        self.record(RouteEvent(
            ts=time.time(), model=rq.model,
            task_type=rq.sig.task_type, domain=rq.sig.domain,
            complexity=rq.sig.complexity,
            fallback=rq.fallback_kind,
            analyzer_s=rq.analyzer_s, route_s=rq.route_s,
            sim_cost=sim_cost))

    def record_route_step(self, *, dispatches: int = 0,
                          compiles: int = 0) -> None:
        """Count fused routing-step device activity: ``dispatches`` is
        one per routed batch; ``compiles`` counts jit-cache misses of
        the bucketed executable (see ``kernels/ops.route_step``).  A
        healthy steady-state serving stream shows dispatches growing
        linearly and compiles FLAT after the warmup batches."""
        with self._lock:
            self._route_step["dispatches"] += int(dispatches)
            self._route_step["compiles"] += int(compiles)

    def route_step_stats(self) -> Dict[str, int]:
        """Fused-dispatch counters: {dispatches, compiles}."""
        with self._lock:
            return dict(self._route_step)

    def record_sharding(self, *, silent_replications: int = 0) -> None:
        """Count partition-spec fallbacks: ``silent_replications`` is
        how many times ``sharding.rules.maybe()`` quietly replicated a
        tensor because its named axis was absent from the mesh.  A
        non-zero steady-state value means a layout the operator thinks
        is sharded is actually N copies — surfaced loudly by
        ``launch/dryrun.py`` and here for dashboards."""
        with self._lock:
            self._sharding["silent_replications"] += \
                int(silent_replications)

    def sharding_stats(self) -> Dict[str, int]:
        """Partition-spec fallback counters: {silent_replications}."""
        with self._lock:
            return dict(self._sharding)

    def record_admission(self, kind: str, count: int = 1) -> None:
        """Count one deadline-admission outcome (``admitted`` /
        ``rerouted`` / ``shed`` — see ``repro.serving.load``)."""
        with self._lock:
            self._admissions[kind] = self._admissions.get(kind, 0) + count

    def admission_funnel(self) -> Dict[str, int]:
        """Deadline-admission outcome counts: how much traffic was
        admitted as routed, rerouted to a lower-ranked candidate to
        make its SLO, or shed as a guaranteed miss."""
        with self._lock:
            return dict(self._admissions)

    def record_cache(self, kind: str, count: int = 1) -> None:
        """Count one semantic-cache outcome (``hit`` / ``miss`` at
        lookup, ``stored`` / ``rejected`` at write-back — see
        ``repro.cache.CACHE_KINDS``)."""
        with self._lock:
            self._cache[kind] = self._cache.get(kind, 0) + count

    def cache_funnel(self) -> Dict[str, int]:
        """Semantic-cache outcome counts with a STABLE key set: every
        kind in ``repro.cache.CACHE_KINDS`` is always present (zeroed
        on an empty engine), so dashboards and tests can key into the
        funnel without existence checks."""
        from repro.cache import CACHE_KINDS
        with self._lock:
            return {k: self._cache.get(k, 0) for k in CACHE_KINDS}

    def attach_thumbs(self, model: str, thumbs_up: bool) -> None:
        with self._lock:
            for e in reversed(self._events):
                if e.model == model and e.thumbs is None:
                    e.thumbs = thumbs_up
                    return

    # ------------------------------------------------------------------
    def per_model(self) -> Dict[str, Dict[str, float]]:
        import numpy as np
        with self._lock:
            agg: Dict[str, Dict[str, float]] = {}
            lat: Dict[str, List[float]] = {}
            for e in self._events:
                a = agg.setdefault(e.model, dict(
                    requests=0, fallbacks=0, cost=0.0, route_s=0.0,
                    thumbs_up=0, thumbs_down=0))
                a["requests"] += 1
                a["fallbacks"] += bool(e.fallback)
                a["cost"] += e.sim_cost
                a["route_s"] += e.route_s
                lat.setdefault(e.model, []).append(e.analyzer_s + e.route_s)
                if e.thumbs is True:
                    a["thumbs_up"] += 1
                elif e.thumbs is False:
                    a["thumbs_down"] += 1
        for m, a in agg.items():
            a["fallback_rate"] = a["fallbacks"] / max(a["requests"], 1)
            n_fb = a["thumbs_up"] + a["thumbs_down"]
            a["satisfaction"] = (a["thumbs_up"] / n_fb) if n_fb else None
            # per-model routing-latency distribution, not just means:
            # operators alarm on tails, and means hide queueing spikes
            a["latency_p50_s"] = float(np.quantile(lat[m], 0.5))
            a["latency_p99_s"] = float(np.quantile(lat[m], 0.99))
        return agg

    def fallback_rate(self) -> float:
        with self._lock:
            if not self._events:
                return 0.0
            return sum(bool(e.fallback) for e in self._events) \
                / len(self._events)

    def fallback_funnel(self) -> Dict[str, int]:
        """Routed-request counts per fallback ladder stage.

        Keys follow ``routing.FALLBACK_LADDER`` ('' = primary fused-kNN
        hit); only stages that occurred appear.  The operator's view of
        how far down the ladder traffic is falling."""
        funnel: Dict[str, int] = {}
        with self._lock:
            for e in self._events:
                funnel[e.fallback] = funnel.get(e.fallback, 0) + 1
        return funnel

    def qps(self, now: Optional[float] = None) -> float:
        now = now if now is not None else time.time()
        with self._lock:
            recent = [e for e in self._events
                      if e.ts > now - self.window_s]
        return len(recent) / self.window_s

    def latency_percentiles(self, q=(0.5, 0.9, 0.99)) -> Dict[str, float]:
        import numpy as np
        with self._lock:
            lat = [e.analyzer_s + e.route_s for e in self._events]
        if not lat:
            return {f"p{int(x*100)}": 0.0 for x in q}
        return {f"p{int(x*100)}": float(np.quantile(lat, x)) for x in q}

    def summary(self) -> Dict[str, Any]:
        return {
            "events": len(self._events),
            "fallback_rate": self.fallback_rate(),
            "fallback_funnel": self.fallback_funnel(),
            "admission_funnel": self.admission_funnel(),
            "cache_funnel": self.cache_funnel(),
            "route_step": self.route_step_stats(),
            "sharding": self.sharding_stats(),
            "latency": self.latency_percentiles(),
            "per_model": self.per_model(),
        }
