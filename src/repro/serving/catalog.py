"""Catalog builder: the 10 assigned architectures ARE the MRES catalog.

Quality/ethics metrics come from each config module's synthetic EVAL
record (the paper treats these as pre-computed evaluation numbers in the
MRES); cost/latency metrics are DERIVED from the model's own roofline:
the decode-step latency is the max(compute, weight-streaming) term of
the architecture on a v5e chip, and cost-per-Mtok charges chip-seconds.
When a dry-run result JSON exists, its measured HLO terms override the
analytic estimate.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional

from repro.configs import ARCH_NAMES, get_config, get_eval, get_smoke
from repro.core.mres import MRES, ModelEntry
from repro.serving.runner import HBM_BW, PEAK_FLOPS, ModelRunner

CHIP_DOLLARS_PER_HOUR = 1.2          # v5e on-demand ballpark
RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def decode_latency_ms(name: str, batch: int = 128) -> float:
    """Per-token decode latency (ms) from the dry-run roofline if
    available, else from the analytic weight-streaming bound."""
    cfg = get_config(name)
    f = RESULTS / f"{name}__decode_32k__pod1.json"
    if f.exists():
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            chips = r["devices"]
            t_comp = r["flops"] / (chips * PEAK_FLOPS)
            t_mem = r["bytes_accessed"] / (chips * HBM_BW)
            return max(t_comp, t_mem) * 1e3
    n_act = cfg.n_active_params()
    flops = 2.0 * n_act * batch
    return max(flops / PEAK_FLOPS, 2.0 * n_act / HBM_BW) * 1e3


def cost_per_mtok(name: str) -> float:
    """Chip-seconds per token * 1e6 * $/chip-second."""
    lat_s = decode_latency_ms(name) / 1e3
    return lat_s * 1e6 * CHIP_DOLLARS_PER_HOUR / 3600.0


def build_entry(name: str, *, runner: Optional[ModelRunner] = None,
                smoke_runner: bool = False, seed: int = 0) -> ModelEntry:
    cfg = get_config(name)
    ev = get_eval(name)
    if runner is None and smoke_runner:
        runner = ModelRunner(get_smoke(name), seed=seed)
    raw = {
        "accuracy": float(ev["accuracy"]),
        "latency_ms": decode_latency_ms(name),
        "cost_per_mtok": cost_per_mtok(name),
        "helpfulness": float(ev["helpfulness"]),
        "harmlessness": float(ev["harmlessness"]),
        "honesty": float(ev["honesty"]),
        "steerability": float(ev["steerability"]),
        "creativity": float(ev["creativity"]),
    }
    return ModelEntry(
        name=name, raw_metrics=raw,
        task_types=tuple(ev["task_types"]),
        domains=tuple(ev["domains"]),
        family=cfg.arch_type, n_params=cfg.n_params(),
        generalist=bool(ev.get("generalist", cfg.arch_type == "dense")),
        runner=runner,
        meta={"config": cfg.name, "active_params": cfg.n_active_params()},
    )


def build_catalog(*, smoke_runners: bool = False, seed: int = 0,
                  archs=None) -> MRES:
    mres = MRES()
    for i, name in enumerate(archs or ARCH_NAMES):
        mres.register(build_entry(name, smoke_runner=smoke_runners,
                                  seed=seed + i))
    return mres
