"""Serving substrate: runners, catalog builder, batched engine, live
per-model load tracking for load-/SLO-aware routing, and the asyncio
multi-tenant front-end (micro-batch windows, token-bucket rate limits,
weighted-fair dequeue, streaming)."""
from repro.serving.load import ADMISSION_KINDS, LoadTracker, plan_admission

__all__ = ["ADMISSION_KINDS", "LoadTracker", "plan_admission",
           "TokenBucket", "TenantPolicy", "MicroBatcher",
           "AsyncServingEngine"]


def __getattr__(name):
    # the async front-end imports the engine stack (and transitively
    # jax); load it lazily so `from repro.serving import LoadTracker`
    # stays cheap for tools that only need the tracker
    if name in ("TokenBucket", "TenantPolicy", "MicroBatcher",
                "AsyncServingEngine"):
        from repro.serving import async_engine
        return getattr(async_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
