"""Serving substrate: runners, catalog builder, batched engine, live
per-model load tracking for load-/SLO-aware routing."""
from repro.serving.load import ADMISSION_KINDS, LoadTracker, plan_admission

__all__ = ["ADMISSION_KINDS", "LoadTracker", "plan_admission"]
