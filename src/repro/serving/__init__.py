"""Serving substrate: runners, catalog builder, batched engine."""
