"""Batched serving engine wired to the router (paper §3.5 inference
engine + the MLaaS use-case of §2).

Requests arrive as (text, preferences); the engine routes ALL requests
in one vectorized ``route_all`` pass (interactive mode) or one
sample-and-aggregate call (batch mode), groups requests by their routed
model, executes each group as ONE batched generate call on that model's
runner, and returns per-request results with latency / cost accounting.
With a real ``TaskAnalyzer`` attached, that ``route_all`` pass is ONE
fused device program per batch — token ids in, model choices out
(``kernels/analyze_step``); the engine itself needs no knowledge of
the fusion beyond the lazy ``RoutedQuery`` accessors it already uses.
Thumbs feedback flows back into the router's FeedbackStore, and
post-generation quality observations flow into the router's adaptive
bandit via ``observe`` (shaped rewards against each routed context).

When a ``LoadTracker`` is attached (``load=`` or via the router's
engine), the serving engine maintains the live per-model capacity
signals the router scores against (admit -> start -> finish per
request) and enforces per-request latency SLOs: a request carrying
``deadline_ms`` whose routed model's estimated wait+service misses the
deadline is rerouted to its best-scoring candidate that fits, or shed
outright when none can make it (``Response.admission`` records the
outcome; counts land in ``Telemetry.admission_funnel``).  A runner
exception during one model group's generate degrades ONLY that group:
its requests come back with ``admission="failed"`` (tokens=None,
``Response.error`` carrying the cause) while every other group in the
batch is served normally — one bad model never kills the batch.

When a ``SemanticCache`` is attached (``cache=`` or via the router),
``submit`` consults it FIRST: each request's (preference axes + text
sketch) key is looked up in one fused batched pass, and a hit
short-circuits the entire analyze -> route -> admit -> generate path —
no decode slot is taken, no admission is planned, and the stored
response comes back with ``Response.cache_hit`` set (counts land in
``Telemetry.cache_funnel``).  Misses proceed normally, carrying their
cache key on the routed query so ``observe`` can write the validated
response back.
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.orchestrator import OptiRoute
from repro.core.preferences import TaskSignature, resolve_batch
from repro.core.telemetry import RouteEvent
from repro.data.tokenizer import HashTokenizer
from repro.obs.trace import NOOP_SPAN
from repro.serving.load import LoadTracker, plan_admission


@dataclass
class Request:
    text: str
    prefs: Any                        # UserPreferences | profile name | dict
    id: int = 0
    max_new: int = 8
    deadline_ms: Optional[float] = None   # latency SLO (None = no SLO)
    tenant: str = ""                  # multi-tenant attribution (traces)


@dataclass
class Response:
    request: Request
    model: str
    sig: TaskSignature
    tokens: Optional[np.ndarray]
    sim_latency_s: float
    route_s: float
    analyzer_s: float
    fallback: str = ""
    rq: Any = None                    # RoutedQuery (adaptive loop handle)
    admission: str = "admitted"       # admitted | rerouted | shed | failed
    est_latency_s: float = 0.0        # admission-time wait+service estimate
    cache_hit: bool = False           # served from the semantic cache
    trace_id: str = ""                # this request's trace (obs.trace)
    trace_root: Any = None            # root Span handle (observe attaches)
    error: str = ""                   # failure detail (admission="failed"
                                      # or an intake rejection reason)

    @property
    def shed(self) -> bool:
        return self.admission == "shed"

    @property
    def failed(self) -> bool:
        return self.admission == "failed"

    @property
    def served(self) -> bool:
        """True when a model actually produced (or simulated) an
        answer — sheds never took a slot, fails took one but raised."""
        return self.admission in ("admitted", "rerouted")


class ServingEngine:
    def __init__(self, router: OptiRoute, *, prompt_len: int = 32,
                 vocab_hash: int = 4096,
                 load: Optional[LoadTracker] = None, cache=None,
                 tracer=None):
        self.router = router
        self.tok = HashTokenizer(vocab_hash)
        self.prompt_len = prompt_len
        self.load = load if load is not None \
            else getattr(router.engine, "load", None)
        self.cache = cache if cache is not None \
            else getattr(router, "cache", None)
        # span sink (obs.trace.Tracer): defaults to the router's, so
        # one tracer covers submit -> route -> kernel dispatch; the
        # attached cache inherits it too (its lookup span must nest
        # under the same batch trace)
        self.tracer = tracer if tracer is not None \
            else getattr(router, "tracer", None)
        if (self.cache is not None and self.tracer is not None
                and getattr(self.cache, "tracer", None) is None):
            self.cache.tracer = self.tracer
        router_cache = getattr(router, "cache", None)
        if cache is not None and router_cache is None:
            # the write-back lives in OptiRoute.observe — an
            # engine-attached cache must be visible there too, or every
            # lookup misses forever (keys stamped, nothing ever stored)
            router.cache = cache
        elif cache is not None and router_cache is not cache:
            # two different stores would split lookup (engine) from
            # write-back (router) into a permanent 0% hit rate
            raise ValueError("ServingEngine(cache=...) conflicts with "
                             "the router's own cache — attach ONE store")
        self.log: List[Response] = []

    def _tokens(self, texts: Sequence[str], vocab_size: int) -> np.ndarray:
        t = self.tok.encode_batch(texts, self.prompt_len)
        return np.clip(t, 0, vocab_size - 1).astype(np.int32)

    # ------------------------------------------------------------------
    def submit(self, requests: Sequence[Request], *,
               mode: str = "interactive") -> List[Response]:
        assert mode in ("interactive", "batch")
        if not requests:
            return []
        if mode == "batch":
            return self._submit_batch(requests)
        # interactive: the semantic cache answers repeats FIRST (one
        # fused batched lookup; a hit skips analyze/route/admit/
        # generate and takes no slot), then the misses flow through
        # one vectorized routing pass + deadline-aware admission +
        # grouped batched generation
        reqs = list(requests)
        out: List[Optional[Response]] = [None] * len(reqs)
        keys = fps = None
        miss = list(range(len(reqs)))
        tel = self.router.telemetry
        tr = self.tracer
        batch_span = tr.start_trace("submit", batch=len(reqs),
                                    mode="interactive") \
            if tr is not None else NOOP_SPAN
        with batch_span:
            # featurize each request's preferences EXACTLY once: the
            # resolved UserPreferences instances (with their memoized
            # weight vectors) feed the cache key vectors, the
            # fingerprint gates, AND — threaded through to route_all —
            # the routing task vectors, instead of re-resolving (and
            # for dict prefs, re-vectorizing) per consumer
            prefs_res = resolve_batch([r.prefs for r in reqs], len(reqs))
            if self.cache is not None:
                keys = self.cache.keys_for(prefs_res,
                                           [r.text for r in reqs])
                # the decoding budget joins the exact-match gate: a
                # 4-token answer must never serve a 256-token request
                fps = self.cache.fingerprints(
                    prefs_res, extras=[r.max_new for r in reqs])
                # entries materialize under the store's lock: a
                # concurrent eviction can never invalidate a hit
                # between lookup and use
                hit, entries, _ = self.cache.lookup_entries(keys, fps)
                if tel is not None:
                    for kind, n in self.cache.drain_events().items():
                        tel.record_cache(kind, n)
                miss = []
                for i, r in enumerate(reqs):
                    if tel is not None:
                        tel.record_cache("hit" if hit[i] else "miss")
                    if hit[i]:
                        e = entries[i]
                        out[i] = Response(
                            request=r, model=e.model, sig=e.sig,
                            tokens=e.response, sim_latency_s=0.0,
                            route_s=0.0, analyzer_s=0.0, cache_hit=True)
                    else:
                        miss.append(i)
            if miss:
                served = self._route_and_serve(
                    [reqs[i] for i in miss],
                    [prefs_res[i] for i in miss],
                    None if keys is None else keys[miss],
                    None if fps is None else fps[miss])
                for j, i in enumerate(miss):
                    out[i] = served[j]
        self._fanout_trace(reqs, out, batch_span)
        self.log.extend(out)            # type: ignore[arg-type]
        return out                      # type: ignore[return-value]

    def _fanout_trace(self, reqs: Sequence[Request],
                      out: Sequence[Response], batch_span) -> None:
        """Fan the batch-level spans out to one trace PER REQUEST: a
        ``request`` root carrying ids and verdicts, with child spans
        for exactly the stages that ran for it (a cache hit gets only
        its ``cache_lookup``; a shed request stops at ``admission``).
        Durations are the batch stage costs amortized per request.
        Each ``Response`` leaves with its ``trace_id``/``trace_root``
        stamped so later ``observe`` calls can attach to the tree."""
        tr = self.tracer
        if tr is None or not tr.enabled:
            return
        B = len(reqs)
        for r, resp in zip(reqs, out):
            root = tr.record_span(
                "request",
                duration_s=resp.analyzer_s + resp.route_s
                + resp.sim_latency_s,
                request_id=r.id, tenant=r.tenant, batch=B,
                batch_trace=batch_span.trace_id, model=resp.model,
                admission=resp.admission, cache_hit=resp.cache_hit)
            resp.trace_id = root.trace_id
            resp.trace_root = root
            if self.cache is not None:
                tr.record_span(
                    "cache_lookup", parent=root,
                    outcome="hit" if resp.cache_hit else "miss")
            if resp.cache_hit:   # short-circuit: no route/admit/generate
                continue
            tr.record_span("analyze", parent=root,
                           duration_s=resp.analyzer_s)
            tr.record_span("route_step", parent=root,
                           duration_s=resp.route_s,
                           fallback=resp.fallback)
            if self.load is not None and r.deadline_ms is not None:
                tr.record_span("admission", parent=root,
                               verdict=resp.admission,
                               est_latency_s=resp.est_latency_s)
            if resp.failed:
                # the group DID take a slot and raise — the trace tree
                # shows the failed generate stage, not a missing one
                tr.record_span("generate", parent=root,
                               duration_s=0.0, model=resp.model,
                               outcome="failed", error=resp.error)
            elif not resp.shed:
                tr.record_span("generate", parent=root,
                               duration_s=resp.sim_latency_s,
                               model=resp.model)

    def _route_and_serve(self, requests: Sequence[Request], prefs_res,
                         cache_keys, cache_fps) -> List[Response]:
        """Route -> admit -> generate for the cache-miss rows (or the
        whole batch when no cache is attached).  ``prefs_res`` carries
        the already-resolved per-request preferences so routing reuses
        the submit-time featurization."""
        routed_q = self.router.route_all([r.text for r in requests],
                                         prefs_res)
        if cache_keys is not None:
            # stamp each routed query with its write-back key: when the
            # outcome later validates well, observe() turns this miss
            # into the entry answering the next near-duplicate
            for j, rq in enumerate(routed_q):
                rq.cache_key = np.asarray(cache_keys[j])
                rq.cache_fp = int(cache_fps[j])
        routed = list(zip(requests, routed_q))
        col: Dict[str, int] = {}
        if self.load is not None:
            names = self.router.mres.snapshot()[1]
            col = {m: j for j, m in enumerate(names)}
            self.load.ensure(len(names))
        plans = []
        tel = self.router.telemetry
        # pending placements from EARLIER requests in this same batch:
        # request #50 of a burst must see the 49 ahead of it, or the
        # whole batch is waved through against a frozen snapshot
        # sized to the TRACKER (which may carry more arms than the
        # catalog) so estimated_latency_s can add it elementwise
        pending = np.zeros(self.load.n_models, np.int64) \
            if self.load is not None else None
        tr = self.tracer
        adm_span = tr.span("admission", batch=len(routed)) \
            if tr is not None and self.load is not None else NOOP_SPAN
        with adm_span:
            for r, rq in routed:
                if self.load is None:
                    plans.append((rq.model, "admitted", 0.0))
                    continue
                if r.deadline_ms is None:
                    # no SLO: admitted as routed, but the placement
                    # still counts toward what LATER requests in this
                    # batch see.  rq.model reads the batch arrays — the
                    # full decision object only materializes for
                    # deadline-carrying requests, whose candidate lists
                    # admission ranks over
                    model, kind, est = rq.model, "admitted", 0.0
                else:
                    # the funnel is recorded AFTER generation (one
                    # final outcome per request), not here: an admitted
                    # request whose group later fails must count as
                    # "failed", not "admitted"
                    model, kind, est = plan_admission(
                        rq.decision, self.load, col, r.deadline_ms,
                        pending=pending)
                plans.append((model, kind, est))
                if pending is not None and kind != "shed":
                    pending[col[model]] += 1
        groups: Dict[Tuple[str, int], List[int]] = defaultdict(list)
        for i, (r, _) in enumerate(routed):
            model, kind, _ = plans[i]
            if kind != "shed":
                groups[(model, r.max_new)].append(i)
        out: List[Optional[Response]] = [None] * len(requests)
        gen_span = tr.span("generate", groups=len(groups)) \
            if tr is not None else NOOP_SPAN
        with gen_span:
            for (model, max_new), idxs in groups.items():
                entry = self.router.mres.entry(model)
                if self.load is not None:
                    self.load.admit(col[model], count=len(idxs))
                    self.load.start(col[model], count=len(idxs))
                gen, per_req_s, err = None, None, ""
                try:
                    if entry.runner is not None:
                        toks = self._tokens(
                            [requests[i].text for i in idxs],
                            entry.runner.cfg.vocab_size)
                        gen = entry.runner.generate(toks, max_new=max_new)
                    per_req_s = (gen.sim_latency_s / len(idxs)
                                 if gen is not None else
                                 entry.raw_metrics.get("latency_ms",
                                                       0.0) / 1e3)
                except Exception as e:             # noqa: BLE001
                    # one model group failing must never kill the other
                    # groups in the batch: degrade THIS group to
                    # admission="failed" responses and keep serving
                    err = f"{type(e).__name__}: {e}"
                finally:
                    # a generate failure must still release the slots,
                    # or the model's inflight count (and its routing
                    # penalty) stays inflated forever; no EWMA sample
                    # on failure (per_req_s is still None then)
                    if self.load is not None:
                        self.load.finish(col[model], per_req_s,
                                         count=len(idxs))
                for j, i in enumerate(idxs):
                    r, rq = routed[i]
                    # a rerouted request was SERVED by a different
                    # model than its routed decision, and a failed one
                    # produced no outcome at all; dropping the rq
                    # handle keeps observe() from crediting the wrong
                    # (or any) bandit arm
                    out[i] = Response(
                        request=r, model=model, sig=rq.sig,
                        tokens=None if (gen is None or err)
                        else gen.tokens[j],
                        sim_latency_s=0.0 if (gen is None or err)
                        else per_req_s,
                        route_s=rq.route_s, analyzer_s=rq.analyzer_s,
                        fallback=rq.fallback_kind,
                        rq=rq if (plans[i][1] == "admitted" and not err)
                        else None,
                        admission="failed" if err else plans[i][1],
                        est_latency_s=plans[i][2], error=err)
        for i, (r, rq) in enumerate(routed):   # shed: fail fast, no slot
            if out[i] is None:
                out[i] = Response(
                    request=r, model=plans[i][0], sig=rq.sig, tokens=None,
                    sim_latency_s=0.0, route_s=rq.route_s,
                    analyzer_s=rq.analyzer_s,
                    fallback=rq.fallback_kind, rq=None,
                    admission="shed", est_latency_s=plans[i][2])
        # ONE funnel entry per request, recording the FINAL outcome:
        # deadline-carrying requests land their admission verdict, and
        # a failed group is always recorded (even SLO-less traffic) —
        # the funnel is how an operator sees the failure at all
        if tel is not None:
            for i, (r, _) in enumerate(routed):
                resp = out[i]
                if resp.failed or (self.load is not None
                                   and r.deadline_ms is not None):
                    tel.record_admission(resp.admission,
                                         tenant=r.tenant or None)
        return out                      # type: ignore[return-value]

    def _submit_batch(self, requests: Sequence[Request]) -> List[Response]:
        """Sample-and-aggregate batch mode with the SAME serving
        lifecycle as interactive mode: the semantic cache answers
        repeats first, the miss rows share ONE routed decision
        (``route_batch``), the load tracker sees admit -> start ->
        finish around the single grouped generate, telemetry records
        one route event per served request, and the batch fans out to
        per-request traces.  Batch responses still carry no ``rq``
        handle (one aggregate decision has no per-query bandit
        context), so ``observe`` skips them — the cache is lookup-only
        in this mode."""
        reqs = list(requests)
        out: List[Optional[Response]] = [None] * len(reqs)
        tel = self.router.telemetry
        tr = self.tracer
        batch_span = tr.start_trace("submit", batch=len(reqs),
                                    mode="batch") \
            if tr is not None else NOOP_SPAN
        with batch_span:
            prefs_res = resolve_batch([r.prefs for r in reqs], len(reqs))
            miss = list(range(len(reqs)))
            if self.cache is not None:
                keys = self.cache.keys_for(prefs_res,
                                           [r.text for r in reqs])
                fps = self.cache.fingerprints(
                    prefs_res, extras=[r.max_new for r in reqs])
                hit, entries, _ = self.cache.lookup_entries(keys, fps)
                if tel is not None:
                    for kind, n in self.cache.drain_events().items():
                        tel.record_cache(kind, n)
                miss = []
                for i, r in enumerate(reqs):
                    if tel is not None:
                        tel.record_cache("hit" if hit[i] else "miss")
                    if hit[i]:
                        e = entries[i]
                        out[i] = Response(
                            request=r, model=e.model, sig=e.sig,
                            tokens=e.response, sim_latency_s=0.0,
                            route_s=0.0, analyzer_s=0.0, cache_hit=True)
                    else:
                        miss.append(i)
            if miss:
                served = self._serve_batch_group([reqs[i] for i in miss])
                for j, i in enumerate(miss):
                    out[i] = served[j]
        self._fanout_trace(reqs, out, batch_span)
        self.log.extend(out)            # type: ignore[arg-type]
        return out                      # type: ignore[return-value]

    def _serve_batch_group(self, requests: Sequence[Request]
                           ) -> List[Response]:
        """One aggregate decision -> one batched generate, with full
        tracker lifecycle, per-group failure degradation and telemetry
        (the batch-mode twin of ``_route_and_serve``'s group loop)."""
        texts = [r.text for r in requests]
        decision, _, stats = self.router.route_batch(
            texts, requests[0].prefs)
        model = decision.model
        entry = self.router.mres.entry(model)
        tel = self.router.telemetry
        col = -1
        if self.load is not None:
            names = self.router.mres.snapshot()[1]
            col = {m: j for j, m in enumerate(names)}[model]
            self.load.ensure(len(names))
            self.load.admit(col, count=len(requests))
            self.load.start(col, count=len(requests))
        gen, per_req_s, err = None, None, ""
        try:
            if entry.runner is not None:
                toks = self._tokens(texts, entry.runner.cfg.vocab_size)
                gen = entry.runner.generate(toks,
                                            max_new=requests[0].max_new)
            per_req_s = (gen.sim_latency_s / len(requests)
                         if gen is not None else
                         entry.raw_metrics.get("latency_ms", 0.0) / 1e3)
        except Exception as e:                     # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
        finally:
            if self.load is not None:
                self.load.finish(col, per_req_s, count=len(requests))
        agg = stats["aggregate_sig"]
        out = [Response(
            request=r, model=model, sig=agg,
            tokens=None if (gen is None or err) else gen.tokens[i],
            sim_latency_s=0.0 if (gen is None or err) else per_req_s,
            route_s=stats["route_s"] / len(requests),
            analyzer_s=stats["analyzer_s"] / len(requests),
            fallback=decision.fallback_kind,
            admission="failed" if err else "admitted",
            error=err) for i, r in enumerate(requests)]
        if tel is not None:
            sim_cost = entry.raw_metrics.get("cost_per_mtok", 0.0)
            for resp in out:
                # route_batch records nothing itself: one event per
                # request served, so sustained batch traffic shows up
                # in QPS / per-model aggregates like interactive does
                tel.record(RouteEvent(
                    ts=time.time(), model=model,
                    task_type=agg.task_type, domain=agg.domain,
                    complexity=agg.complexity,
                    fallback=decision.fallback_kind,
                    analyzer_s=resp.analyzer_s, route_s=resp.route_s,
                    sim_cost=sim_cost))
                if resp.failed:
                    tel.record_admission(
                        "failed", tenant=resp.request.tenant or None)
        return out

    # ------------------------------------------------------------------
    def feedback(self, resp: Response, thumbs_up: bool) -> float:
        return self.router.feedback.record(resp.sig, resp.model, thumbs_up)

    def observe(self, responses: Sequence[Response],
                qualities: Sequence[float]):
        """Close the adaptive loop with post-generation ground truth:
        shaped rewards (quality minus cost/latency penalties) flow into
        the router's bandit against each response's routed context.
        Responses without a routed-query handle are skipped: the
        sample-and-aggregate batch mode (no per-query context), and
        rerouted/shed requests (the routed decision's model is not the
        one that produced — or failed to produce — the outcome)."""
        if len(responses) != len(qualities):
            raise ValueError(f"{len(responses)} responses but "
                             f"{len(qualities)} qualities — observations "
                             "must align one-to-one")
        tr = self.tracer
        pairs = []
        for r, q in zip(responses, qualities):
            if r.rq is None:
                continue
            # hand the generated payload to the routed query so the
            # router's observe() can write it into the semantic cache
            if r.rq.response is None:
                r.rq.response = r.tokens
            # the outcome joins the request's own trace tree, not just
            # the router-level batch span
            if tr is not None and r.trace_root is not None:
                tr.record_span("observe", parent=r.trace_root,
                               quality=float(q), model=r.model)
            pairs.append((r.rq, q))
        if not pairs:
            return None
        return self.router.observe([p[0] for p in pairs],
                                   [p[1] for p in pairs])

    def summary(self) -> Dict[str, Any]:
        if not self.log:
            return {}
        by_model: Dict[str, int] = defaultdict(int)
        lat: Dict[str, List[float]] = defaultdict(list)
        admissions: Dict[str, int] = defaultdict(int)
        cache_hits = 0
        for r in self.log:
            if r.cache_hit:   # answered from the cache: no admission
                cache_hits += 1    # outcome, no slot, no model latency
                continue
            admissions[r.admission] += 1
            if not r.served:  # shed/failed requests were served by NO
                continue      # model — they only show up in the
                              # admission counts
            by_model[r.model] += 1
            lat[r.model].append(r.sim_latency_s + r.route_s
                                + r.analyzer_s)
        # per-model end-to-end latency PERCENTILES, not means: tails
        # are what SLOs are written against, and a mean hides the
        # queueing spikes load-aware routing exists to prevent
        latency = {m: {"p50_s": float(np.quantile(v, 0.5)),
                       "p99_s": float(np.quantile(v, 0.99))}
                   for m, v in lat.items()}
        return {
            "requests": len(self.log),
            "sim_latency_s": sum(r.sim_latency_s for r in self.log),
            "route_s": sum(r.route_s for r in self.log),
            "analyzer_s": sum(r.analyzer_s for r in self.log),
            "models": dict(by_model),
            "latency": latency,
            "admissions": dict(admissions),
            "cache_hits": cache_hits,
        }
