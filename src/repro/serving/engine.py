"""Batched serving engine wired to the router (paper §3.5 inference
engine + the MLaaS use-case of §2).

Requests arrive as (text, preferences); the engine routes ALL requests
in one vectorized ``route_all`` pass (interactive mode) or one
sample-and-aggregate call (batch mode), groups requests by their routed
model, executes each group as ONE batched generate call on that model's
runner, and returns per-request results with latency / cost accounting.
Thumbs feedback flows back into the router's FeedbackStore, and
post-generation quality observations flow into the router's adaptive
bandit via ``observe`` (shaped rewards against each routed context).
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.orchestrator import OptiRoute
from repro.core.preferences import TaskSignature
from repro.data.tokenizer import HashTokenizer


@dataclass
class Request:
    text: str
    prefs: Any                        # UserPreferences | profile name | dict
    id: int = 0
    max_new: int = 8


@dataclass
class Response:
    request: Request
    model: str
    sig: TaskSignature
    tokens: Optional[np.ndarray]
    sim_latency_s: float
    route_s: float
    analyzer_s: float
    fallback: str = ""
    rq: Any = None                    # RoutedQuery (adaptive loop handle)


class ServingEngine:
    def __init__(self, router: OptiRoute, *, prompt_len: int = 32,
                 vocab_hash: int = 4096):
        self.router = router
        self.tok = HashTokenizer(vocab_hash)
        self.prompt_len = prompt_len
        self.log: List[Response] = []

    def _tokens(self, texts: Sequence[str], vocab_size: int) -> np.ndarray:
        t = self.tok.encode_batch(texts, self.prompt_len)
        return np.clip(t, 0, vocab_size - 1).astype(np.int32)

    # ------------------------------------------------------------------
    def submit(self, requests: Sequence[Request], *,
               mode: str = "interactive") -> List[Response]:
        assert mode in ("interactive", "batch")
        if not requests:
            return []
        if mode == "batch":
            return self._submit_batch(requests)
        # interactive: ONE vectorized routing pass over all requests,
        # then group identical (model, max_new) for batched generation
        routed_q = self.router.route_all([r.text for r in requests],
                                         [r.prefs for r in requests])
        routed = list(zip(requests, routed_q))
        groups: Dict[Tuple[str, int], List[int]] = defaultdict(list)
        for i, (r, rq) in enumerate(routed):
            groups[(rq.decision.model, r.max_new)].append(i)
        out: List[Optional[Response]] = [None] * len(requests)
        for (model, max_new), idxs in groups.items():
            entry = self.router.mres.entry(model)
            gen = None
            if entry.runner is not None:
                toks = self._tokens([requests[i].text for i in idxs],
                                    entry.runner.cfg.vocab_size)
                gen = entry.runner.generate(toks, max_new=max_new)
            for j, i in enumerate(idxs):
                r, rq = routed[i]
                out[i] = Response(
                    request=r, model=model, sig=rq.sig,
                    tokens=None if gen is None else gen.tokens[j],
                    sim_latency_s=0.0 if gen is None
                    else gen.sim_latency_s / len(idxs),
                    route_s=rq.route_s, analyzer_s=rq.analyzer_s,
                    fallback=rq.decision.fallback_kind, rq=rq)
        self.log.extend(out)            # type: ignore[arg-type]
        return out                      # type: ignore[return-value]

    def _submit_batch(self, requests: Sequence[Request]) -> List[Response]:
        texts = [r.text for r in requests]
        decision, sigs, stats = self.router.route_batch(
            texts, requests[0].prefs)
        entry = self.router.mres.entry(decision.model)
        gen = None
        if entry.runner is not None:
            toks = self._tokens(texts, entry.runner.cfg.vocab_size)
            gen = entry.runner.generate(toks, max_new=requests[0].max_new)
        agg = stats["aggregate_sig"]
        out = [Response(
            request=r, model=decision.model, sig=agg,
            tokens=None if gen is None else gen.tokens[i],
            sim_latency_s=0.0 if gen is None
            else gen.sim_latency_s / len(requests),
            route_s=stats["route_s"] / len(requests),
            analyzer_s=stats["analyzer_s"] / len(requests),
            fallback=decision.fallback_kind) for i, r in enumerate(requests)]
        self.log.extend(out)
        return out

    # ------------------------------------------------------------------
    def feedback(self, resp: Response, thumbs_up: bool) -> float:
        return self.router.feedback.record(resp.sig, resp.model, thumbs_up)

    def observe(self, responses: Sequence[Response],
                qualities: Sequence[float]):
        """Close the adaptive loop with post-generation ground truth:
        shaped rewards (quality minus cost/latency penalties) flow into
        the router's bandit against each response's routed context.
        Responses without a routed-query handle (the sample-and-
        aggregate batch mode) carry no per-query context and are
        skipped."""
        if len(responses) != len(qualities):
            raise ValueError(f"{len(responses)} responses but "
                             f"{len(qualities)} qualities — observations "
                             "must align one-to-one")
        pairs = [(r.rq, q) for r, q in zip(responses, qualities)
                 if r.rq is not None]
        if not pairs:
            return None
        return self.router.observe([p[0] for p in pairs],
                                   [p[1] for p in pairs])

    def summary(self) -> Dict[str, float]:
        if not self.log:
            return {}
        by_model: Dict[str, int] = defaultdict(int)
        for r in self.log:
            by_model[r.model] += 1
        return {
            "requests": len(self.log),
            "sim_latency_s": sum(r.sim_latency_s for r in self.log),
            "route_s": sum(r.route_s for r in self.log),
            "analyzer_s": sum(r.analyzer_s for r in self.log),
            "models": dict(by_model),
        }
