"""Live per-model capacity signals for load- and SLO-aware routing.

The static router scores catalog metrics plus learned rewards; nothing
in that blend knows that the best-scoring model currently has forty
requests queued on four decode slots.  ``LoadTracker`` closes that gap:
one tracker per serving deployment maintains the live serving state of
every catalog model as packed ``(N,)`` arrays —

  queue      (N,) int64    admitted but not yet executing
  inflight   (N,) int64    currently occupying a decode slot
  capacity   (N,) float32  parallel decode slots (heterogeneous)
  ewma_s     (N,) float32  EWMA per-request service time (seconds)

so the routing hot path reads expected-wait estimates as one vectorized
gather.  The canonical lifecycle per request is

    admit(model) -> start(model) -> finish(model, service_s)

(queue += 1, queue -= 1 / inflight += 1, inflight -= 1 + EWMA fold).

Two derived views feed the router:

* ``estimated_wait_s`` — expected queueing delay before a new arrival
  starts executing: zero while a free slot exists
  (``queue + inflight < capacity``), else the completions that must
  land before it starts (``queue + inflight - capacity + 1``) drained
  at ``capacity`` requests per service time;
* ``penalty`` — the wait estimate squashed through ``w / (w + tau)``
  into [0, 1), so it joins the O(1)-scale score blend at the
  ``load_weight`` knob without a saturated model driving scores to
  -inf.  ``tau`` is the wait (seconds) at which the penalty reaches
  0.5 — an SLO-scale constant, not a tuning knob.

Thread-safe: the serving engine mutates counters from request threads
while the router snapshots them per batch.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence, Tuple

import numpy as np
from repro.analysis.sanitize import make_lock

# admission outcomes (telemetry funnel keys), in severity order:
# admitted/rerouted/shed are decided at admission time (plan_admission);
# failed is decided at generation time — the request WAS admitted and
# consumed slot lifecycle, but its model group's generate raised
ADMISSION_KINDS = ("admitted", "rerouted", "shed", "failed")


class LoadTracker:
    def __init__(self, n_models: int = 0, *, capacity: float = 4.0,
                 ewma_alpha: float = 0.3, default_service_s: float = 0.05,
                 tau_s: float = 0.25):
        assert 0.0 < ewma_alpha <= 1.0, ewma_alpha
        self.ewma_alpha = float(ewma_alpha)
        self.default_service_s = float(default_service_s)
        self.tau_s = float(tau_s)
        self._default_capacity = float(capacity)
        self._lock = make_lock("serving.load")
        self.n_models = 0
        self.queue = np.zeros(0, np.int64)
        self.inflight = np.zeros(0, np.int64)
        self.capacity = np.zeros(0, np.float32)
        self.ewma_s = np.zeros(0, np.float32)
        self.ensure(n_models)

    # ---------------- capacity ----------------
    def ensure(self, n_models: int,
               capacity: Optional[Sequence[float]] = None) -> None:
        """Grow to ``n_models`` arms (catalog growth, e.g. merging).

        ``capacity`` optionally sets the NEW arms' slot counts — either
        a new-arms-only ``(grow,)`` vector or a full-length
        ``(n_models,)`` vector (callers naturally hold the whole
        catalog's capacities; the tail covers the new arms and existing
        arms keep theirs).  A no-op when already at ``n_models``."""
        with self._lock:
            if n_models <= self.n_models:
                return
            grow = n_models - self.n_models
            if capacity is None:
                cap = np.full(grow, self._default_capacity, np.float32)
            else:
                cap = np.asarray(capacity, np.float32).reshape(-1)
                if cap.size == n_models:
                    cap = cap[self.n_models:]
                elif cap.size != grow:
                    raise ValueError(
                        f"capacity must have {grow} (new arms) or "
                        f"{n_models} (full catalog) entries, got "
                        f"{cap.size}")
            assert (cap > 0).all(), cap
            self.queue = np.concatenate([self.queue,
                                         np.zeros(grow, np.int64)])
            self.inflight = np.concatenate([self.inflight,
                                            np.zeros(grow, np.int64)])
            self.capacity = np.concatenate([self.capacity, cap])
            self.ewma_s = np.concatenate(
                [self.ewma_s,
                 np.full(grow, self.default_service_s, np.float32)])
            self.n_models = n_models

    def set_capacity(self, idx: int, capacity: float) -> None:
        with self._lock:
            assert capacity > 0, capacity
            self.capacity[idx] = capacity

    def reset(self) -> None:
        with self._lock:
            self.queue[:] = 0
            self.inflight[:] = 0
            self.ewma_s[:] = self.default_service_s

    # ---------------- lifecycle ----------------
    def admit(self, idx: int, count: int = 1) -> None:
        with self._lock:
            self.queue[idx] += count

    def admit_many(self, model_idx: np.ndarray) -> None:
        """Vectorized admit for one routed batch (bincount fold)."""
        model_idx = np.asarray(model_idx, np.int64)
        if model_idx.size == 0:
            return
        with self._lock:
            self.queue += np.bincount(model_idx, minlength=self.n_models)

    def start(self, idx: int, count: int = 1) -> None:
        with self._lock:
            self.queue[idx] = max(self.queue[idx] - count, 0)
            self.inflight[idx] += count

    def finish(self, idx: int, service_s: Optional[float] = None,
               count: int = 1) -> None:
        """Retire ``count`` requests; fold their (mean) realized service
        time into the EWMA when provided."""
        with self._lock:
            self.inflight[idx] = max(self.inflight[idx] - count, 0)
            if service_s is not None and service_s >= 0.0:
                a = self.ewma_alpha
                self.ewma_s[idx] = (1.0 - a) * self.ewma_s[idx] \
                    + a * float(service_s)

    def cancel(self, idx: int, *, queued: int = 0, inflight: int = 0
               ) -> None:
        """Roll back counters for ABANDONED requests: work that was
        admitted (and possibly started) but will never finish — e.g. a
        scheduler giving up on its backlog at ``max_ticks``.  Unlike
        ``finish`` this never folds an EWMA sample (no service
        happened), and it decrements the queue directly (the request
        never started).  Clamped at zero."""
        assert queued >= 0 and inflight >= 0, (queued, inflight)
        with self._lock:
            self.queue[idx] = max(self.queue[idx] - queued, 0)
            self.inflight[idx] = max(self.inflight[idx] - inflight, 0)

    # ---------------- derived views ----------------
    def snapshot(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
        """(queue, inflight, capacity, ewma_s) copies under one lock."""
        with self._lock:
            return (self.queue.copy(), self.inflight.copy(),
                    self.capacity.copy(), self.ewma_s.copy())

    @staticmethod
    def _wait_of(ahead: np.ndarray, c: np.ndarray, s: np.ndarray
                 ) -> np.ndarray:
        """Expected start delay given ``ahead`` outstanding requests on
        ``c`` slots at EWMA service time ``s``: zero while a free slot
        exists (``ahead < c``); otherwise ``ahead - c + 1`` completions
        must land first, draining at ``c`` per service time."""
        return np.maximum(ahead - c + 1.0, 0.0) / c * s

    def estimated_wait_s(self, cols: Optional[np.ndarray] = None
                         ) -> np.ndarray:
        """(C,) expected queueing delay before a NEW arrival starts.
        Zero until ``queue + inflight >= capacity`` — idle slots mean
        immediate start, so a single in-flight request on a multi-slot
        model is never penalized over an idle one."""
        q, f, c, s = self.snapshot()
        w = self._wait_of(q + f, c, s).astype(np.float32)
        return w if cols is None else w[np.asarray(cols)]

    def estimated_latency_s(self, cols: Optional[np.ndarray] = None,
                            extra: Optional[np.ndarray] = None
                            ) -> np.ndarray:
        """(C,) expected wait + service for a new arrival.

        ``extra`` (N,) adds not-yet-committed request counts on top of
        the tracked queue — the admission planner passes the requests
        it has already placed earlier in the SAME batch, so request
        #50 of a burst sees the 49 placements ahead of it instead of a
        frozen pre-batch snapshot."""
        q, f, c, s = self.snapshot()
        ahead = q + f if extra is None else q + f + np.asarray(extra)
        lat = (self._wait_of(ahead, c, s) + s).astype(np.float32)
        return lat if cols is None else lat[np.asarray(cols)]

    def penalty(self, cols: Optional[np.ndarray] = None) -> np.ndarray:
        """(C,) saturating load penalty in [0, 1): expected wait
        squashed through w / (w + tau).  This is the term
        ``RoutingEngine`` blends at ``load_weight``."""
        w = self.estimated_wait_s(cols)
        return (w / (w + self.tau_s)).astype(np.float32)

    def metrics(self, names: Optional[Sequence[str]] = None) -> dict:
        """Gauge view for the Prometheus export: per-model queue depth,
        inflight count, capacity and EWMA service time, keyed by model
        name when ``names`` is given (else by column index)."""
        q, f, c, s = self.snapshot()
        keys = [str(i) for i in range(self.n_models)] \
            if names is None else [str(m) for m in names[:self.n_models]]
        keys += [str(i) for i in range(len(keys), self.n_models)]
        return {
            "queue_depth": {k: int(v) for k, v in zip(keys, q)},
            "inflight": {k: int(v) for k, v in zip(keys, f)},
            "capacity": {k: float(v) for k, v in zip(keys, c)},
            "ewma_service_s": {k: float(v) for k, v in zip(keys, s)},
        }

    # ---------------- persistence (RouterState) ----------------
    def state(self) -> dict:
        """Packed-array snapshot for ``repro.checkpoint.RouterState``:
        one consistent copy of every per-arm array under the lock."""
        with self._lock:
            return {"queue": self.queue.copy(),
                    "inflight": self.inflight.copy(),
                    "capacity": self.capacity.copy(),
                    "ewma_s": self.ewma_s.copy()}

    def load_state(self, state: dict) -> None:
        """Restore a ``state()`` snapshot, REPLACING live counters.

        Restores every array bit-exactly (so penalties — and therefore
        routing — resume where the snapshot left off).  A restarted
        process whose in-flight work died with it can follow up with
        ``reset()`` to zero the transient queue/inflight counters while
        keeping the learned EWMAs and capacities."""
        cap = np.asarray(state["capacity"], np.float32)
        assert (cap > 0).all(), cap
        with self._lock:
            self.queue = np.asarray(state["queue"], np.int64).copy()
            self.inflight = np.asarray(state["inflight"], np.int64).copy()
            self.capacity = cap.copy()
            self.ewma_s = np.asarray(state["ewma_s"], np.float32).copy()
            self.n_models = int(self.queue.shape[0])


# ----------------------------------------------------------------------
# deadline-aware admission (shared by ServingEngine and the simulator)
# ----------------------------------------------------------------------

def plan_admission(decision, load: Optional[LoadTracker],
                   col_of, deadline_ms: Optional[float],
                   pending: Optional[np.ndarray] = None
                   ) -> Tuple[str, str, float]:
    """Decide how to serve one routed request against its SLO.

    ``decision`` is a RoutingDecision (model + ranked candidates),
    ``col_of`` maps model name -> catalog column, ``deadline_ms`` the
    request's latency SLO (None = no SLO).  ``pending`` (N,) counts
    requests the caller has already planned onto each model earlier in
    the same batch (not yet admitted to the tracker) so a burst cannot
    be waved through — or rerouted onto one alternate — against a
    frozen snapshot.  Returns ``(model, kind, est_latency_s)`` with
    kind in ``ADMISSION_KINDS``:

      * admitted — the routed model's estimated wait+service fits;
      * rerouted — it does not, but a lower-ranked candidate's does
        (first fit in score order: second choice before third, ...);
      * shed     — no candidate can meet the deadline; the caller
        should fail fast rather than burn a slot on a guaranteed miss.

    Without a tracker or deadline every request is simply admitted.
    """
    model = decision.model
    if load is None or deadline_ms is None:
        return model, "admitted", 0.0
    budget_s = float(deadline_ms) / 1e3
    cand = [m for m, _ in decision.candidates] or [model]
    if model not in cand:
        cand.insert(0, model)
    cols = np.array([col_of[m] for m in cand])
    est = load.estimated_latency_s(cols, extra=pending)
    if est[0] <= budget_s:
        return model, "admitted", float(est[0])
    fits = np.flatnonzero(est <= budget_s)
    if fits.size:
        j = int(fits[0])
        return cand[j], "rerouted", float(est[j])
    # guaranteed miss everywhere: report the least-bad estimate
    j = int(np.argmin(est))
    return cand[j], "shed", float(est[j])
