"""Asyncio front-end for the serving engine: async intake, micro-batch
aggregation, per-tenant fairness, and streaming responses.

The synchronous ``ServingEngine.submit`` is most efficient when handed
a BATCH: one fused ``route_all`` dispatch, one admission plan, one
grouped generate per model.  Real traffic arrives one request at a
time.  This module bridges the two:

* ``AsyncServingEngine.submit(request)`` is an awaitable that enqueues
  the request and resolves to its ``Response`` when its micro-batch
  completes.  A background flusher aggregates intake into windows of at
  most ``max_batch`` requests or ``max_wait_ms`` milliseconds —
  whichever closes first — and drives each window through the
  engine's single-dispatch route -> admit -> grouped-generate path on
  an executor thread, so the event loop never blocks on device work.

* Multi-tenant isolation happens at INTAKE, before a request can touch
  the router: each tenant has a ``TenantPolicy`` with a token-bucket
  rate limit (``rate``/``burst``), a backlog cap (``max_backlog``) and
  a fairness ``weight``.  Over-limit requests are rejected immediately
  with a shed ``Response`` (``error`` says why) — a flooding tenant
  exhausts its own bucket, not the shared catalog.  Dequeue is
  deficit-round-robin across tenant FIFOs, so when the aggregate
  backlog exceeds a window, tenants drain proportionally to their
  weights instead of first-come-first-flooded.

* ``stream(request)`` yields tokens as they decode, through a lazily
  built per-model ``ContinuousBatcher`` (fixed decode slots, shared KV
  cache) whose tick loop runs on the executor; concurrent streams to
  the same model share its slots.

``MicroBatcher`` (the intake/window/fair-dequeue core) is deliberately
clock-agnostic — every method takes ``now`` — so the soak harness can
replay hours-equivalent traffic in virtual time through EXACTLY the
aggregation logic production uses, and unit tests are deterministic.
"""
from __future__ import annotations

import asyncio
import threading
from collections import deque
from dataclasses import dataclass
from typing import (Any, AsyncIterator, Deque, Dict, List, Optional,
                    Sequence, Tuple)

from repro.serving.engine import Request, Response, ServingEngine
from repro.analysis.sanitize import make_lock

__all__ = ["TokenBucket", "TenantPolicy", "MicroBatcher",
           "AsyncServingEngine", "DEFAULT_TENANT"]

DEFAULT_TENANT = "default"

# intake rejection reasons (Response.error on an intake shed)
REJECT_RATE = "rate-limited"
REJECT_BACKLOG = "backlog-full"


class TokenBucket:
    """Classic token bucket in caller-supplied time: ``rate`` tokens/s
    refill up to a ``burst`` ceiling; ``try_take`` spends one.  Clock-
    agnostic (pass ``now``), so rate limits replay identically in the
    virtual-time soak and in wall-clock serving."""

    def __init__(self, rate: float, burst: float):
        assert rate > 0 and burst > 0, (rate, burst)
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t: Optional[float] = None

    def try_take(self, now: float, n: float = 1.0) -> bool:
        if self._t is None:
            self._t = now
        if now > self._t:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t) * self.rate)
            self._t = now
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False


@dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant intake knobs.

    ``weight``       fair-share weight for dequeue (DRR quantum);
    ``rate``         token-bucket refill, requests/s (None = unlimited);
    ``burst``        bucket depth (defaults to ``max(2 * rate, 1)``);
    ``max_backlog``  queued-request cap (None = unbounded) — beyond it
                     intake sheds instead of queueing unboundedly.
    """
    weight: float = 1.0
    rate: Optional[float] = None
    burst: Optional[float] = None
    max_backlog: Optional[int] = None

    def validate(self) -> "TenantPolicy":
        assert self.weight > 0, self.weight
        assert self.rate is None or self.rate > 0, self.rate
        assert self.burst is None or self.burst > 0, self.burst
        assert self.max_backlog is None or self.max_backlog > 0
        return self

    def make_bucket(self) -> Optional[TokenBucket]:
        if self.rate is None:
            return None
        return TokenBucket(self.rate,
                           self.burst if self.burst is not None
                           else max(2.0 * self.rate, 1.0))


class MicroBatcher:
    """Intake -> aggregation-window -> weighted-fair dequeue core.

    Requests are offered with a timestamp and buffered in per-tenant
    FIFOs.  A window is ``due`` when ``max_batch`` items are pending or
    the OLDEST pending item has waited ``max_wait_s``.  ``take`` drains
    up to ``max_batch`` items by deficit round-robin: each pass credits
    every backlogged tenant its policy weight, and a tenant spends one
    deficit unit per dequeued item — so over a sustained backlog,
    tenants drain in proportion to their weights regardless of arrival
    order.  Deficits reset when a tenant's queue empties (an idle
    tenant cannot bank credit).

    Thread-safe; every method takes an explicit ``now`` so the caller
    owns the clock (event loop, test, or virtual-time soak).
    """

    def __init__(self, *, max_batch: int = 32, max_wait_s: float = 0.005,
                 policies: Optional[Dict[str, TenantPolicy]] = None,
                 default_policy: TenantPolicy = TenantPolicy()):
        assert max_batch > 0 and max_wait_s >= 0.0
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.default_policy = default_policy.validate()
        self._policies = {t: p.validate()
                          for t, p in (policies or {}).items()}
        self._queues: Dict[str, Deque[Tuple[float, Any]]] = {}
        self._order: List[str] = []       # round-robin tenant order
        self._deficit: Dict[str, float] = {}
        self._buckets: Dict[str, Optional[TokenBucket]] = {}
        self._pending = 0
        self._lock = make_lock("serving.microbatcher")
        # intake accounting per tenant: offered / queued / rate-limited
        # / backlog-shed (the async engine exports these as gauges)
        self.stats: Dict[str, Dict[str, int]] = {}

    def policy(self, tenant: str) -> TenantPolicy:
        return self._policies.get(tenant, self.default_policy)

    def _stats(self, tenant: str) -> Dict[str, int]:
        return self.stats.setdefault(
            tenant, {"offered": 0, "queued": 0, "rate_limited": 0,
                     "backlog_shed": 0})

    # ------------------------------------------------------------------
    def offer(self, tenant: str, item: Any, now: float) -> str:
        """Try to enqueue ``item`` for ``tenant`` at time ``now``.
        Returns ``"queued"`` on success, or the rejection reason
        (``"rate-limited"`` / ``"backlog-full"``) — rejected items are
        NOT buffered; the caller degrades them immediately."""
        with self._lock:
            st = self._stats(tenant)
            st["offered"] += 1
            if tenant not in self._queues:
                self._queues[tenant] = deque()
                self._order.append(tenant)
                self._deficit[tenant] = 0.0
                self._buckets[tenant] = self.policy(tenant).make_bucket()
            bucket = self._buckets[tenant]
            if bucket is not None and not bucket.try_take(now):
                st["rate_limited"] += 1
                return REJECT_RATE
            pol = self.policy(tenant)
            if (pol.max_backlog is not None
                    and len(self._queues[tenant]) >= pol.max_backlog):
                st["backlog_shed"] += 1
                return REJECT_BACKLOG
            self._queues[tenant].append((now, item))
            self._pending += 1
            st["queued"] += 1
            return "queued"

    def pending(self) -> int:
        with self._lock:
            return self._pending

    def backlog(self) -> Dict[str, int]:
        """Current queued count per tenant (gauge view)."""
        with self._lock:
            return {t: len(q) for t, q in self._queues.items()}

    def _oldest_locked(self) -> Optional[float]:
        heads = [q[0][0] for q in self._queues.values() if q]
        return min(heads) if heads else None

    def due(self, now: float) -> bool:
        """True when a window should flush: the batch is full, or the
        oldest pending request has aged past the aggregation window."""
        with self._lock:
            if self._pending >= self.max_batch:
                return True
            oldest = self._oldest_locked()
            return (oldest is not None
                    and now - oldest >= self.max_wait_s)

    def next_deadline(self, now: float) -> Optional[float]:
        """Absolute time at which the current backlog becomes due
        (None when empty; may be <= ``now`` when already due)."""
        with self._lock:
            if self._pending == 0:
                return None
            if self._pending >= self.max_batch:
                return now
            oldest = self._oldest_locked()
            return oldest + self.max_wait_s if oldest is not None else None

    # ------------------------------------------------------------------
    def take(self, now: float, limit: Optional[int] = None) -> List[Any]:
        """Dequeue up to ``min(limit, max_batch)`` items by weighted
        deficit round-robin across backlogged tenants."""
        del now  # dequeue is instantaneous; signature mirrors offer()
        budget = self.max_batch if limit is None \
            else min(int(limit), self.max_batch)
        out: List[Any] = []
        with self._lock:
            active = [t for t in self._order if self._queues[t]]
            while len(out) < budget and active:
                for t in list(active):
                    q = self._queues[t]
                    # one weight quantum per pass; spend it greedily
                    self._deficit[t] += self.policy(t).weight
                    while q and self._deficit[t] >= 1.0 \
                            and len(out) < budget:
                        out.append(q.popleft()[1])
                        self._deficit[t] -= 1.0
                    if not q:
                        active.remove(t)
                        self._deficit[t] = 0.0  # no banked credit
                    if len(out) >= budget:
                        break
            self._pending -= len(out)
        return out


class AsyncServingEngine:
    """Event-loop front end over a synchronous ``ServingEngine``.

    One background flusher task owns the window clock: it sleeps until
    the batcher's next deadline, drains a window by weighted-fair
    dequeue, and runs ``engine.submit(window)`` on ``executor`` (the
    loop's default thread pool when None) — so at most one route/
    generate pass is in flight and the event loop stays responsive.
    Per-tenant backlog and intake counters are exported as telemetry
    gauges (``tenant_backlog{t}`` etc.) when the router carries a
    ``Telemetry``.

    Usage::

        aeng = AsyncServingEngine(engine, max_batch=32, max_wait_ms=5,
                                  policies={"acme": TenantPolicy(rate=50)})
        async with aeng:
            resp = await aeng.submit(Request(text=..., prefs=...,
                                             tenant="acme"))
    """

    def __init__(self, engine: ServingEngine, *, max_batch: int = 32,
                 max_wait_ms: float = 5.0,
                 policies: Optional[Dict[str, TenantPolicy]] = None,
                 default_policy: TenantPolicy = TenantPolicy(),
                 executor=None, stream_slots: int = 4,
                 stream_ctx_len: int = 128):
        self.engine = engine
        self.batcher = MicroBatcher(max_batch=max_batch,
                                    max_wait_s=max_wait_ms / 1e3,
                                    policies=policies,
                                    default_policy=default_policy)
        self._executor = executor
        self._stream_slots = int(stream_slots)
        self._stream_ctx_len = int(stream_ctx_len)
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._running = False
        self.windows: List[int] = []      # flushed window sizes
        # streaming state: model -> (batcher, condition); plus the
        # driver task currently ticking that batcher (if any)
        self._stream_state: Dict[str, Tuple[Any, asyncio.Condition]] = {}
        self._stream_tasks: Dict[str, asyncio.Task] = {}

    # ---------------- lifecycle ----------------
    async def start(self) -> "AsyncServingEngine":
        if self._task is not None:
            return self
        self._wake = asyncio.Event()
        self._running = True
        self._task = asyncio.get_running_loop().create_task(self._run())
        return self

    async def stop(self, *, drain: bool = True) -> None:
        """Stop the flusher.  ``drain=True`` (default) flushes the
        remaining backlog first so every accepted request resolves."""
        if self._task is None:
            return
        self._running = False
        if not drain:
            pending = self.batcher.take(0.0, limit=self.batcher.pending())
            while pending:
                for _, fut in pending:
                    if not fut.done():
                        fut.cancel()
                pending = self.batcher.take(
                    0.0, limit=self.batcher.pending())
        self._wake.set()
        await self._task
        self._task = None
        for t in list(self._stream_tasks.values()):
            await t

    async def __aenter__(self) -> "AsyncServingEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> bool:
        await self.stop()
        return False

    # ---------------- intake ----------------
    async def submit(self, request: Request) -> Response:
        """Enqueue one request; resolves when its window is served.
        Over-limit intake resolves IMMEDIATELY to a shed response
        (``admission="shed"``, ``error`` = reason) without touching
        the router."""
        if self._task is None:
            raise RuntimeError("AsyncServingEngine is not started — "
                               "use 'async with engine:' or await "
                               "start()")
        tenant = request.tenant or DEFAULT_TENANT
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        verdict = self.batcher.offer(tenant, (request, fut), loop.time())
        if verdict != "queued":
            return self._reject(request, tenant, verdict)
        self._wake.set()
        return await fut

    def _reject(self, request: Request, tenant: str,
                reason: str) -> Response:
        tel = self.router_telemetry()
        if tel is not None:
            tel.record_admission("shed", tenant=tenant)
            tel.inc(f"intake_{reason.replace('-', '_')}")
        resp = Response(request=request, model="", sig=None, tokens=None,
                        sim_latency_s=0.0, route_s=0.0, analyzer_s=0.0,
                        admission="shed", error=reason)
        self.engine.log.append(resp)
        return resp

    def router_telemetry(self):
        return getattr(self.engine.router, "telemetry", None)

    # ---------------- flusher ----------------
    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            now = loop.time()
            if self.batcher.pending() == 0:
                if not self._running:
                    break
                self._wake.clear()
                # re-check under the cleared event: an offer between
                # pending() and clear() also set the event, so no lost
                # wakeups
                if self.batcher.pending() == 0 and self._running:
                    await self._wake.wait()
                continue
            deadline = self.batcher.next_deadline(now)
            if self._running and deadline is not None and deadline > now:
                # batch not full and window still open: sleep until the
                # window closes or new intake arrives (which may fill
                # the batch early)
                self._wake.clear()
                try:
                    await asyncio.wait_for(self._wake.wait(),
                                           timeout=deadline - now)
                except asyncio.TimeoutError:
                    pass
                continue
            items = self.batcher.take(now)
            if items:
                await self._flush(items, loop)

    async def _flush(self, items: Sequence[Tuple[Request, asyncio.Future]],
                     loop) -> None:
        reqs = [r for r, _ in items]
        self.windows.append(len(reqs))
        tel = self.router_telemetry()
        if tel is not None:
            for t, n in self.batcher.backlog().items():
                tel.set_gauge(f"tenant_backlog_{t}", float(n))
            tel.set_gauge("window_size", float(len(reqs)))
        try:
            resps = await loop.run_in_executor(
                self._executor, self.engine.submit, reqs)
        except Exception as e:                     # noqa: BLE001
            # submit itself should degrade per group; anything that
            # still escapes (e.g. routing failure) fails THIS window's
            # futures, never the flusher loop
            for _, fut in items:
                if not fut.done():
                    fut.set_exception(e)
            return
        for (_, fut), resp in zip(items, resps):
            if not fut.done():
                fut.set_result(resp)

    # ---------------- streaming ----------------
    async def stream(self, request: Request) -> AsyncIterator[int]:
        """Yield tokens for one request as they decode.

        The request is routed individually (one fused single-row
        dispatch), then decoded through the routed model's shared
        ``ContinuousBatcher`` — concurrent streams to the same model
        interleave on its decode slots instead of serializing.  Models
        without a loaded runner (metrics-only catalog entries) cannot
        stream and raise ``ValueError``."""
        if self._task is None:
            raise RuntimeError("AsyncServingEngine is not started")
        from repro.serving.scheduler import ContinuousBatcher, SlotRequest

        eng = self.engine
        rq = eng.router.route_all([request.text], [request.prefs])[0]
        model = rq.model
        entry = eng.router.mres.entry(model)
        if entry.runner is None:
            raise ValueError(f"model {model!r} has no runner loaded — "
                             "streaming needs weights")
        if model not in self._stream_state:
            col = 0
            if eng.load is not None:
                names = eng.router.mres.snapshot()[1]
                col = {m: j for j, m in enumerate(names)}[model]
            cb = ContinuousBatcher(
                entry.runner.cfg, entry.runner.params,
                slots=self._stream_slots, ctx_len=self._stream_ctx_len,
                load=eng.load, model_idx=col)
            self._stream_state[model] = (cb, asyncio.Condition())
        cb, cond = self._stream_state[model]
        toks = eng._tokens([request.text],
                           entry.runner.cfg.vocab_size)[0]
        sr = SlotRequest(id=request.id, tokens=toks,
                         max_new=request.max_new)
        cb.submit(sr, truncate=True)
        self._ensure_stream_driver(model)
        sent = 0
        while True:
            async with cond:
                await cond.wait_for(
                    lambda: len(sr.out) > sent or sr.done
                    or sr in cb.cancelled)
            while sent < len(sr.out):
                yield sr.out[sent]
                sent += 1
            if sr.done or sr in cb.cancelled:
                return

    def _ensure_stream_driver(self, model: str) -> None:
        task = self._stream_tasks.get(model)
        if task is not None and not task.done():
            return
        self._stream_tasks[model] = \
            asyncio.get_running_loop().create_task(
                self._drive_stream(model))

    async def _drive_stream(self, model: str) -> None:
        cb, cond = self._stream_state[model]
        loop = asyncio.get_running_loop()
        while cb.queue_depth() > 0:
            await loop.run_in_executor(self._executor, cb.tick)
            async with cond:
                cond.notify_all()
        async with cond:
            cond.notify_all()
