"""ModelRunner: a servable handle around (config, params).

This is what an MRES entry's ``runner`` points at.  It owns the jitted
prefill / decode-step executables and a KV/SSD cache per active batch,
exposes ``generate`` (greedy, batched), and accounts simulated
cost/latency from the architecture's analytic FLOPs so the routing
benchmarks can charge each request to the model that served it.

``merged_with`` produces the model-soup runner for the §5 fallback.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.steps import make_decode_step, make_prefill_step

# TPU v5e hardware constants (roofline targets; DESIGN.md §Roofline)
PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclass
class GenerationResult:
    tokens: np.ndarray               # (B, new) generated ids
    logits_last: np.ndarray          # (B, V) final-step logits
    prefill_tokens: int
    decode_steps: int
    sim_latency_s: float             # roofline-simulated
    wall_s: float


class ModelRunner:
    def __init__(self, cfg: ModelConfig, params=None, seed: int = 0):
        self.cfg = cfg
        if params is None:
            params = M.init_params(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self._decode = jax.jit(make_decode_step(cfg))
        self._calls: List[Dict[str, float]] = []

    # ------------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, cfg: ModelConfig, path: str) -> "ModelRunner":
        """Cold-load a runner from an npz checkpoint (the MRES 'stores
        the models' contract — entries can point at checkpoint paths and
        materialize runners lazily)."""
        from repro.checkpoint import load
        params, meta = load(path)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        runner = cls(cfg, params=params)
        runner.meta = meta
        return runner

    def save_checkpoint(self, path: str, metadata=None) -> None:
        from repro.checkpoint import save
        save(path, self.params, {"config": self.cfg.name,
                                 **(metadata or {})})

    # ------------------------------------------------------------------
    def _batch(self, tokens: np.ndarray) -> Dict[str, jnp.ndarray]:
        b: Dict[str, jnp.ndarray] = {"tokens": jnp.asarray(tokens, jnp.int32)}
        cfg = self.cfg
        B = tokens.shape[0]
        if cfg.is_encdec:
            b["src_embeds"] = jnp.zeros((B, 16, cfg.frontend_dim),
                                        jnp.dtype(cfg.compute_dtype))
        elif cfg.frontend:
            b["frontend"] = jnp.zeros((B, cfg.frontend_tokens, cfg.frontend_dim),
                                      jnp.dtype(cfg.compute_dtype))
        return b

    def sim_step_latency(self, batch: int, decode: bool = True) -> float:
        """Roofline latency of one step on a single v5e chip: max of the
        compute term and the weight-streaming memory term."""
        n_act = self.cfg.n_active_params()
        flops = 2.0 * n_act * batch
        mem = 2.0 * n_act  # bf16 weight bytes touched once per step
        return max(flops / PEAK_FLOPS, mem / HBM_BW)

    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, max_new: int = 16
                 ) -> GenerationResult:
        """Greedy generation. tokens (B, L) int32 (right-aligned, no pad)."""
        t0 = time.time()
        cfg = self.cfg
        B, Lp = tokens.shape
        batch = self._batch(tokens)
        last, cache, pos = M.prefill(self.params, cfg, batch,
                                     max_len=Lp + max_new + 8)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        for _ in range(max_new - 1):
            logits, tok, cache = self._decode(
                self.params, cache, {"token": tok, "pos": pos})
            pos = pos + 1
            out.append(np.asarray(tok))
        sim = (self.sim_step_latency(B, decode=False) * Lp
               + self.sim_step_latency(B) * max_new)
        wall = time.time() - t0
        self._calls.append({"B": B, "prefill": Lp, "decode": max_new,
                            "sim_latency_s": sim, "wall_s": wall})
        return GenerationResult(
            tokens=np.concatenate(out, axis=1),
            logits_last=np.asarray(last),
            prefill_tokens=B * Lp, decode_steps=max_new,
            sim_latency_s=sim, wall_s=wall)

    # ------------------------------------------------------------------
    def merged_with(self, other: "ModelRunner", alpha: float) -> "ModelRunner":
        """Model-soup merge (paper §5): same-family weight average."""
        assert dataclasses.replace(self.cfg, name="") == \
            dataclasses.replace(other.cfg, name=""), "soup needs same family"
        from repro.core.merging import soup
        params = soup([self.params, other.params], [alpha, 1 - alpha])
        merged = ModelRunner.__new__(ModelRunner)
        merged.cfg = self.cfg
        merged.params = params
        merged._decode = self._decode           # same arch: reuse executable
        merged._calls = []
        return merged

    @property
    def stats(self) -> Dict[str, float]:
        if not self._calls:
            return {"calls": 0}
        return {
            "calls": len(self._calls),
            "sim_latency_s": float(sum(c["sim_latency_s"] for c in self._calls)),
            "wall_s": float(sum(c["wall_s"] for c in self._calls)),
            "decode_steps": int(sum(c["decode"] for c in self._calls)),
        }
