"""Continuous-batching scheduler (vLLM-style, simplified to fixed slots).

One scheduler per routed model: a fixed number of decode SLOTS share a
persistent KV/SSD cache.  Arriving requests are prefilled one at a time
into a free slot (their prefix cache is written into the slot), and all
active slots decode together on every tick — so short requests retire
and hand their slot to queued work without ever stalling long ones.
This is the serving substrate underneath the OptiRoute engine when
request rates exceed what one-shot batching handles.

The decode executable is compiled ONCE for the (slots, cache) shape;
admission and retirement are pure cache-slot updates.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.steps import make_decode_step
from repro.analysis.sanitize import make_lock


@dataclass
class SlotRequest:
    id: int
    tokens: np.ndarray               # (L,) prompt
    max_new: int
    out: List[int] = field(default_factory=list)
    slot: int = -1
    started_s: float = 0.0           # perf_counter at slot admission

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class ContinuousBatcher:
    """``load``/``model_idx`` optionally mirror this batcher's queue
    depth, slot occupancy and realized per-request service time into a
    ``repro.serving.load.LoadTracker`` arm, so the router's load-aware
    scoring sees this model's live state."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 ctx_len: int = 256, load=None, model_idx: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.ctx_len = ctx_len
        self.cache = M.init_cache(cfg, slots, ctx_len)
        self.pos = np.zeros(slots, np.int32)
        self.active: List[Optional[SlotRequest]] = [None] * slots
        self.queue: Deque[SlotRequest] = collections.deque()
        self.finished: List[SlotRequest] = []
        self._decode = jax.jit(make_decode_step(cfg))
        self._next_tok = np.zeros(slots, np.int32)
        self.ticks = 0
        self.load = load
        self.model_idx = model_idx
        self.cancelled: List[SlotRequest] = []
        # guards queue/active membership so submit() from request
        # threads, queue_depth() from the router's scoring path and the
        # tick driver all see one consistent outstanding-work count
        self._lock = make_lock("serving.scheduler")
        if load is not None:
            load.ensure(model_idx + 1)
            load.set_capacity(model_idx, float(slots))

    # ------------------------------------------------------------------
    def submit(self, req: SlotRequest, *, truncate: bool = False) -> None:
        """Queue a request.  The prompt plus every decode step whose
        output is kept must fit the slot cache: positions beyond
        ``ctx_len`` are written with jax's out-of-bounds ``.at[].set``,
        which drops the KV SILENTLY and corrupts later tokens.  The
        last kept token decodes at ``len(tokens) + max_new - 2``, so
        prompts longer than ``ctx_len - max(max_new - 1, 1)`` are
        rejected, or clipped to that limit with ``truncate=True``.
        """
        limit = self.ctx_len - max(req.max_new - 1, 1)
        if len(req.tokens) > limit:
            if not truncate:
                raise ValueError(
                    f"prompt of {len(req.tokens)} tokens with max_new="
                    f"{req.max_new} overflows the ctx_len={self.ctx_len} "
                    f"slot cache (limit {limit}; pass truncate=True to "
                    f"clip)")
            req.tokens = req.tokens[:limit]
        with self._lock:
            self.queue.append(req)
        if self.load is not None:
            self.load.admit(self.model_idx)

    def queue_depth(self) -> int:
        """Queued + active requests (the batcher's outstanding work).
        Taken under the batcher lock so a request mid-transition from
        queue to slot is counted exactly once, never zero or twice."""
        with self._lock:
            return (len(self.queue)
                    + sum(r is not None for r in self.active))

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self) -> None:
        """Prefill queued requests into free slots (slot-cache insert)."""
        for i in self._free_slots():
            with self._lock:
                if not self.queue:
                    return
                req = self.queue.popleft()
                # the slot claim happens in the SAME critical section
                # as the dequeue: queue_depth never sees the request in
                # neither place
                self.active[i] = req
            toks = jnp.asarray(req.tokens[None], jnp.int32)
            last, cache1, pos1 = M.prefill(self.params, self.cfg,
                                           {"tokens": toks},
                                           max_len=self.ctx_len)
            # write the single-sequence cache into slot i
            def insert(slot_cache, one):
                return slot_cache.at[:, i].set(one[:, 0])
            self.cache = jax.tree_util.tree_map(insert, self.cache, cache1)
            self.pos[i] = int(pos1[0])
            self._next_tok[i] = int(jnp.argmax(last[0]))
            req.slot = i
            req.started_s = time.perf_counter()
            if self.load is not None:
                self.load.start(self.model_idx)

    def _retire(self) -> None:
        for i, req in enumerate(self.active):
            if req is not None and req.done:
                with self._lock:
                    self.finished.append(req)
                    self.active[i] = None
                if self.load is not None:
                    self.load.finish(
                        self.model_idx,
                        time.perf_counter() - req.started_s)

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One scheduler step: admit -> joint decode -> collect -> retire.
        Returns the number of active slots that decoded."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        batch = {"token": jnp.asarray(self._next_tok[:, None], jnp.int32),
                 "pos": jnp.asarray(self.pos, jnp.int32)}
        logits, nxt, self.cache = self._decode(self.params, self.cache,
                                               batch)
        nxt = np.asarray(nxt)[:, 0]
        for i in live:
            self.active[i].out.append(int(self._next_tok[i]))
            self._next_tok[i] = nxt[i]
            self.pos[i] += 1
        self._retire()
        self.ticks += 1
        return len(live)

    def cancel(self) -> List[SlotRequest]:
        """Abandon all outstanding work and ROLL BACK the mirrored
        tracker arm: queued requests decrement the queue counter,
        active ones the inflight counter — with no EWMA sample (no
        service completed).  Without this, a scheduler that gives up
        (``max_ticks``, shutdown) leaves the arm's counters inflated
        forever and the router keeps penalizing a model that is
        actually idle.  Returns the dropped requests (also appended to
        ``self.cancelled``).  Not safe concurrently with ``tick``:
        call it from the tick driver."""
        with self._lock:
            queued = list(self.queue)
            self.queue.clear()
            active = [r for r in self.active if r is not None]
            for i in range(self.slots):
                self.active[i] = None
        if self.load is not None and (queued or active):
            self.load.cancel(self.model_idx, queued=len(queued),
                             inflight=len(active))
        for r in active:
            r.slot = -1
        dropped = queued + active
        self.cancelled.extend(dropped)
        return dropped

    def run_until_drained(self, max_ticks: int = 10_000, *,
                          cancel_leftover: bool = True
                          ) -> List[SlotRequest]:
        """Tick until no work remains or ``max_ticks`` is reached.  On
        a ``max_ticks`` exit the leftover queue/slots are cancelled by
        default so the mirrored tracker arm nets back to zero instead
        of staying inflated forever; pass ``cancel_leftover=False`` to
        keep the backlog (and its tracker counters) for a later drain.
        """
        # lint: ignore[lock-unlocked-read] -- run_until_drained is the
        # single tick-driver thread; submitters only ever grow `queue`,
        # so a stale read here costs one extra loop iteration, not a
        # torn decision (tick() re-checks everything under the lock)
        while (self.queue or any(r is not None for r in self.active)) \
                and self.ticks < max_ticks:
            self.tick()
        if cancel_leftover and (
                self.queue or any(r is not None for r in self.active)):
            self.cancel()
        return self.finished
