"""Step builders: train_step / prefill_step / decode (serve) step.

These are the functions the launcher jits; the dry-run lowers them with
ShapeDtypeStruct inputs against the production mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig()):
    def train_step(params, opt_state, batch):
        (total, (lm, aux)), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, cfg, batch)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": lm, "aux": aux, "total": total, "gnorm": gnorm}
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, *, long_mode: bool = False,
                      max_len: int = 0):
    def prefill_step(params, batch):
        last, cache, pos = M.prefill(params, cfg, batch, long_mode=long_mode,
                                     max_len=max_len)
        return last, cache, pos
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, long_mode: bool = False):
    def serve_step(params, cache, batch):
        logits, cache = M.decode_step(params, cfg, cache, batch,
                                      long_mode=long_mode)
        # greedy next token (serving engines may sample outside the jit)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return logits, next_tok, cache
    return serve_step
