"""Minimal pure-JAX AdamW (no optax dependency)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """One AdamW step with linear warmup + gradient clipping."""
    step = opt_state["step"] + 1
    warm = jnp.minimum(1.0, step.astype(jnp.float32) / max(cfg.warmup_steps, 1))
    lr = cfg.lr * warm

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree_util.tree_map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, opt_state["mu"], grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, opt_state["nu"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, gnorm
