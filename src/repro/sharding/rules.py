"""Sharding rules: param/activation/cache PartitionSpecs per architecture.

Baseline layout ("2D"):
  * weight matrices shard their input-feature (d_model) dim over 'data'
    (FSDP-style) and their output-feature dim over 'model' (tensor
    parallel); out-projections are the transpose.
  * MoE expert stacks shard experts over 'model' and d_model over 'data'.
  * a dim is only sharded if its size is divisible by the mesh axis —
    otherwise it silently stays replicated (``maybe``).
  * batch shards over ('pod','data'); for batch=1 long-context decode the
    cache length axis shards over ('pod','data') and heads stay local —
    attention becomes a GSPMD partial-softmax (flash-decode style).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def data_axes(mesh: Mesh):
    """The (possibly compound) batch-parallel axis."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# silent-replication audit: ``maybe`` falling back to None is usually
# intentional (1-D norms, odd vocab tails) but can silently hide a
# mis-sized mesh axis that replicates a tensor meant to be sharded —
# at mega-catalog sizes that is a multi-GB surprise per device.  Every
# fallback bumps this counter; ``launch/dryrun.py`` snapshots it
# around spec construction, warns, and records it into Telemetry.
_SILENT_REPLICATIONS = {"count": 0}


def silent_replication_count() -> int:
    """Total ``maybe`` calls that silently replicated so far."""
    return _SILENT_REPLICATIONS["count"]


def reset_silent_replication_count() -> None:
    _SILENT_REPLICATIONS["count"] = 0


def maybe(mesh: Mesh, dim: int, axis):
    """axis if dim divides evenly over it, else None (replicate).

    The replicate fallback is counted in
    ``silent_replication_count()`` so dry-runs can surface layouts
    that quietly lost their sharding to a non-dividing dim.
    """
    if dim % axis_size(mesh, axis) == 0:
        return axis
    _SILENT_REPLICATIONS["count"] += 1
    return None


# ----------------------------------------------------------------------
# routing-catalog specs (mega-catalog route_step)
# ----------------------------------------------------------------------

# the 1-D routing mesh axis the catalog (N) dimension shards over —
# built by ``launch.mesh.make_routing_mesh``
CATALOG_AXIS = "catalog"


def route_step_specs(mesh: Mesh) -> Dict[str, P]:
    """PartitionSpecs for the sharded fused route step's operands.

    Every (.., N) operand splits its catalog axis over
    ``CATALOG_AXIS``; per-query operands (T/W/ti/di), the ladder
    counts table and the scalar params are replicated — the batch is
    small next to the catalog, and replicating it makes the per-shard
    scan embarrassingly parallel with ONE cross-shard top-k merge
    tree as the only collective (kernels/route_step.py).
    """
    assert CATALOG_AXIS in mesh.axis_names, mesh.axis_names
    c = CATALOG_AXIS
    return {
        "e2": P(c, None),               # catalog block rows
        "e2s": P(c, None),              # int8 per-row scales
        "masks_table": P(None, c),      # mask rows x catalog cols
        "counts_table": P(),            # ladder counts: replicated
        "fb": P(None, c),               # feedback bias (B, N)
        "theta": P(c, None),            # bandit posterior rows
        "ainv_flat": P(c, None),
        "lpen": P(c),                   # load penalty (N,)
        "query": P(),                   # T/W/ti/di: replicated
        "params": P(),
    }


# ----------------------------------------------------------------------
# parameter specs
# ----------------------------------------------------------------------

def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> Dict[str, Any]:
    """PartitionSpec tree matching the params pytree.

    params_shape: pytree of ShapeDtypeStruct (or arrays) used for shapes.
    Stacked layer params have a leading n_layers dim (never sharded).
    """
    da = data_axes(mesh)

    def spec_for(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        shape = leaf.shape
        stacked = "layers" in names[:1] or names[0] in ("layers", "enc_layers")
        dims = list(shape[1:]) if stacked else list(shape)
        lead = [None] if stacked else []

        def two_d(rows_axis, cols_axis):
            return P(*lead, maybe(mesh, dims[0], rows_axis),
                     maybe(mesh, dims[1], cols_axis))

        leafname = names[-1]
        if leafname == "embed":
            # vocab over 'model' ONLY: with d replicated, the tied LM
            # head (x @ embed.T) has no sharded contraction, so logits
            # are born vocab-sharded instead of all-reduced — for 256k
            # vocabs that all-reduce is ~67 GB/device/step (EXPERIMENTS
            # §Perf, gemma2 hillclimb)
            if cfg.embed_shard_d:                # naive FSDP baseline
                return P(maybe(mesh, shape[0], "model"),
                         maybe(mesh, shape[1], da))
            return P(maybe(mesh, shape[0], "model"), None)
        if len(dims) == 3 and leafname in ("wi", "wg", "wo") and "moe" in names:
            # Expert-parallel over 'model'; the second shard axis is f
            # (not d): with d replicated, the in-projection einsums have
            # NO sharded contraction, and the only partial-sum reduction
            # is the final f-contraction -> one all-reduce of the
            # (tokens, d) layer output instead of all-reducing the much
            # larger (g, E, C, f) intermediates (EXPERIMENTS §Perf,
            # llama4 hillclimb: collective bytes -6.4x).
            e_ax = maybe(mesh, dims[0], "model")
            if cfg.moe_shard_axis == "d":        # naive FSDP baseline
                return P(*lead, e_ax, maybe(mesh, dims[1], da), None)
            if leafname == "wo":                 # (E, f, d): f is dims[1]
                return P(*lead, e_ax, maybe(mesh, dims[1], da), None)
            return P(*lead, e_ax, None, maybe(mesh, dims[2], da))
        if len(dims) == 2:
            if leafname in ("wo", "out_proj"):      # (f|qd|di, d): row-parallel
                return two_d("model", da)
            if leafname in ("wq", "wk", "wv", "wi", "wg", "router",
                            "in_proj", "w"):
                return two_d(da, "model")
            if leafname == "conv_w":
                return P(*lead, None, maybe(mesh, dims[1], "model"))
            return P(*lead, *([None] * len(dims)))
        # 1-D (norms, biases, A_log, ...) and scalars: replicate
        return P(*lead, *([None] * len(dims)))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# ----------------------------------------------------------------------
# activation / cache specs
# ----------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Dict[str, Any]:
    """Specs for a train/prefill input batch dict."""
    da = data_axes(mesh)

    def spec_for(path, leaf):
        b = leaf.shape[0]
        ax = maybe(mesh, b, da)
        return P(ax, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Dict[str, Any]:
    """Specs for the decode cache pytree (leading n_layers dim).

    If batch divides the data axes, shard batch; otherwise (batch=1
    long-context) shard the length/state axes instead.
    """
    da = data_axes(mesh)

    def spec_for(path, leaf):
        name = getattr(path[-1], "key", "")
        shape = leaf.shape          # (nL, B, ...)
        B = shape[1]
        b_ax = maybe(mesh, B, da)
        if name in ("k", "v", "cross_k", "cross_v", "k_scale", "v_scale"):
            C, H, hd = shape[2], shape[3], shape[4]
            if b_ax is not None:
                return P(None, b_ax, maybe(mesh, C, "model"), None, None)
            # batch=1: context-shard the cache over data axes, heads over model
            return P(None, None, maybe(mesh, C, da), maybe(mesh, H, "model"), None)
        if name == "ssd":           # (nL, B, H, P, N)
            H = shape[2]
            if b_ax is not None:
                return P(None, b_ax, maybe(mesh, H, "model"), None, None)
            return P(None, None, maybe(mesh, H, "model"), None, None)
        if name == "conv":          # (nL, B, K-1, conv_dim)
            cd = shape[3]
            return P(None, b_ax, None, maybe(mesh, cd, "model"))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def decode_batch_spec(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Dict[str, Any]:
    da = data_axes(mesh)

    def spec_for(path, leaf):
        b = leaf.shape[0]
        ax = maybe(mesh, b, da)
        return P(ax, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)
