"""Benchmark: observability must be (nearly) free on the hot path.

Tracing and metrics only earn their place in the serving loop if they
cost less than the noise floor of the thing they measure.  Two parts:

1. Overhead — the fused ``route_many_batch`` step with a live
   ``Telemetry`` ledger + ``Tracer`` span ring attached vs the same
   engine bare, measured as interleaved sustained-median rounds (both
   variants sample the same machine states).  ASSERTED: the
   instrumented path stays within ``MAX_OVERHEAD`` (5%) of bare.

2. Traced serving smoke (``--smoke``) — a full OptiRoute +
   ServingEngine pass with load tracker, semantic cache, deadlines and
   feedback, producing the CI artifacts the SLO gate consumes:
   ``results/metrics.prom`` (Prometheus text exposition) and
   ``results/trace_sample.jsonl`` (span ring dump).  Route-step
   buckets are warmed FIRST and a fresh Telemetry swapped in, so the
   exported counters describe steady state — the gate's
   ``route_step_compiles == 0`` rule is a real recompile-freedom
   check, not a warmup artifact.  The same rules are asserted
   in-process before CI ever sees the dump.

  PYTHONPATH=src:. python -m benchmarks.obs_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import pathlib

from benchmarks.common import REPO, cached_analyzer, save_result
from benchmarks.router_scale import (_random_queries, _sustained_median,
                                     _synthetic_catalog)
from repro.core.routing import RoutingEngine
from repro.core.telemetry import Telemetry
from repro.obs import (Tracer, evaluate_rules, metrics_from_prom,
                       parse_rules, write_prom)

# instrumented hot path must stay within 5% of bare (ISSUE acceptance)
MAX_OVERHEAD = 0.05

# the smoke's steady-state SLO contract; CI re-evaluates the same rules
# from the dumped .prom file via `python -m repro.obs.slo`
SMOKE_RULES = (
    "no_recompiles: route_step_compiles == 0",
    "analyze_recompiles: analyze_step_compiles == 0",
    "no_shedding:   shed_rate <= 0.0",
    "cache_warm:    cache_hit_rate >= 0.4",
    "events_flow:   events >= 1",
)


def bench_overhead(catalog_n: int = 4096, b: int = 256, rounds: int = 5,
                   seconds: float = 1.0, verbose: bool = True) -> dict:
    """Fused route step, bare vs fully instrumented (telemetry ledger
    + tracer span ring), interleaved sustained-median rounds."""
    mres = _synthetic_catalog(catalog_n)
    mres.embeddings()
    bare = RoutingEngine(mres, knn_k=8, use_kernel=False)
    tel, tracer = Telemetry(), Tracer()
    inst = RoutingEngine(mres, knn_k=8, use_kernel=False,
                         telemetry=tel, tracer=tracer)
    prefs, sigs = _random_queries(b)

    # warm both paths (shared jit bucket), then gate on parity: the
    # instrumentation must observe the route step, never perturb it
    rb = bare.route_many_batch(prefs, sigs)
    ri = inst.route_many_batch(prefs, sigs)
    assert rb.models() == ri.models(), "instrumented path changed routing"

    t_bare, t_inst = [], []
    for _ in range(rounds):
        t_bare.append(_sustained_median(
            lambda: bare.route_many_batch(prefs, sigs), seconds))
        t_inst.append(_sustained_median(
            lambda: inst.route_many_batch(prefs, sigs), seconds))
    bare_us = sorted(t_bare)[rounds // 2] / b * 1e6
    inst_us = sorted(t_inst)[rounds // 2] / b * 1e6
    overhead = inst_us / bare_us - 1.0

    # the instrumentation actually recorded something (a 0%-overhead
    # no-op tracer would "pass" the budget while measuring nothing)
    stats = tracer.stats()
    assert stats["spans_total"] > 0, stats
    assert tel.route_step_stats()["dispatches"] > 0
    assert overhead <= MAX_OVERHEAD, (
        f"observability overhead {overhead * 100:.1f}% exceeds "
        f"{MAX_OVERHEAD * 100:.0f}% budget "
        f"(bare={bare_us:.1f}us/q inst={inst_us:.1f}us/q)")
    if verbose:
        print(f"  obs overhead N={catalog_n:,} B={b}: "
              f"bare={bare_us:8.1f}us/q  instrumented={inst_us:8.1f}us/q  "
              f"overhead={overhead * 100:+5.1f}%  "
              f"spans={stats['spans_total']}")
    return {"catalog": catalog_n, "batch": b, "bare_us": bare_us,
            "instrumented_us": inst_us, "overhead": overhead,
            "budget": MAX_OVERHEAD, "spans_total": stats["spans_total"]}


def traced_serving_smoke(metrics_path=None, trace_path=None, b: int = 16,
                         verbose: bool = True) -> dict:
    """Full traced serving pass; dumps the CI gate artifacts and
    asserts the SLO rules in-process."""
    from repro.cache.semantic import SemanticCache
    from repro.core.orchestrator import OptiRoute
    from repro.core.preferences import PROFILES
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.load import LoadTracker

    metrics_path = pathlib.Path(metrics_path or REPO / "results"
                                / "metrics.prom")
    trace_path = pathlib.Path(trace_path or REPO / "results"
                              / "trace_sample.jsonl")

    mres = _synthetic_catalog(64, seed=7)
    analyzer, _ = cached_analyzer()
    tel, tracer = Telemetry(), Tracer()
    router = OptiRoute(mres, analyzer, telemetry=tel, tracer=tracer,
                       load=LoadTracker(len(mres), capacity=4.0),
                       cache=SemanticCache(capacity=512))
    engine = ServingEngine(router)
    profiles = list(PROFILES)

    def reqs(tag: str, deadline_ms=10_000.0):
        return [Request(text=f"{tag} request {i}: summarize the report",
                        prefs=profiles[i % len(profiles)], id=i,
                        max_new=4, tenant=f"team{i % 3}",
                        deadline_ms=deadline_ms if i % 2 else None)
                for i in range(b)]

    # warm every bucket the measured phase will touch (analyzer +
    # route-step jit caches), then swap in a FRESH ledger so the
    # exported counters are steady-state: compiles==0 is the real
    # recompile-freedom claim, not "we only counted after warmup"
    engine.submit(reqs("warmup"))
    fresh = Telemetry()
    router.telemetry = fresh
    router.engine.telemetry = fresh
    router.analyzer.telemetry = fresh

    out = engine.submit(reqs("steady"))        # all miss: full path
    engine.observe(out, [0.9] * len(out))      # validates -> cache fill
    again = engine.submit(reqs("steady"))      # repeat: cache hits
    for r in out:
        engine.feedback(r, thumbs_up=True)

    hits = sum(r.cache_hit for r in again)
    assert hits >= b // 2, f"cache refill too cold: {hits}/{b}"
    assert all(r.trace_id for r in out + again), "untraced response"

    metrics_path.parent.mkdir(parents=True, exist_ok=True)
    text = write_prom(metrics_path, fresh, load=engine.load,
                      tracer=tracer)
    n_spans = tracer.export_jsonl(trace_path)

    verdicts = evaluate_rules(parse_rules(SMOKE_RULES),
                              metrics_from_prom(text))
    for v in verdicts:
        if verbose:
            print("  " + v.line())
    breached = [v for v in verdicts if not v.ok]
    assert not breached, f"SLO breach in smoke: {breached}"
    if verbose:
        print(f"  artifacts: {metrics_path} ({len(text)}B), "
              f"{trace_path} ({n_spans} spans)")
    return {"requests": 2 * b, "cache_hits": int(hits),
            "spans_exported": n_spans,
            "rules": [v.line() for v in verdicts]}


def run():
    res = bench_overhead()
    smoke = traced_serving_smoke(b=16)
    save_result("obs_overhead", {**res, "smoke": smoke})
    return ("obs_overhead", res["instrumented_us"],
            f"overhead={res['overhead'] * 100:.1f}%<= "
            f"{MAX_OVERHEAD * 100:.0f}%")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant")
    args = ap.parse_args(argv)
    if args.smoke:
        res = bench_overhead(catalog_n=1024, b=128, rounds=3,
                             seconds=0.4)
        smoke = traced_serving_smoke(b=16)
        save_result("obs_overhead_smoke", {**res, "smoke": smoke})
        return 0
    name, us, derived = run()
    print(f"{name}: {us:.2f}us/q  {derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
