"""Benchmark 4 (paper §3.4): routing stays cheap (µs/query) as the
catalog grows — "approximate kNN ... ideal for real-time applications".

Two sections:

1. kNN primitive scaling — sweeps catalog size 1k -> 100k synthetic
   entries and times the numpy dense cosine top-k vs the jit'd fused
   top-k (XLA CPU standing in for the Pallas kernel; interpret=False
   requires TPU), plus the analytic TPU roofline.

2. End-to-end routing-decision throughput — batched ``route_many``
   (one vectorized kNN -> filter -> score pass) vs a loop of per-query
   ``route`` calls on a >=4096-entry catalog at B=256.  This is the
   serving engine's hot path; the batched path must win by >=5x.

``--smoke`` runs a seconds-scale version of both for CI.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.mres import MRES, ModelEntry
from repro.core.preferences import (DOMAINS, METRICS, TASK_TYPES,
                                    TaskSignature, UserPreferences)
from repro.core.routing import RoutingEngine, cosine_sim
from repro.kernels import ref as R

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _synthetic_catalog(n: int, seed: int = 0) -> MRES:
    rng = np.random.default_rng(seed)
    m = MRES()
    m.register_many([
        ModelEntry(
            name=f"syn{i}",
            raw_metrics={
                "accuracy": float(rng.random()),
                "latency_ms": float(rng.random() * 500 + 1),
                "cost_per_mtok": float(rng.random() * 20 + 0.1),
                "helpfulness": float(rng.random()),
                "harmlessness": float(rng.random()),
                "honesty": float(rng.random()),
                "steerability": float(rng.random()),
                "creativity": float(rng.random()),
            },
            task_types=tuple(rng.choice(TASK_TYPES,
                                        size=int(rng.integers(1, 4)),
                                        replace=False)),
            domains=tuple(rng.choice(DOMAINS, size=int(rng.integers(1, 3)),
                                     replace=False)),
            generalist=bool(rng.random() < 0.2))
        for i in range(n)])
    return m


def _random_queries(b: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    sigs = [TaskSignature(task_type=str(rng.choice(TASK_TYPES)),
                          domain=str(rng.choice(DOMAINS)),
                          complexity=float(rng.random()),
                          confidence=float(rng.random())) for _ in range(b)]
    prefs = [UserPreferences(weights={m: float(rng.random())
                                      for m in METRICS}) for _ in range(b)]
    return prefs, sigs


def _best_of(f, trials: int, inner: int) -> float:
    """Min-of-trials wall time per call (robust to scheduler noise)."""
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(inner):
            f()
        times.append((time.perf_counter() - t0) / inner)
    return min(times)


def bench_batched_vs_loop(catalog_n: int = 4096, b: int = 256,
                          repeats: int = 8, verbose: bool = True):
    """route_many (one vectorized pass) vs a loop of route() calls."""
    mres = _synthetic_catalog(catalog_n)
    mres.embeddings()                       # warm the cache
    eng = RoutingEngine(mres, knn_k=8, use_kernel=False)
    prefs, sigs = _random_queries(b)

    batch = eng.route_many(prefs, sigs)     # warm-up
    loop = [eng.route(p, s) for p, s in zip(prefs, sigs)]
    assert [d.model for d in batch] == [d.model for d in loop]

    t_batch = _best_of(lambda: eng.route_many(prefs, sigs),
                       trials=repeats, inner=3) / b * 1e6
    t_loop = _best_of(
        lambda: [eng.route(p, s) for p, s in zip(prefs, sigs)],
        trials=max(2, repeats // 2), inner=1) / b * 1e6

    speedup = t_loop / t_batch
    if verbose:
        print(f"  routing decisions N={catalog_n:,} B={b}: "
              f"loop={t_loop:8.1f}us/q  batched={t_batch:8.1f}us/q  "
              f"speedup={speedup:5.1f}x")
    return {"catalog": catalog_n, "batch": b, "loop_us": t_loop,
            "batched_us": t_batch, "speedup": speedup}


def run(sizes=(1_000, 10_000, 100_000), q_batch: int = 8, k: int = 8,
        d: int = 8, repeats: int = 20, decision_catalog: int = 4096,
        decision_batch: int = 256, verbose: bool = True):
    rng = np.random.default_rng(0)
    rows = []
    jit_topk = jax.jit(lambda e, q: R.router_topk(e, q, k))
    for n in sizes:
        emb = rng.random((n, d)).astype(np.float32)
        q = rng.random((q_batch, d)).astype(np.float32)

        # numpy path (route() per query)
        t0 = time.perf_counter()
        for _ in range(repeats):
            for i in range(q_batch):
                sims = cosine_sim(emb, q[i])
                np.argpartition(-sims, k)[:k]
        t_np = (time.perf_counter() - t0) / (repeats * q_batch) * 1e6

        # jit'd fused top-k (XLA CPU standing in for the TPU kernel)
        ej, qj = jnp.asarray(emb), jnp.asarray(q)
        jit_topk(ej, qj)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(repeats):
            jit_topk(ej, qj)[0].block_until_ready()
        t_jit = (time.perf_counter() - t0) / (repeats * q_batch) * 1e6

        # analytic TPU roofline for the Pallas kernel (128-padded)
        flops = 2.0 * n * 128 * q_batch
        bytes_ = n * 128 * 2.0        # catalog streamed once per q-block
        t_tpu = max(flops / PEAK_FLOPS, bytes_ / HBM_BW) / q_batch * 1e6

        rows.append({"catalog": n, "numpy_us": t_np, "xla_fused_us": t_jit,
                     "tpu_roofline_us": t_tpu})
        if verbose:
            print(f"  N={n:>7,}: numpy={t_np:8.1f}us  xla={t_jit:8.1f}us  "
                  f"tpu-roofline={t_tpu:6.2f}us")

    decisions = bench_batched_vs_loop(decision_catalog, decision_batch,
                                      verbose=verbose)
    save_result("router_scale", {"rows": rows, "decisions": decisions})
    biggest = rows[-1]
    # real-time claim: even at 100k the fused path is sub-millisecond
    assert biggest["xla_fused_us"] < 10_000
    # batched array-first routing must beat the per-query loop >=5x
    assert decisions["speedup"] >= 5.0, decisions
    return ("router_scale", biggest["xla_fused_us"],
            f"100k-catalog {biggest['xla_fused_us']:.0f}us/query "
            f"(tpu roofline {biggest['tpu_roofline_us']:.1f}us); "
            f"batched routing {decisions['speedup']:.1f}x vs loop "
            f"@B={decisions['batch']}/N={decisions['catalog']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for CI (small sizes, still "
                    "asserts the >=5x batched-routing speedup)")
    args = ap.parse_args(argv)
    if args.smoke:
        run(sizes=(1_000,), repeats=5, decision_catalog=4096,
            decision_batch=256, verbose=True)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
