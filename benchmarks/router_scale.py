"""Benchmark 4 (paper §3.4): routing stays cheap (µs/query) as the
catalog grows — "approximate kNN ... ideal for real-time applications".

Two sections:

1. kNN primitive scaling — sweeps catalog size 1k -> 100k synthetic
   entries and times the numpy dense cosine top-k vs the jit'd fused
   top-k (XLA CPU standing in for the Pallas kernel; interpret=False
   requires TPU), plus the analytic TPU roofline.

2. End-to-end routing-decision throughput — batched ``route_many``
   (one vectorized kNN -> filter -> score pass) vs a loop of per-query
   ``route`` calls on a >=4096-entry catalog at B=256.  This is the
   serving engine's hot path; the batched path must win by >=5x.

3. Fused single-dispatch route step — ``route_many_batch`` (ONE jitted
   device program per batch behind recompile-free shape buckets,
   array-first ``RoutingBatch`` output) vs the staged numpy reference
   path at B=256 / N=4096, reporting per-query latency, device
   dispatches per batch, and recompiles across a mixed-batch-size
   replay after warmup.  Asserted: exactly one dispatch per batch,
   zero steady-state recompiles, and a backend-dependent latency
   floor — >=2x on accelerators, no material regression on CPU (see
   ``bench_fused_vs_staged``).

``--smoke`` runs a seconds-scale version of all three for CI.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.mres import MRES, ModelEntry
from repro.core.preferences import (DOMAINS, METRICS, TASK_TYPES,
                                    TaskSignature, UserPreferences)
from repro.core.routing import RoutingEngine, cosine_sim
from repro.kernels import ref as R

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _synthetic_catalog(n: int, seed: int = 0) -> MRES:
    rng = np.random.default_rng(seed)
    m = MRES()
    m.register_many([
        ModelEntry(
            name=f"syn{i}",
            raw_metrics={
                "accuracy": float(rng.random()),
                "latency_ms": float(rng.random() * 500 + 1),
                "cost_per_mtok": float(rng.random() * 20 + 0.1),
                "helpfulness": float(rng.random()),
                "harmlessness": float(rng.random()),
                "honesty": float(rng.random()),
                "steerability": float(rng.random()),
                "creativity": float(rng.random()),
            },
            task_types=tuple(rng.choice(TASK_TYPES,
                                        size=int(rng.integers(1, 4)),
                                        replace=False)),
            domains=tuple(rng.choice(DOMAINS, size=int(rng.integers(1, 3)),
                                     replace=False)),
            generalist=bool(rng.random() < 0.2))
        for i in range(n)])
    return m


def _mega_catalog(n: int, seed: int = 0, clusters: int = 256) -> MRES:
    """Clustered synthetic catalog at mega scale.

    Vectorized (the per-entry rng of ``_synthetic_catalog`` takes
    minutes at N=100k) and CLUSTERED: raw metric profiles are sampled
    as family centers plus small noise, the structure real model
    catalogs have (size/price tiers of the same family) and the one
    the IVF coarse quantizer exploits."""
    rng = np.random.default_rng(seed)
    centers = rng.random((clusters, 8))
    raw = np.clip(centers[rng.integers(0, clusters, size=n)]
                  + rng.normal(0.0, 0.03, (n, 8)), 0.0, 1.0)
    tt_pick = rng.integers(0, len(TASK_TYPES), size=n)
    dm_pick = rng.integers(0, len(DOMAINS), size=n)
    gen = rng.random(n) < 0.2
    entries = [ModelEntry(
        name=f"mega{i}",
        raw_metrics={
            "accuracy": float(v[0]),
            "latency_ms": float(v[1] * 500 + 1),
            "cost_per_mtok": float(v[2] * 20 + 0.1),
            "helpfulness": float(v[3]),
            "harmlessness": float(v[4]),
            "honesty": float(v[5]),
            "steerability": float(v[6]),
            "creativity": float(v[7]),
        },
        task_types=(TASK_TYPES[tt_pick[i]],),
        domains=(DOMAINS[dm_pick[i]],),
        generalist=bool(gen[i]))
        for i, v in enumerate(raw)]
    m = MRES()
    m.register_many(entries)
    return m


def _random_queries(b: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    sigs = [TaskSignature(task_type=str(rng.choice(TASK_TYPES)),
                          domain=str(rng.choice(DOMAINS)),
                          complexity=float(rng.random()),
                          confidence=float(rng.random())) for _ in range(b)]
    prefs = [UserPreferences(weights={m: float(rng.random())
                                      for m in METRICS}) for _ in range(b)]
    return prefs, sigs


def _best_of(f, trials: int, inner: int) -> float:
    """Min-of-trials wall time per call (robust to scheduler noise)."""
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(inner):
            f()
        times.append((time.perf_counter() - t0) / inner)
    return min(times)


def bench_batched_vs_loop(catalog_n: int = 4096, b: int = 256,
                          repeats: int = 8, verbose: bool = True):
    """route_many (one vectorized pass) vs a loop of route() calls."""
    mres = _synthetic_catalog(catalog_n)
    mres.embeddings()                       # warm the cache
    eng = RoutingEngine(mres, knn_k=8, use_kernel=False)
    prefs, sigs = _random_queries(b)

    batch = eng.route_many(prefs, sigs)     # warm-up
    loop = [eng.route(p, s) for p, s in zip(prefs, sigs)]
    assert [d.model for d in batch] == [d.model for d in loop]

    t_batch = _best_of(lambda: eng.route_many(prefs, sigs),
                       trials=repeats, inner=3) / b * 1e6
    t_loop = _best_of(
        lambda: [eng.route(p, s) for p, s in zip(prefs, sigs)],
        trials=max(2, repeats // 2), inner=1) / b * 1e6

    speedup = t_loop / t_batch
    if verbose:
        print(f"  routing decisions N={catalog_n:,} B={b}: "
              f"loop={t_loop:8.1f}us/q  batched={t_batch:8.1f}us/q  "
              f"speedup={speedup:5.1f}x")
    return {"catalog": catalog_n, "batch": b, "loop_us": t_loop,
            "batched_us": t_batch, "speedup": speedup}


def _sustained_median(fn, seconds: float) -> float:
    """Run ``fn`` continuously for ``seconds`` and return the median
    per-call wall time of the SECOND half of the calls — the sustained
    steady-state cost, robust to burst/throttle swings that make
    min-of-trials microbenchmarks lie on shared CI machines."""
    ts = []
    end = time.perf_counter() + seconds
    while time.perf_counter() < end:
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    half = sorted(ts[len(ts) // 2:])
    return half[len(half) // 2]


def bench_fused_vs_staged(catalog_n: int = 4096, b: int = 256,
                          rounds: int = 3, seconds: float = 1.0,
                          verbose: bool = True):
    """Fused single-dispatch ``route_many_batch`` vs the staged numpy
    reference path, plus dispatch/recompile accounting.

    Latency is measured as interleaved sustained-median rounds (both
    paths sample the same machine states) and the speedup floor is
    backend-dependent: on an accelerator the fused path must win >=2x
    (one executable vs several dispatches + a host blend); on CPU —
    where XLA's top-k emitter and numpy's chunked argmax are the same
    order and the staged path is already vectorized — the fused path
    must simply not regress materially, and the STRUCTURAL claims are
    asserted exactly: one device dispatch per routed batch and zero
    recompiles across mixed batch sizes after warmup.
    """
    from repro.kernels import ops as K
    mres = _synthetic_catalog(catalog_n)
    mres.embeddings()
    eng = RoutingEngine(mres, knn_k=8, use_kernel=False)
    prefs, sigs = _random_queries(b)

    # parity gate before timing anything: the fused path must pick the
    # same models (score ties aside) as the staged reference
    fused = eng.route_many_batch(prefs, sigs)
    staged = eng.route_many_staged(prefs, sigs)
    agree = sum(f == s.model for f, s in zip(fused.models(), staged))
    assert agree >= int(0.99 * b), f"fused/staged diverge: {agree}/{b}"

    t_staged = []
    t_fused = []
    for _ in range(rounds):
        t_staged.append(_sustained_median(
            lambda: eng.route_many_staged(prefs, sigs), seconds))
        t_fused.append(_sustained_median(
            lambda: eng.route_many_batch(prefs, sigs), seconds))
    staged_us = sorted(t_staged)[rounds // 2] / b * 1e6
    fused_us = sorted(t_fused)[rounds // 2] / b * 1e6

    # steady-state serving: replay mixed batch sizes after warming the
    # power-of-two buckets — zero recompiles, one dispatch per batch
    for wb in (1, 9, 17, 33, 65, b):
        p2, s2 = _random_queries(wb, seed=wb)
        eng.route_many_batch(p2, s2)
    warm = K.route_step_stats()
    replay = (3, 17, b, 40, 1, 100, 8, b // 2)
    for i, rb in enumerate(replay):
        p2, s2 = _random_queries(rb, seed=1000 + i)
        eng.route_many_batch(p2, s2)
    stats = K.route_step_stats()
    # "dispatches" counts fused-op invocations (each issues exactly
    # one jitted call): the ==1/batch assert guards the CALL structure
    # — route_many_batch must never reintroduce host-side retry loops
    # or split the batch across multiple op calls.  The recompile
    # counter (jit-cache growth) is the device-side guarantee.
    dispatches = stats["route_step_dispatches"] \
        - warm["route_step_dispatches"]
    recompiles = stats["route_step_compiles"] \
        - warm["route_step_compiles"]

    backend = jax.default_backend()
    speedup = staged_us / fused_us
    floor = 2.0 if backend in ("tpu", "gpu") else 0.7
    if verbose:
        print(f"  fused route step N={catalog_n:,} B={b} "
              f"[{backend}]: staged={staged_us:8.1f}us/q  "
              f"fused={fused_us:8.1f}us/q  speedup={speedup:5.2f}x  "
              f"dispatches/batch={dispatches / len(replay):.2f}  "
              f"recompiles={recompiles}")
    return {"catalog": catalog_n, "batch": b, "backend": backend,
            "staged_us": staged_us, "fused_us": fused_us,
            "speedup": speedup, "speedup_floor": floor,
            "dispatches_per_batch": dispatches / len(replay),
            "replay_batches": len(replay),
            "recompiles_after_warmup": recompiles}


def bench_mega(catalog_n: int = 100_000, b: int = 64, n_devices: int = 4,
               nprobe: int = 8, verbose: bool = True):
    """Mega-catalog sweep (paper §3.4 at provider scale): one 100k-entry
    catalog served four ways — dense fp32, catalog-sharded fp32 across
    ``n_devices`` host devices, int8 quantized, and int8+IVF pruned —
    with the structural claims asserted:

      * the sharded fused step stays ONE device dispatch per routed
        batch with ZERO recompiles across mixed batch sizes after
        warmup (same guarantee the single-device path makes);
      * sharded fp32 picks BIT-identical candidates to single-device;
      * int8 and int8+IVF recall@k vs the exhaustive fp32 scan >= 0.99.

    Gated by the analytic roofline projection (``benchmarks/roofline.
    mega_projection``): if the model stops predicting >=2x for int8 or
    >=3x for int8+IVF at N=1M, this sweep fails before building the
    catalog.  Needs >= ``n_devices`` devices — on CPU set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
    """
    from benchmarks.roofline import mega_projection
    from repro.kernels import ops as K
    from repro.launch.mesh import make_routing_mesh

    proj = mega_projection()

    assert jax.device_count() >= n_devices, (
        f"need >= {n_devices} devices; set XLA_FLAGS="
        f"--xla_force_host_platform_device_count={n_devices}")
    mesh = make_routing_mesh(n_devices)

    t0 = time.perf_counter()
    mres = _mega_catalog(catalog_n)
    mres.embeddings()
    t_build = time.perf_counter() - t0
    prefs, sigs = _random_queries(b)

    eng_dense = RoutingEngine(mres, knn_k=8)
    eng_shard = RoutingEngine(mres, knn_k=8, mesh=mesh)
    eng_q8 = RoutingEngine(mres, knn_k=8, quantize=True)
    eng_ivf = RoutingEngine(mres, knn_k=8, quantize=True, ivf=True,
                            nprobe=nprobe)

    dense = eng_dense.route_many_batch(prefs, sigs)
    shard = eng_shard.route_many_batch(prefs, sigs)
    # the headline correctness claim: catalog-sharding is invisible —
    # fp32 across n_devices picks bit-identical ranked candidates
    assert shard.models() == dense.models()
    assert np.array_equal(shard.cand_idx, dense.cand_idx), \
        "sharded fp32 diverged from single-device"

    emb = mres.embeddings()
    embn = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)
    m_dim = emb.shape[1]
    # Worst-case |Δcosine| of symmetric int8 quantization of two unit
    # vectors (per-component error <= scale/2, scale <= 1/127).  At
    # d=8 the cosine gap between neighboring catalog entries sits BELOW
    # this resolution, so recall is scored against it: a retrieved
    # candidate whose exact score is within quantization tolerance of
    # the exact k-th best is a hit, not an error (the exact-set overlap
    # is also reported, unasserted).
    tol = float(np.sqrt(m_dim) / 127.0 + m_dim / (2.0 * 127.0 ** 2))

    def recall_at_k(test, ref):
        qn = ref.task_vectors / (np.linalg.norm(
            ref.task_vectors, axis=1, keepdims=True) + 1e-9)
        num = num_exact = den = 0
        for bq in range(len(ref)):
            rrow = [x for x in ref.cand_idx[bq].tolist() if x >= 0]
            trow = [x for x in test.cand_idx[bq].tolist() if x >= 0]
            if not rrow:
                continue
            c_kth = float((embn[rrow] @ qn[bq]).min())
            c_test = embn[trow] @ qn[bq]
            den += len(rrow)
            num += min(len(rrow), int((c_test >= c_kth - tol).sum()))
            num_exact += len(set(rrow) & set(trow))
        return num / max(den, 1), num_exact / max(den, 1)

    r_q8, r_q8_exact = recall_at_k(
        eng_q8.route_many_batch(prefs, sigs), dense)
    r_ivf, r_ivf_exact = recall_at_k(
        eng_ivf.route_many_batch(prefs, sigs), dense)

    # steady-state serving on the SHARDED engine: warm every power-of-
    # two batch bucket the replay touches, then replay mixed sizes —
    # one dispatch per batch, zero recompiles
    for wb in (1, 3, 9, 17, 33, b):
        p2, s2 = _random_queries(wb, seed=wb)
        eng_shard.route_many_batch(p2, s2)
    warm = K.route_step_stats()
    replay = (3, b, 17, 1, b // 2, 9)
    for i, rb in enumerate(replay):
        p2, s2 = _random_queries(rb, seed=500 + i)
        eng_shard.route_many_batch(p2, s2)
    stats = K.route_step_stats()
    dispatches = stats["route_step_dispatches"] \
        - warm["route_step_dispatches"]
    recompiles = stats["route_step_compiles"] \
        - warm["route_step_compiles"]

    t_shard = _best_of(lambda: eng_shard.route_many_batch(prefs, sigs),
                       trials=3, inner=2) / b * 1e6
    t_dense = _best_of(lambda: eng_dense.route_many_batch(prefs, sigs),
                       trials=3, inner=2) / b * 1e6

    result = {
        "catalog": catalog_n, "batch": b, "devices": n_devices,
        "backend": jax.default_backend(), "nprobe": nprobe,
        "catalog_build_s": t_build,
        "dense_us": t_dense, "sharded_us": t_shard,
        "sharded_bitexact": True,
        "recall_tol": tol,
        "recall_int8": r_q8, "recall_int8_ivf": r_ivf,
        "recall_int8_exact": r_q8_exact,
        "recall_int8_ivf_exact": r_ivf_exact,
        "dispatches_per_batch": dispatches / len(replay),
        "recompiles_after_warmup": recompiles,
        "projection": proj,
    }
    if verbose:
        print(f"  mega catalog N={catalog_n:,} B={b} "
              f"x{n_devices}dev [{result['backend']}]: "
              f"dense={t_dense:7.1f}us/q  sharded={t_shard:7.1f}us/q  "
              f"recall int8={r_q8:.4f} int8+ivf={r_ivf:.4f}  "
              f"dispatches/batch={result['dispatches_per_batch']:.2f}  "
              f"recompiles={recompiles}")
    assert dispatches == len(replay), (dispatches, len(replay))
    assert recompiles == 0, stats
    assert r_q8 >= 0.99, f"int8 recall {r_q8}"
    assert r_ivf >= 0.99, f"int8+IVF recall {r_ivf}"
    return result


def run(sizes=(1_000, 10_000, 100_000), q_batch: int = 8, k: int = 8,
        d: int = 8, repeats: int = 20, decision_catalog: int = 4096,
        decision_batch: int = 256, verbose: bool = True):
    rng = np.random.default_rng(0)
    if max(sizes) >= 100_000:
        # the 100k+ sweep is only worth running while the analytic
        # roofline model still backs the mega-catalog serving claims
        from benchmarks.roofline import mega_projection
        mega_projection()
    rows = []
    jit_topk = jax.jit(lambda e, q: R.router_topk(e, q, k))
    for n in sizes:
        emb = rng.random((n, d)).astype(np.float32)
        q = rng.random((q_batch, d)).astype(np.float32)

        # numpy path (route() per query)
        t0 = time.perf_counter()
        for _ in range(repeats):
            for i in range(q_batch):
                sims = cosine_sim(emb, q[i])
                np.argpartition(-sims, k)[:k]
        t_np = (time.perf_counter() - t0) / (repeats * q_batch) * 1e6

        # jit'd fused top-k (XLA CPU standing in for the TPU kernel)
        ej, qj = jnp.asarray(emb), jnp.asarray(q)
        jit_topk(ej, qj)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(repeats):
            jit_topk(ej, qj)[0].block_until_ready()
        t_jit = (time.perf_counter() - t0) / (repeats * q_batch) * 1e6

        # analytic TPU roofline for the Pallas kernel (128-padded)
        flops = 2.0 * n * 128 * q_batch
        bytes_ = n * 128 * 2.0        # catalog streamed once per q-block
        t_tpu = max(flops / PEAK_FLOPS, bytes_ / HBM_BW) / q_batch * 1e6

        rows.append({"catalog": n, "numpy_us": t_np, "xla_fused_us": t_jit,
                     "tpu_roofline_us": t_tpu})
        if verbose:
            print(f"  N={n:>7,}: numpy={t_np:8.1f}us  xla={t_jit:8.1f}us  "
                  f"tpu-roofline={t_tpu:6.2f}us")

    decisions = bench_batched_vs_loop(decision_catalog, decision_batch,
                                      verbose=verbose)
    fused = bench_fused_vs_staged(decision_catalog, decision_batch,
                                  verbose=verbose)
    save_result("router_scale", {"rows": rows, "decisions": decisions,
                                 "fused": fused})
    biggest = rows[-1]
    # real-time claim: even at 100k the fused path is sub-millisecond
    assert biggest["xla_fused_us"] < 10_000
    # batched array-first routing must beat the per-query loop >=5x
    assert decisions["speedup"] >= 5.0, decisions
    # the fused single-dispatch step: >=2x on accelerator backends
    # (dispatch overhead + kernel fusion are the point), no material
    # regression on CPU — and the structural claims exactly: one
    # device dispatch per batch, zero recompiles across mixed batch
    # sizes after warmup
    assert fused["speedup"] >= fused["speedup_floor"], fused
    assert fused["dispatches_per_batch"] == 1.0, fused
    assert fused["recompiles_after_warmup"] == 0, fused
    return ("router_scale", biggest["xla_fused_us"],
            f"100k-catalog {biggest['xla_fused_us']:.0f}us/query "
            f"(tpu roofline {biggest['tpu_roofline_us']:.1f}us); "
            f"batched routing {decisions['speedup']:.1f}x vs loop, "
            f"fused route step {fused['speedup']:.1f}x vs staged "
            f"({fused['fused_us']:.0f}us/q, "
            f"{fused['recompiles_after_warmup']} recompiles) "
            f"@B={decisions['batch']}/N={decisions['catalog']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for CI (small sizes, still "
                    "asserts the >=5x batched-routing speedup)")
    ap.add_argument("--mega-smoke", action="store_true",
                    help="mega-catalog sweep for CI: N=100k across 4 "
                    "host devices (set XLA_FLAGS=--xla_force_host_"
                    "platform_device_count=4), asserting bit-exact "
                    "sharding, >=0.99 int8/IVF recall, one dispatch "
                    "per batch and zero steady-state recompiles")
    args = ap.parse_args(argv)
    if args.mega_smoke:
        mega = bench_mega(verbose=True)
        save_result("router_scale", {"mega": mega})
    elif args.smoke:
        run(sizes=(1_000,), repeats=5, decision_catalog=4096,
            decision_batch=256, verbose=True)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
