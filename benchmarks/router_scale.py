"""Benchmark 4 (paper §3.4): routing stays cheap (µs/query) as the
catalog grows — "approximate kNN ... ideal for real-time applications".

Sweeps catalog size 1k -> 100k synthetic entries and times:
  * numpy dense cosine top-k (the small-catalog path),
  * the Pallas ``router_topk`` kernel (jit wall time on this host;
    interpret=False requires TPU, so on CPU we time the compiled XLA
    fallback of the same fused computation via ref.router_topk under
    jit — the TPU roofline estimate is derived analytically).

Also reports the analytic TPU roofline for the kernel: a (Q x N x 128)
bf16 matmul + mask + k-pass select is ~2*N*128 FLOPs/query and
~N*128*2 bytes streamed — at v5e rates that is sub-10µs even at N=100k.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.routing import cosine_sim
from repro.kernels import ref as R

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def run(sizes=(1_000, 10_000, 100_000), q_batch: int = 8, k: int = 8,
        d: int = 8, repeats: int = 20, verbose: bool = True):
    rng = np.random.default_rng(0)
    rows = []
    jit_topk = jax.jit(lambda e, q: R.router_topk(e, q, k))
    for n in sizes:
        emb = rng.random((n, d)).astype(np.float32)
        q = rng.random((q_batch, d)).astype(np.float32)

        # numpy path (route() per query)
        t0 = time.perf_counter()
        for _ in range(repeats):
            for i in range(q_batch):
                sims = cosine_sim(emb, q[i])
                np.argpartition(-sims, k)[:k]
        t_np = (time.perf_counter() - t0) / (repeats * q_batch) * 1e6

        # jit'd fused top-k (XLA CPU standing in for the TPU kernel)
        ej, qj = jnp.asarray(emb), jnp.asarray(q)
        jit_topk(ej, qj)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(repeats):
            jit_topk(ej, qj)[0].block_until_ready()
        t_jit = (time.perf_counter() - t0) / (repeats * q_batch) * 1e6

        # analytic TPU roofline for the Pallas kernel (128-padded)
        flops = 2.0 * n * 128 * q_batch
        bytes_ = n * 128 * 2.0        # catalog streamed once per q-block
        t_tpu = max(flops / PEAK_FLOPS, bytes_ / HBM_BW) / q_batch * 1e6

        rows.append({"catalog": n, "numpy_us": t_np, "xla_fused_us": t_jit,
                     "tpu_roofline_us": t_tpu})
        if verbose:
            print(f"  N={n:>7,}: numpy={t_np:8.1f}us  xla={t_jit:8.1f}us  "
                  f"tpu-roofline={t_tpu:6.2f}us")

    save_result("router_scale", {"rows": rows})
    biggest = rows[-1]
    # real-time claim: even at 100k the fused path is sub-millisecond
    assert biggest["xla_fused_us"] < 10_000
    return ("router_scale", biggest["xla_fused_us"],
            f"100k-catalog {biggest['xla_fused_us']:.0f}us/query "
            f"(tpu roofline {biggest['tpu_roofline_us']:.1f}us)")


if __name__ == "__main__":
    run()
