"""Benchmark 3 (paper §3.5): the thumbs feedback loop monotonically
reduces routing regret.

Protocol: the synthetic ground-truth quality table defines, per task
cluster, the best model (max quality).  Regret of a decision = quality
of best - quality of chosen.  A FIXED workload is replayed for several
rounds (the paper's "similar queries in the future follow the same
routing path").  Execution is epsilon-greedy (a small fraction of
requests go to a random catalog model — production systems get this
exploration for free from preference diversity); the user thumbs-up
iff quality meets their experience-calibrated expectation (the best
quality they have seen for that task cluster so far).  Regret is
measured on the EXPLOIT decision (what the router would pick), so the
curve isolates policy improvement; it must trend down.

A flat-threshold no-exploration ablation is also recorded: it shows the
loop stalls at "good enough" without exploration — an honest note the
paper itself does not make.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import UserPreferences
from repro.data.workload import make_workload, quality_of
from repro.serving.catalog import build_catalog


def entry_meta(e):
    return {"accuracy": e.raw_metrics["accuracy"],
            "task_types": e.task_types, "domains": e.domains}


def _loop(wl, rounds, seed, *, explore_eps, calibrated, verbose,
          thumbs_threshold=0.7):
    from repro.core.feedback import cluster_of
    mres = build_catalog(smoke_runners=False)
    entries = {e.name: e for e in mres.entries}
    names = list(entries)

    class _Oracle:
        def analyze(self, text):
            return next(r.sig for r in wl if r.text == text)

    router = OptiRoute(mres, _Oracle(), feedback_weight=2.0)
    prefs = UserPreferences(weights=dict(
        accuracy=0.9, cheapness=0.3, speed=0.2, helpfulness=0.5,
        harmlessness=0.5, honesty=0.5, steerability=0.2, creativity=0.2))

    rng = np.random.default_rng(seed)
    expectation = {}                        # cluster -> best quality seen
    regret_per_round, hit_per_round = [], []
    for rd in range(rounds):
        order = rng.permutation(len(wl))
        regs, hits = [], []
        for i in order:
            r = wl[i]
            rq = router.route(r.text, prefs)
            best = max(quality_of(entry_meta(e), r.sig)
                       for e in entries.values())
            exploit_q = quality_of(entry_meta(entries[rq.decision.model]),
                                   r.sig)
            regs.append(best - exploit_q)
            hits.append(exploit_q >= best - 1e-9)
            # execution: epsilon-greedy
            if explore_eps and rng.random() < explore_eps:
                used = str(rng.choice(names))
            else:
                used = rq.decision.model
            got = quality_of(entry_meta(entries[used]), r.sig)
            c = cluster_of(r.sig)
            if calibrated:
                expect = expectation.get(c, 0.5)
                up = got >= expect - 0.02
                expectation[c] = max(expect, got)
            else:
                up = got > thumbs_threshold
            router.feedback.record(r.sig, used, up)
        regret_per_round.append(float(np.mean(regs)))
        hit_per_round.append(float(np.mean(hits)))
        if verbose:
            print(f"  round {rd}: regret={regret_per_round[-1]:.4f} "
                  f"best-hit={hit_per_round[-1]:.2%}")
    return regret_per_round, hit_per_round


def run(rounds: int = 16, n_queries: int = 150, seed: int = 0,
        verbose: bool = True):
    wl = make_workload(n_queries, seed=seed)
    if verbose:
        print("  [explore+calibrated]")
    regret, hits = _loop(wl, rounds, seed, explore_eps=0.15,
                         calibrated=True, verbose=verbose)
    if verbose:
        print("  [ablation: no exploration, flat threshold]")
    regret_abl, hits_abl = _loop(wl, rounds, seed, explore_eps=0.0,
                                 calibrated=False, verbose=verbose)

    out = {"regret_per_round": regret, "best_hit_per_round": hits,
           "ablation_regret_per_round": regret_abl,
           "ablation_best_hit_per_round": hits_abl}
    first = float(np.mean(regret[:3]))
    last = float(np.mean(regret[-3:]))
    hit_gain = float(np.mean(hits[-3:]) - np.mean(hits[:3]))
    out["derived"] = {
        "regret_first3": first, "regret_last3": last,
        "regret_drop": first - last,
        "relative_drop": 0.0 if first == 0 else 1 - last / first,
        "best_hit_gain": hit_gain,
        "ablation_hit_gain": float(np.mean(hits_abl[-3:])
                                   - np.mean(hits_abl[:3])),
    }
    save_result("feedback", out)
    assert last <= first, "feedback loop must reduce regret"
    assert hit_gain > 0.03, "feedback loop must lift best-model hit rate"
    return ("feedback", 0.0,
            f"regret {first:.4f}->{last:.4f}, best-hit +{hit_gain:.1%} "
            f"(no-explore ablation +{out['derived']['ablation_hit_gain']:.1%})")


if __name__ == "__main__":
    run()
