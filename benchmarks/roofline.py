"""Benchmark 7 (deliverable g): roofline terms per (arch x shape x mesh)
from the compiled dry-run artifacts in results/dryrun/.

Per pair, three terms (seconds, per-chip):
  compute    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory     = HLO_bytes(per-device) / HBM_bw
  collective = collective_bytes(per-device) / link_bw

cost_analysis() of an SPMD-compiled module reports the PER-DEVICE
program (its argument sizes match the per-device parameter shard), so
no further division by chip count is applied.

MODEL_FLOPS uses the standard analytic formulas (6·N·D train,
2·N_active·D prefill, 2·N_active·B decode).  The usefulness ratio
MODEL/HLO can exceed 1: 6·N·D charges the embedding table as a matmul
while the compiled program gathers rows (0 FLOPs) — the ratio still
catches remat/redundancy (lower = more recompute).
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from benchmarks.common import save_result

REPO = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = REPO / "results" / "dryrun"

PEAK_FLOPS = 197e12          # v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

SHAPE_TOKENS = {
    "train_4k": ("train", 4096 * 256),
    "prefill_32k": ("prefill", 32768 * 32),
    "decode_32k": ("decode", 128),
    "long_500k": ("decode", 1),
}


def model_flops(rec: Dict) -> float:
    kind, tokens = SHAPE_TOKENS[rec["shape"]]
    n_act = rec["n_active_params"]
    n = rec["n_params"]
    if kind == "train":
        return 6.0 * (n_act if n_act != n else n) * tokens
    return 2.0 * n_act * tokens


def analyze(rec: Dict) -> Dict:
    chips = rec["devices"]
    coll = sum(rec["collective_bytes"].values())
    t_c = rec["flops"] / PEAK_FLOPS
    t_m = rec["bytes_accessed"] / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": f"{chips}", "compute_s": t_c, "memory_s": t_m,
        "collective_s": t_x, "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / chips / max(rec["flops"], 1.0),
        "hbm_gb_per_chip": (rec["memory"]["argument_size_bytes"]
                            + rec["memory"]["temp_size_bytes"]
                            + rec["memory"]["output_size_bytes"]) / 2**30,
        "step_s_bound": max(terms.values()),
    }


DRYRUN_OPT = REPO / "results" / "dryrun_opt"


def load_all(pod: str = "pod1", directory: Optional[pathlib.Path] = None
             ) -> List[Dict]:
    rows = []
    for f in sorted((directory or DRYRUN).glob(f"*__{pod}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok":
            rows.append(analyze(rec))
        elif rec.get("status") == "n/a":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": pod, "dominant": "n/a",
                         "reason": rec.get("reason", "")})
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | HBM GiB/chip |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["dominant"] == "n/a":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"n/a | — | — |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
                f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
                f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                f"{r['hbm_gb_per_chip']:.2f} |")
    return hdr + "\n".join(lines) + "\n"


# ----------------------------------------------------------------------
# mega-catalog route-step projection (kernels/route_step.py)
# ----------------------------------------------------------------------
#
# Analytic bytes-moved / FLOP model of the fused routing step at
# 100k-1M catalog entries, independent of the dry-run artifacts above.
# The catalog block e2 is (N, 2*N_METRICS): fp32 streams 64 B/row,
# the int8 path 16 B/row + an (N, 2) f32 scale pair, and each row
# carries one fused-mask byte.  Distinct IVF cells touched across a
# batch are streamed from HBM once (queries probing the same cell hit
# cache), so the pruned path's bytes scale with expected cell
# coverage, not raw rows-scanned.  These projections GATE the 100k+
# catalog sweep in benchmarks/router_scale.py: the sweep only runs if
# the model predicts >=2x for int8 on an accelerator and >=3x for
# int8+IVF at N=1M.

ROUTE_F = 16                 # e2 cols: 2 * N_METRICS (embn | emb halves)
ROUTE_SCALE_BYTES = 8        # e2s: (N, 2) f32 per-row scales (int8 path)
ROUTE_CARRY = 32             # per-shard sorted carry lanes in the merge


def route_step_projection(n: int, *, batch: int = 64, quant: bool = False,
                          nprobe: int = 0, n_cells: int = 0,
                          devices: int = 1) -> Dict:
    """Roofline terms (seconds) for ONE fused route-step dispatch.

    ``nprobe > 0`` selects the two-level IVF pruned path
    (``n_cells`` defaults to ~sqrt(N), matching
    ``mres.default_n_cells``); ``devices > 1`` shards the catalog axis
    and adds the cross-shard top-k merge-tree all-gather.
    """
    import math
    elem = 1 if quant else 4
    row_bytes = ROUTE_F * elem + (ROUTE_SCALE_BYTES if quant else 0) + 1
    if nprobe:
        c = n_cells or max(1, round(math.sqrt(n)))
        cap = -(-n // c)
        scanned = min(n, nprobe * cap)              # rows per query
        frac = 1.0 - (1.0 - min(1.0, nprobe / c)) ** batch
        bytes_hbm = frac * n * row_bytes + c * ROUTE_F * elem
        flops = 2.0 * batch * (scanned + c) * ROUTE_F
    else:
        scanned = n
        bytes_hbm = float(n) * row_bytes
        flops = 2.0 * batch * n * ROUTE_F
    t_c = flops / PEAK_FLOPS / devices
    t_m = bytes_hbm / HBM_BW / devices
    t_x = (devices * batch * ROUTE_CARRY * 4 * 4) / LINK_BW \
        if devices > 1 else 0.0
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    return {"n": n, "batch": batch, "quant": quant, "nprobe": nprobe,
            "devices": devices, "scanned_rows_per_query": int(scanned),
            "bytes_hbm": bytes_hbm, "flops": flops,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": max(terms, key=terms.get),
            "step_s": max(terms.values())}


def mega_projection(sizes=(100_000, 1_000_000), *, batch: int = 64,
                    devices: int = 1) -> List[Dict]:
    """Speedup table for the mega-catalog serving modes, with the two
    headline claims asserted: int8 cuts the memory-bound scan >=2x on
    an accelerator at every size, and int8+IVF cuts projected scan
    time >=3x at N=1M.  ``benchmarks/router_scale.py`` calls this
    before its 100k+ sweep — a model change that breaks either claim
    fails the sweep before any catalog is built."""
    rows = []
    for n in sizes:
        fp32 = route_step_projection(n, batch=batch, devices=devices)
        q8 = route_step_projection(n, batch=batch, quant=True,
                                   devices=devices)
        ivf = route_step_projection(n, batch=batch, quant=True, nprobe=8,
                                    devices=devices)
        rows.append({
            "n": n, "batch": batch, "devices": devices,
            "fp32_step_s": fp32["step_s"], "int8_step_s": q8["step_s"],
            "int8_ivf_step_s": ivf["step_s"],
            "dominant": fp32["dominant"],
            "int8_speedup": fp32["step_s"] / q8["step_s"],
            "int8_ivf_speedup": fp32["step_s"] / ivf["step_s"],
        })
    assert all(r["int8_speedup"] >= 2.0 for r in rows), rows
    big = [r for r in rows if r["n"] >= 1_000_000]
    assert all(r["int8_ivf_speedup"] >= 3.0 for r in big), rows
    return rows


def run(verbose: bool = True):
    rows = load_all("pod1")
    ok = [r for r in rows if r["dominant"] != "n/a"]
    na = [r for r in rows if r["dominant"] == "n/a"]
    assert len(ok) + len(na) == 40, f"expected 40 pairs, got {len(rows)}"
    out = {"pod1": rows, "pod2_status": {}}
    for f in sorted(DRYRUN.glob("*__pod2.json")):
        rec = json.loads(f.read_text())
        out["pod2_status"][f"{rec['arch']}__{rec['shape']}"] = rec["status"]
    assert all(v in ("ok", "n/a") for v in out["pod2_status"].values())
    if verbose:
        by_dom = {}
        for r in ok:
            by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
        print(f"  40 pairs: {len(ok)} lowered, {len(na)} n/a (long_500k "
              f"full-attention). dominant terms: {by_dom}")
        worst = sorted(ok, key=lambda r: r["useful_ratio"])[:3]
        for r in worst:
            print(f"  lowest useful: {r['arch']}/{r['shape']} "
                  f"{r['useful_ratio']:.2f} (dom {r['dominant']})")
    (REPO / "results" / "bench").mkdir(parents=True, exist_ok=True)
    (REPO / "results" / "bench" / "roofline.md").write_text(
        markdown_table(rows))

    # optimized sweep (post-hillclimb defaults), if present
    gain = ""
    if DRYRUN_OPT.exists():
        rows_opt = load_all("pod1", DRYRUN_OPT)
        ok_opt = {(r["arch"], r["shape"]): r for r in rows_opt
                  if r["dominant"] != "n/a"}
        out["pod1_optimized"] = rows_opt
        (REPO / "results" / "bench" / "roofline_opt.md").write_text(
            markdown_table(rows_opt))
        deltas = []
        for r in ok:
            o = ok_opt.get((r["arch"], r["shape"]))
            if o:
                deltas.append(r["step_s_bound"] / max(o["step_s_bound"],
                                                      1e-12))
        if deltas:
            import numpy as np
            gain = (f"; opt step-bound speedup geomean "
                    f"{float(np.exp(np.mean(np.log(deltas)))):.2f}x "
                    f"(max {max(deltas):.0f}x)")
            out["opt_speedups"] = {"geomean": float(
                np.exp(np.mean(np.log(deltas)))), "max": float(max(deltas))}
            if verbose:
                print(f"  optimized sweep: {len(deltas)} pairs{gain}")
    save_result("roofline", out)
    doms = {r["dominant"] for r in ok}
    return ("roofline", 0.0,
            f"{len(ok)} lowered + {len(na)} documented-n/a; "
            f"dominant in {sorted(doms)}{gain}")


if __name__ == "__main__":
    run()
