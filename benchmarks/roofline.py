"""Benchmark 7 (deliverable g): roofline terms per (arch x shape x mesh)
from the compiled dry-run artifacts in results/dryrun/.

Per pair, three terms (seconds, per-chip):
  compute    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory     = HLO_bytes(per-device) / HBM_bw
  collective = collective_bytes(per-device) / link_bw

cost_analysis() of an SPMD-compiled module reports the PER-DEVICE
program (its argument sizes match the per-device parameter shard), so
no further division by chip count is applied.

MODEL_FLOPS uses the standard analytic formulas (6·N·D train,
2·N_active·D prefill, 2·N_active·B decode).  The usefulness ratio
MODEL/HLO can exceed 1: 6·N·D charges the embedding table as a matmul
while the compiled program gathers rows (0 FLOPs) — the ratio still
catches remat/redundancy (lower = more recompute).
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from benchmarks.common import save_result

REPO = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = REPO / "results" / "dryrun"

PEAK_FLOPS = 197e12          # v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

SHAPE_TOKENS = {
    "train_4k": ("train", 4096 * 256),
    "prefill_32k": ("prefill", 32768 * 32),
    "decode_32k": ("decode", 128),
    "long_500k": ("decode", 1),
}


def model_flops(rec: Dict) -> float:
    kind, tokens = SHAPE_TOKENS[rec["shape"]]
    n_act = rec["n_active_params"]
    n = rec["n_params"]
    if kind == "train":
        return 6.0 * (n_act if n_act != n else n) * tokens
    return 2.0 * n_act * tokens


def analyze(rec: Dict) -> Dict:
    chips = rec["devices"]
    coll = sum(rec["collective_bytes"].values())
    t_c = rec["flops"] / PEAK_FLOPS
    t_m = rec["bytes_accessed"] / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": f"{chips}", "compute_s": t_c, "memory_s": t_m,
        "collective_s": t_x, "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / chips / max(rec["flops"], 1.0),
        "hbm_gb_per_chip": (rec["memory"]["argument_size_bytes"]
                            + rec["memory"]["temp_size_bytes"]
                            + rec["memory"]["output_size_bytes"]) / 2**30,
        "step_s_bound": max(terms.values()),
    }


DRYRUN_OPT = REPO / "results" / "dryrun_opt"


def load_all(pod: str = "pod1", directory: Optional[pathlib.Path] = None
             ) -> List[Dict]:
    rows = []
    for f in sorted((directory or DRYRUN).glob(f"*__{pod}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok":
            rows.append(analyze(rec))
        elif rec.get("status") == "n/a":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": pod, "dominant": "n/a",
                         "reason": rec.get("reason", "")})
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | HBM GiB/chip |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["dominant"] == "n/a":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"n/a | — | — |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
                f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
                f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                f"{r['hbm_gb_per_chip']:.2f} |")
    return hdr + "\n".join(lines) + "\n"


def run(verbose: bool = True):
    rows = load_all("pod1")
    ok = [r for r in rows if r["dominant"] != "n/a"]
    na = [r for r in rows if r["dominant"] == "n/a"]
    assert len(ok) + len(na) == 40, f"expected 40 pairs, got {len(rows)}"
    out = {"pod1": rows, "pod2_status": {}}
    for f in sorted(DRYRUN.glob("*__pod2.json")):
        rec = json.loads(f.read_text())
        out["pod2_status"][f"{rec['arch']}__{rec['shape']}"] = rec["status"]
    assert all(v in ("ok", "n/a") for v in out["pod2_status"].values())
    if verbose:
        by_dom = {}
        for r in ok:
            by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
        print(f"  40 pairs: {len(ok)} lowered, {len(na)} n/a (long_500k "
              f"full-attention). dominant terms: {by_dom}")
        worst = sorted(ok, key=lambda r: r["useful_ratio"])[:3]
        for r in worst:
            print(f"  lowest useful: {r['arch']}/{r['shape']} "
                  f"{r['useful_ratio']:.2f} (dom {r['dominant']})")
    (REPO / "results" / "bench").mkdir(parents=True, exist_ok=True)
    (REPO / "results" / "bench" / "roofline.md").write_text(
        markdown_table(rows))

    # optimized sweep (post-hillclimb defaults), if present
    gain = ""
    if DRYRUN_OPT.exists():
        rows_opt = load_all("pod1", DRYRUN_OPT)
        ok_opt = {(r["arch"], r["shape"]): r for r in rows_opt
                  if r["dominant"] != "n/a"}
        out["pod1_optimized"] = rows_opt
        (REPO / "results" / "bench" / "roofline_opt.md").write_text(
            markdown_table(rows_opt))
        deltas = []
        for r in ok:
            o = ok_opt.get((r["arch"], r["shape"]))
            if o:
                deltas.append(r["step_s_bound"] / max(o["step_s_bound"],
                                                      1e-12))
        if deltas:
            import numpy as np
            gain = (f"; opt step-bound speedup geomean "
                    f"{float(np.exp(np.mean(np.log(deltas)))):.2f}x "
                    f"(max {max(deltas):.0f}x)")
            out["opt_speedups"] = {"geomean": float(
                np.exp(np.mean(np.log(deltas)))), "max": float(max(deltas))}
            if verbose:
                print(f"  optimized sweep: {len(deltas)} pairs{gain}")
    save_result("roofline", out)
    doms = {r["dominant"] for r in ok}
    return ("roofline", 0.0,
            f"{len(ok)} lowered + {len(na)} documented-n/a; "
            f"dominant in {sorted(doms)}{gain}")


if __name__ == "__main__":
    run()
