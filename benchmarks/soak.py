"""Benchmark: sustained-load multi-tenant soak of the serving stack.

Closed-loop replay of a bursty multi-tenant episode through the REAL
serving path — ``MicroBatcher`` aggregation windows feeding
``ServingEngine.submit`` (one fused ``route_all`` dispatch + deadline
admission + grouped generate per window) — in VIRTUAL time, so an
hours-equivalent episode runs in seconds and every run is
deterministic.  Four phases over the same catalog and traffic:

  1. control      — the clean episode (the reference outcome stream);
  2. fault        — a runner fault is injected into the hot model
     mid-soak: ONLY that model's group may degrade to
     ``admission="failed"`` (the batch, and the soak, must survive);
  3. restart      — a rolling restart under load: the router state is
     checkpointed at a window boundary (``save_router_state``), the
     router/engine/tracker are rebuilt from scratch, state is restored
     (``load_router_state``), and the remaining backlog drains through
     the new engine.  The restart must be TRANSPARENT: the outcome
     stream is asserted identical to the control run;
  4. queueing     — the same arrival trace through the discrete-event
     ``ServingSimulator`` (real queueing delay) with window-batched
     routing + per-tenant intake buckets: p99 / p99.9 tail latency,
     shed/reroute rates and cross-tenant fairness are measured here.

Soak-wide assertions (the PR's acceptance criteria):
  * zero route-step recompiles after the control run's warmup — across
    the fault run, the restart (fresh engine!) and the queueing phase;
  * the load tracker nets to ZERO after every drain;
  * a mid-soak runner fault degrades only its own group, never the
    batch — and the failures are visible (``admission="failed"``);
  * quiet tenants keep a near-zero shed rate while a flooding tenant
    is rate-limited at intake (cross-tenant isolation);
  * bounded tail latency and bounded cross-tenant unfairness (Jain).

Writes ``results/bench/soak.json`` and ``results/soak_metrics.prom``
(per-tenant admission funnel + ``soak_*`` gauges) — the CI SLO gate
re-evaluates the soak SLOs from that dump.  ``--smoke`` runs a
seconds-scale episode with the same assertions.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from types import SimpleNamespace
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import REPO, save_result, synthetic_entry
from repro.core.mres import MRES
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import TaskSignature
from repro.core.routing import RoutingEngine
from repro.core.telemetry import Telemetry
from repro.checkpoint import load_router_state, save_router_state
from repro.data.workload import (MultiTenantScenario, ServingSimulator,
                                 TenantSpec, TrafficScenario,
                                 jain_fairness, make_workload,
                                 multi_tenant_arrivals)
from repro.serving.async_engine import MicroBatcher, TenantPolicy
from repro.serving.engine import Request, ServingEngine
from repro.serving.load import ADMISSION_KINDS, LoadTracker, plan_admission

# (name, accuracy, latency_ms, cost, slots): hot dominates the static
# score but owns the fewest decode slots (the load-aware stress shape)
CATALOG: Tuple[Tuple[str, float, float, float, int], ...] = (
    ("hot",   0.95, 40.0, 2.0,  4),
    ("alt-a", 0.88, 60.0, 1.5,  8),
    ("alt-b", 0.86, 80.0, 1.0,  8),
    ("alt-c", 0.82, 50.0, 0.8,  8),
    ("bulk",  0.75, 90.0, 0.5, 16),
)
HOT = CATALOG[0][0]
_PROFILES = ("accuracy-first", "balanced", "cost-effective")


class SoakAnalyzer:
    """Deterministic text -> signature stand-in: the soak exercises the
    serving/admission path, not the trained analyzer (and restart
    equality needs bit-identical signatures across runs)."""

    def analyze_batch(self, texts):
        return [TaskSignature(task_type="chat", domain="general",
                              complexity=round(
                                  0.15 + (len(t) % 37) / 60.0, 4))
                for t in texts]

    def analyze(self, text):
        return self.analyze_batch([text])[0]


class FakeRunner:
    """Deterministic zero-weight runner: ``generate`` returns
    (B, max_new) token zeros with ``sim_latency_s = service_s * B``
    (the engine divides by batch size -> ``service_s`` per request)."""

    class _Cfg:
        vocab_size = 256

    cfg = _Cfg()

    def __init__(self, service_s: float):
        self.service_s = float(service_s)

    def generate(self, toks, max_new: int = 8):
        B = int(np.asarray(toks).shape[0])
        return SimpleNamespace(tokens=np.zeros((B, max_new), np.int32),
                               sim_latency_s=self.service_s * B)


class FaultRunner:
    """Injected mid-soak: every generate raises (a crashed backend)."""

    cfg = FakeRunner._Cfg()

    def generate(self, toks, max_new: int = 8):
        raise RuntimeError("soak fault injection")


def _build_catalog() -> Tuple[MRES, List[str]]:
    m = MRES()
    for name, acc, lat, cost, _ in CATALOG:
        e = synthetic_entry(name, accuracy=acc, latency_ms=lat, cost=cost,
                            task_types=("chat",), domains=("general",),
                            generalist=True)
        e.runner = FakeRunner(lat / 1e3)
        m.register(e)
    return m, [c[0] for c in CATALOG]


def _fresh_stack(sc: MultiTenantScenario, tel: Telemetry
                 ) -> Tuple[ServingEngine, LoadTracker, MRES]:
    """Catalog + tracker + router + engine, built from scratch (the
    rolling restart proves state transfers via the checkpoint, not via
    shared objects)."""
    mres, names = _build_catalog()
    service = [c[2] / 1e3 for c in CATALOG]
    tracker = LoadTracker(len(names), tau_s=sc.base.deadline_ms / 2e3,
                          default_service_s=float(np.mean(service)))
    for j, c in enumerate(CATALOG):
        tracker.set_capacity(j, float(c[4]))
    router = OptiRoute(mres, SoakAnalyzer(), knn_k=len(names),
                       telemetry=tel, load=tracker, load_weight=1.0)
    return ServingEngine(router), tracker, mres


def _policies(sc: MultiTenantScenario) -> Dict[str, TenantPolicy]:
    return {t.name: TenantPolicy(weight=t.weight, rate=t.rate_limit)
            for t in sc.tenants}


# ----------------------------------------------------------------------
# phase 1: virtual-time window replay through the real engine
# ----------------------------------------------------------------------

def replay_engine_soak(sc: MultiTenantScenario, tel: Telemetry, *,
                       max_batch: int = 32, max_wait_s: float = 0.1,
                       fail_t: Optional[float] = None,
                       restart_t: Optional[float] = None,
                       ckpt_path: Optional[str] = None) -> Dict:
    """One virtual-time episode: arrivals -> MicroBatcher windows ->
    ``engine.submit`` per window.  ``fail_t`` arms a hot-model runner
    fault at that virtual time (injected until a window actually
    routes to it); ``restart_t`` performs a checkpoint/rebuild/restore
    rolling restart at the first window boundary past that time.
    Returns the per-request outcome stream plus accounting."""
    sc = sc.validate()
    times, tidx = multi_tenant_arrivals(sc)
    assert times.size, "scenario produced no arrivals"
    pool = make_workload(64, seed=sc.base.seed + 101,
                         task_type=sc.base.task_type,
                         domain=sc.base.domain)
    mb = MicroBatcher(max_batch=max_batch, max_wait_s=max_wait_s,
                      policies=_policies(sc))
    engine, tracker, mres = _fresh_stack(sc, tel)
    stack = {"engine": engine, "tracker": tracker, "mres": mres}

    # warm every power-of-two shape bucket the windows can hit, through
    # the full submit path (route + admission + grouped generate)
    b = 1
    while b <= max_batch:
        engine.submit([Request(text=pool[j % len(pool)].text,
                               prefs="accuracy-first", id=-1, max_new=4,
                               deadline_ms=sc.base.deadline_ms)
                       for j in range(b)])
        b *= 2
    compiles_after_warmup = tel.route_step_stats()["compiles"]

    state = {"injecting": False, "fault_armed": fail_t is None,
             "fault_seen": False, "restarted": restart_t is None}
    outcomes: List[Tuple[int, str, str, str]] = []
    windows: List[int] = []

    def flush(now: float) -> None:
        items = mb.take(now)
        if not items:
            return
        inject = state["injecting"]
        hot = stack["mres"].entry(HOT)
        keep = hot.runner
        if inject:
            hot.runner = FaultRunner()
        try:
            resps = stack["engine"].submit(items)
        finally:
            if inject:
                hot.runner = keep
        windows.append(len(items))
        if inject:
            for rp in resps:        # ONLY the hot group may degrade
                if rp.model == HOT and not rp.shed:
                    assert rp.failed and "soak fault" in rp.error, rp
                else:
                    assert not rp.failed, (rp.model, rp.error)
            if any(rp.failed for rp in resps):
                state["fault_seen"] = True
                state["injecting"] = False
        outcomes.extend((rp.request.id, rp.request.tenant, rp.admission,
                         rp.model) for rp in resps)

    def boundary_events(now: float) -> None:
        # fired ONLY at window boundaries, which the control and
        # restart runs share exactly — a restart elsewhere would change
        # the windowing and break the equality assertion
        if fail_t is not None and not state["fault_armed"] \
                and now >= fail_t:
            state["fault_armed"] = True
            state["injecting"] = True
        if restart_t is not None and not state["restarted"] \
                and now >= restart_t:
            assert ckpt_path, "restart needs a checkpoint path"
            save_router_state(ckpt_path, stack["engine"].router)
            engine2, tracker2, mres2 = _fresh_stack(sc, tel)
            load_router_state(ckpt_path, engine2.router)
            stack.update(engine=engine2, tracker=tracker2, mres=mres2)
            state["restarted"] = True

    for k in range(times.size):
        t = float(times[k])
        while True:
            dl = mb.next_deadline(t)
            if dl is None or dl > t:
                break
            flush(dl)
            boundary_events(dl)
        ti = int(tidx[k])
        name = sc.tenants[ti].name
        req = Request(text=pool[k % len(pool)].text,
                      prefs=_PROFILES[ti % len(_PROFILES)], id=k,
                      max_new=4, deadline_ms=sc.deadline_ms_of(ti),
                      tenant=name)
        verdict = mb.offer(name, req, t)
        if verdict != "queued":      # intake shed: degrade immediately
            tel.record_admission("shed", tenant=name)
            tel.inc(f"intake_{verdict.replace('-', '_')}")
            outcomes.append((k, name, "shed", ""))
    end = float(times[-1])
    while mb.pending():
        dl = mb.next_deadline(end)
        end = max(end, dl if dl is not None else end)
        flush(end)
        boundary_events(end)

    # the tracker must net to zero after the drain — any residue is a
    # leaked admit/start (and a permanent phantom routing penalty)
    q, f, _, _ = stack["tracker"].snapshot()
    assert (q == 0).all() and (f == 0).all(), (q, f)
    assert len(outcomes) == times.size, (len(outcomes), times.size)
    if fail_t is not None:
        assert state["fault_seen"], "fault was armed but never fired"
    assert all(w <= max_batch for w in windows), max(windows)

    tally = {t.name: dict.fromkeys(ADMISSION_KINDS, 0)
             for t in sc.tenants}
    for _, tenant, adm, _ in outcomes:
        tally[tenant][adm] += 1
    return {"outcomes": sorted(outcomes), "windows": windows,
            "tally": tally, "intake": mb.stats,
            "fault_seen": state["fault_seen"],
            "restarted": state["restarted"],
            "compiles_after_warmup": compiles_after_warmup,
            "requests": int(times.size)}


# ----------------------------------------------------------------------
# phase 2: queueing tails through the discrete-event simulator
# ----------------------------------------------------------------------

def run_queueing_soak(sc: MultiTenantScenario, tel: Telemetry, *,
                      max_batch: int = 32, max_wait_s: float = 0.1
                      ) -> Dict:
    """The same traffic through real queueing: per-tenant intake
    buckets, window-batched ``route_many`` + ``plan_admission``, and
    the ``ServingSimulator``'s FIFO servers.  Tail latency, shed /
    reroute rates and per-tenant fairness are computed here."""
    sc = sc.validate()
    times, tidx = multi_tenant_arrivals(sc)
    R = times.size
    mres, names = _build_catalog()
    col = {m: j for j, m in enumerate(names)}
    service = [c[2] / 1e3 for c in CATALOG]
    capacity = [c[4] for c in CATALOG]
    tracker = LoadTracker(len(names), tau_s=sc.base.deadline_ms / 2e3,
                          default_service_s=float(np.mean(service)))
    eng = RoutingEngine(mres, knn_k=len(names), load=tracker,
                        load_weight=1.0, telemetry=tel)
    sim = ServingSimulator(service, capacity, tracker=tracker)

    # intake rate limiting (virtual time), then window assignment over
    # the ACCEPTED stream — same aggregation constants as phase 1
    buckets = {t.name: _policies(sc)[t.name].make_bucket()
               for t in sc.tenants}
    ok = np.zeros(R, bool)
    for i, t in enumerate(times):
        b = buckets[sc.tenants[int(tidx[i])].name]
        ok[i] = b is None or b.try_take(float(t))
    win_of = np.full(R, -1, np.int64)
    windows: List[List[int]] = []
    w_start = -1.0
    for i in np.flatnonzero(ok):
        t = float(times[i])
        if (not windows or len(windows[-1]) >= max_batch
                or t - w_start > max_wait_s):
            windows.append([])
            w_start = t
        win_of[i] = len(windows) - 1
        windows[-1].append(int(i))

    rng = np.random.default_rng(sc.base.seed + 17)
    sigs = [TaskSignature(task_type="chat", domain="general",
                          complexity=float(rng.random()))
            for _ in range(64)]
    decisions: Dict[int, Tuple[int, str]] = {}
    routed_windows = set()

    def route_fn(i: int, t: float) -> Tuple[int, str]:
        name = sc.tenants[int(tidx[i])].name
        if not ok[i]:
            tel.record_admission("shed", tenant=name)
            tel.inc("intake_rate_limited")
            return 0, "shed"
        w = int(win_of[i])
        if w not in routed_windows:   # one fused dispatch per window
            idxs = windows[w]
            ds = eng.route_many(
                [_PROFILES[int(tidx[j]) % len(_PROFILES)] for j in idxs],
                [sigs[j % len(sigs)] for j in idxs])
            pending = np.zeros(len(names), np.int64)
            for j, d in zip(idxs, ds):
                m, kind, _ = plan_admission(
                    d, tracker, col, sc.deadline_ms_of(int(tidx[j])),
                    pending=pending)
                if kind != "shed":
                    pending[col[m]] += 1
                decisions[j] = (col[m], kind)
                tel.record_admission(
                    kind, tenant=sc.tenants[int(tidx[j])].name)
            routed_windows.add(w)
        return decisions[i]

    res = sim.run(times, route_fn, deadline_ms=sc.base.deadline_ms)
    served = ~res["shed"]
    lat = res["latency_s"][served]
    per_tenant = {}
    for i, t in enumerate(sc.tenants):
        mask = tidx == i
        offered = int(mask.sum())
        per_tenant[t.name] = {
            "offered": offered,
            "served": int((mask & served).sum()),
            "shed": int((mask & res["shed"]).sum()),
            "intake_rejected": int((mask & ~ok).sum()),
            "shed_rate": float((mask & res["shed"]).sum()
                               / max(offered, 1)),
        }
    # fairness over each tenant's served share of its POST-INTAKE
    # demand: intake limits are policy (flood pays for its own flood);
    # unfairness would be the shared pipeline starving one tenant's
    # accepted traffic
    ratios = [per_tenant[t.name]["served"]
              / max(per_tenant[t.name]["offered"]
                    - per_tenant[t.name]["intake_rejected"], 1)
              for t in sc.tenants]
    fair = jain_fairness(ratios)
    return {
        "requests": int(R),
        "served": int(served.sum()),
        "throughput_rps": float(served.sum() / sc.base.duration_s),
        "p50_s": res["p50_s"], "p99_s": res["p99_s"],
        "p999_s": float(np.quantile(lat, 0.999)) if lat.size else 0.0,
        "slo_miss_rate": res["slo_miss_rate"],
        "shed_rate": float(res["shed"].mean()),
        "reroute_rate": float(res["rerouted"].mean()),
        "fairness_jain": fair,
        "per_tenant": per_tenant,
    }


# ----------------------------------------------------------------------
# the full soak
# ----------------------------------------------------------------------

def _scenario(*, duration_s: float, base_rate: float, burst_rate: float,
              flood_limit: float, seed: int = 11) -> MultiTenantScenario:
    return MultiTenantScenario(
        base=TrafficScenario(duration_s=duration_s, base_rate=base_rate,
                             burst_rate=burst_rate, burst_start=0.25,
                             burst_len=0.35, deadline_ms=400.0,
                             seed=seed),
        tenants=(TenantSpec("acme", weight=2.0),
                 TenantSpec("globex", weight=1.0),
                 TenantSpec("flood", weight=1.0, rate_scale=3.0,
                            rate_limit=flood_limit, deadline_ms=300.0)))


def run(*, duration_s: float = 90.0, base_rate: float = 25.0,
        burst_rate: float = 100.0, flood_limit: float = 30.0,
        max_batch: int = 32, max_wait_ms: float = 100.0,
        quiet_shed_max: float = 0.05, fairness_min: float = 0.85,
        p99_bound_s: float = 0.8, p999_bound_s: float = 1.0,
        verbose: bool = True):
    sc = _scenario(duration_s=duration_s, base_rate=base_rate,
                   burst_rate=burst_rate, flood_limit=flood_limit)
    wait_s = max_wait_ms / 1e3
    tel = Telemetry()
    results_dir = REPO / "results"
    results_dir.mkdir(parents=True, exist_ok=True)

    t0 = time.perf_counter()
    control = replay_engine_soak(sc, tel, max_batch=max_batch,
                                 max_wait_s=wait_s)
    control_s = time.perf_counter() - t0
    fault = replay_engine_soak(sc, tel, max_batch=max_batch,
                               max_wait_s=wait_s,
                               fail_t=0.35 * duration_s)
    restart = replay_engine_soak(
        sc, tel, max_batch=max_batch, max_wait_s=wait_s,
        restart_t=0.6 * duration_s,
        ckpt_path=str(results_dir / "soak_router.npz"))

    # the rolling restart must be invisible in the outcome stream
    assert restart["restarted"]
    assert restart["outcomes"] == control["outcomes"], \
        "rolling restart changed routing/admission outcomes"
    assert fault["fault_seen"]

    queueing = run_queueing_soak(sc, tel, max_batch=max_batch,
                                 max_wait_s=wait_s)

    # zero recompiles after the FIRST run's warmup — across the fault
    # run, the rebuilt post-restart engine and the queueing phase
    post_warm = (tel.route_step_stats()["compiles"]
                 - control["compiles_after_warmup"])
    assert post_warm == 0, f"{post_warm} route-step recompiles mid-soak"

    # cross-tenant isolation: the flooding tenant was rate-limited at
    # intake while the quiet tenants kept a near-zero shed rate
    for run_row in (control, fault, restart):
        for t in sc.tenants:
            total = max(sum(run_row["tally"][t.name].values()), 1)
            rate = run_row["tally"][t.name]["shed"] / total
            if t.rate_limit is None:
                assert rate <= quiet_shed_max, (t.name, rate)
    assert control["intake"]["flood"]["rate_limited"] > 0
    quiet = [t.name for t in sc.tenants if t.rate_limit is None]
    engine_fair = jain_fairness(
        [sum(v for k, v in control["tally"][n].items()
             if k in ("admitted", "rerouted"))
         / max(control["intake"][n]["queued"], 1) for n in quiet])
    assert engine_fair >= fairness_min, engine_fair
    assert queueing["fairness_jain"] >= fairness_min, queueing
    for name in quiet:
        assert queueing["per_tenant"][name]["shed_rate"] \
            <= quiet_shed_max, queueing["per_tenant"]
    assert queueing["p99_s"] <= p99_bound_s, queueing["p99_s"]
    assert queueing["p999_s"] <= p999_bound_s, queueing["p999_s"]

    # exportable SLO surface: soak gauges + per-tenant funnel -> .prom
    tel.set_gauge("soak_post_warmup_compiles", float(post_warm))
    tel.set_gauge("soak_fairness_jain", queueing["fairness_jain"])
    tel.set_gauge("soak_p99_s", queueing["p99_s"])
    tel.set_gauge("soak_p999_s", queueing["p999_s"])
    tel.set_gauge("soak_shed_rate", queueing["shed_rate"])
    tel.set_gauge("soak_throughput_rps", queueing["throughput_rps"])
    tel.set_gauge("soak_requests", float(control["requests"]))
    tel.set_gauge("soak_windows", float(len(control["windows"])))
    from repro.obs import write_prom
    prom_path = results_dir / "soak_metrics.prom"
    write_prom(str(prom_path), tel)

    us = control_s / max(control["requests"], 1) * 1e6
    if verbose:
        print(f"  engine soak: {control['requests']} reqs in "
              f"{len(control['windows'])} windows "
              f"({us:.0f}us/req wall), tally={control['tally']}")
        print(f"  fault run: fault_seen={fault['fault_seen']} "
              f"failed={ {t: v['failed'] for t, v in fault['tally'].items()} }")
        print(f"  restart run: outcomes identical to control "
              f"({len(restart['outcomes'])} requests)")
        print(f"  queueing: p50={queueing['p50_s']*1e3:.0f}ms "
              f"p99={queueing['p99_s']*1e3:.0f}ms "
              f"p99.9={queueing['p999_s']*1e3:.0f}ms "
              f"shed={queueing['shed_rate']*100:.1f}% "
              f"reroute={queueing['reroute_rate']*100:.1f}% "
              f"jain={queueing['fairness_jain']:.3f}")
        print(f"  recompiles after warmup: {post_warm}  "
              f"-> {prom_path}")

    payload = {
        "scenario": {"duration_s": duration_s, "base_rate": base_rate,
                     "burst_rate": burst_rate,
                     "flood_limit": flood_limit,
                     "tenants": [dataclasses.asdict(t)
                                 for t in sc.tenants]},
        "catalog": [dict(zip(("name", "accuracy", "latency_ms", "cost",
                              "slots"), c)) for c in CATALOG],
        "engine_soak": {k: control[k] for k in
                        ("requests", "tally", "intake", "windows")},
        "fault_run": {"fault_seen": fault["fault_seen"],
                      "tally": fault["tally"]},
        "restart_run": {"restarted": restart["restarted"],
                        "outcomes_match_control": True},
        "queueing": queueing,
        "post_warmup_compiles": post_warm,
        "engine_us_per_req": us,
    }
    save_result("soak", payload)
    return ("soak", us,
            f"{control['requests']} reqs/run x3 + restart + fault, "
            f"0 recompiles post-warmup, p99.9 "
            f"{queueing['p999_s']*1e3:.0f}ms, shed "
            f"{queueing['shed_rate']*100:.1f}%, jain "
            f"{queueing['fairness_jain']:.3f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale episode for CI; same restart/"
                    "fault/recompile/fairness assertions")
    args = ap.parse_args(argv)
    if args.smoke:
        run(duration_s=30.0, base_rate=12.0, burst_rate=48.0,
            flood_limit=20.0)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
