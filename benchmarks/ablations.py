"""Benchmark 8 (beyond-paper science): routing-component ablations.

The paper composes four routing mechanisms — kNN candidate stage,
hierarchical task-type/domain filtering, complexity-adjusted task
vectors, and the trained analyzer itself — without quantifying their
individual contributions.  This ablation removes each one and measures
the quality/cost impact on the standard workload:

  full            — everything on (oracle analyzer isolates routing)
  no-filter       — hierarchical filters skipped (confidence gate 1.1)
  no-complexity   — task vector does not raise the accuracy demand
  no-knn          — kNN widened to the whole catalog (score-only)
  noisy-analyzer  — trained analyzer replaced by 30%-corrupted sigs
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result
from repro.core.preferences import DOMAINS, TASK_TYPES, TaskSignature, UserPreferences
from repro.core.routing import RoutingEngine
from repro.data.workload import make_workload, quality_of
from repro.serving.catalog import build_catalog


def entry_meta(e):
    return {"accuracy": e.raw_metrics["accuracy"],
            "task_types": e.task_types, "domains": e.domains}


def run(n_queries: int = 400, seed: int = 0, verbose: bool = True):
    mres = build_catalog(smoke_runners=False)
    entries = {e.name: e for e in mres.entries}
    wl = make_workload(n_queries, seed=seed)
    rng = np.random.default_rng(seed)
    prefs = UserPreferences(weights=dict(
        accuracy=0.8, cheapness=0.7, speed=0.5, helpfulness=0.4,
        harmlessness=0.4, honesty=0.4, steerability=0.2, creativity=0.2))

    def corrupt(sig: TaskSignature) -> TaskSignature:
        if rng.random() < 0.3:
            return TaskSignature(
                task_type=str(rng.choice(TASK_TYPES)),
                domain=str(rng.choice(DOMAINS)),
                complexity=float(rng.random()), confidence=1.0)
        return sig

    variants = {
        "full": (RoutingEngine(mres), lambda s: s),
        "no-filter": (RoutingEngine(mres, confidence_threshold=1.1),
                      lambda s: s),
        "no-complexity": (RoutingEngine(mres, use_complexity=False),
                          lambda s: s),
        "no-knn": (RoutingEngine(mres, knn_k=len(mres)), lambda s: s),
        "noisy-analyzer": (RoutingEngine(mres), corrupt),
    }
    out = {}
    for name, (eng, sig_fn) in variants.items():
        qual, cost = [], []
        for r in wl:
            d = eng.route(prefs, sig_fn(r.sig))
            e = entries[d.model]
            qual.append(quality_of(entry_meta(e), r.sig))
            cost.append(e.raw_metrics["cost_per_mtok"])
        out[name] = {"quality": float(np.mean(qual)),
                     "cost_per_mtok": float(np.mean(cost))}
        if verbose:
            print(f"  {name:<15} quality={out[name]['quality']:.4f} "
                  f"cost={out[name]['cost_per_mtok']:.5f}")

    full_q = out["full"]["quality"]
    out["derived"] = {
        f"dq_{k}": out[k]["quality"] - full_q for k in variants if k != "full"
    }

    # The complexity mechanism only binds when the user's own accuracy
    # weight is LOW (task_vector takes max(w_acc, complexity)) — re-run
    # that ablation under a cost-focused user to expose it.
    cheap_prefs = UserPreferences(weights=dict(
        accuracy=0.1, cheapness=1.0, speed=0.6, helpfulness=0.3,
        harmlessness=0.3, honesty=0.3, steerability=0.1, creativity=0.1))
    for name, eng in (("full", RoutingEngine(mres)),
                      ("no-complexity", RoutingEngine(mres,
                                                      use_complexity=False))):
        qual = [quality_of(entry_meta(entries[
            eng.route(cheap_prefs, r.sig).model]), r.sig) for r in wl]
        out[f"lowacc_{name}"] = {"quality": float(np.mean(qual))}
    dq_low = (out["lowacc_no-complexity"]["quality"]
              - out["lowacc_full"]["quality"])
    out["derived"]["dq_no-complexity_lowacc_user"] = dq_low
    if verbose:
        print(f"  [low-accuracy user] complexity ablation dq={dq_low:+.4f}")
    save_result("ablations", out)
    assert dq_low < 0.01, "complexity raise must not hurt"
    # every ablation must not IMPROVE on the full system's quality
    # beyond noise — each component must pull its weight
    assert out["no-filter"]["quality"] <= full_q + 0.01
    assert out["noisy-analyzer"]["quality"] <= full_q + 0.01
    deltas = ", ".join(f"{k[3:]}{v:+.3f}"
                       for k, v in out["derived"].items())
    return ("ablations", 0.0, f"quality deltas vs full: {deltas}")


if __name__ == "__main__":
    run()
