"""Benchmark 1 (paper §1/§3.4 claim): preference-aware routing reduces
cost and latency versus always-using-the-largest-model at matched (or
better) quality, and versus naive baselines.

Policies compared over the same synthetic workload:
  * always-biggest   — the one-size-fits-all upper baseline
  * always-cheapest  — the cost floor (quality collapses)
  * random           — uniform over the catalog
  * optiroute        — full route(): analyzer sig + kNN + filter + score

Quality is the deterministic synthetic ground truth from
``repro.data.workload.quality_of`` (catalog accuracy vs task complexity
and domain/task-tag match) — the paper's MRES evaluation numbers.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import UserPreferences
from repro.core.routing import RoutingEngine
from repro.data.workload import make_workload, quality_of
from repro.serving.catalog import build_catalog


def entry_meta(e):
    return {"accuracy": e.raw_metrics["accuracy"],
            "task_types": e.task_types, "domains": e.domains}


def run(n_queries: int = 400, seed: int = 0, verbose: bool = True):
    mres = build_catalog(smoke_runners=False)
    entries = {e.name: e for e in mres.entries}
    biggest = max(entries.values(), key=lambda e: e.meta["active_params"])
    cheapest = min(entries.values(),
                   key=lambda e: e.raw_metrics["cost_per_mtok"])
    wl = make_workload(n_queries, seed=seed)
    rng = np.random.default_rng(seed)

    # oracle analyzer isolates routing quality from analyzer error
    class _Oracle:
        def analyze(self, text):
            return next(r.sig for r in wl if r.text == text)

    router = OptiRoute(mres, _Oracle())
    prefs = UserPreferences(weights=dict(
        accuracy=0.8, cheapness=0.7, speed=0.5, helpfulness=0.4,
        harmlessness=0.4, honesty=0.4, steerability=0.2, creativity=0.2))

    policies = {
        "always-biggest": lambda r: biggest.name,
        "always-cheapest": lambda r: cheapest.name,
        "random": lambda r: str(rng.choice(list(entries))),
        "optiroute": lambda r: router.route(r.text, prefs).decision.model,
    }
    out = {}
    for pol, pick in policies.items():
        qual, cost, lat = [], [], []
        for r in wl:
            e = entries[pick(r)]
            qual.append(quality_of(entry_meta(e), r.sig))
            cost.append(e.raw_metrics["cost_per_mtok"])
            lat.append(e.raw_metrics["latency_ms"])
        out[pol] = {"quality": float(np.mean(qual)),
                    "cost_per_mtok": float(np.mean(cost)),
                    "latency_ms": float(np.mean(lat))}

    big, opt = out["always-biggest"], out["optiroute"]
    out["derived"] = {
        "cost_reduction_vs_biggest": 1.0 - opt["cost_per_mtok"] / big["cost_per_mtok"],
        "latency_reduction_vs_biggest": 1.0 - opt["latency_ms"] / big["latency_ms"],
        "quality_delta_vs_biggest": opt["quality"] - big["quality"],
        "quality_delta_vs_cheapest": opt["quality"] - out["always-cheapest"]["quality"],
    }
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    save_result("routing_win", out)
    d = out["derived"]
    assert d["cost_reduction_vs_biggest"] > 0, "routing must cut cost"
    assert d["quality_delta_vs_biggest"] > -0.05, "quality must hold"
    return ("routing_win", 0.0,
            f"cost-{d['cost_reduction_vs_biggest']:.0%}/"
            f"lat-{d['latency_reduction_vs_biggest']:.0%}/"
            f"dq{d['quality_delta_vs_biggest']:+.3f}")


if __name__ == "__main__":
    run()
