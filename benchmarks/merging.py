"""Benchmark 6 (paper §5): model-merging fallback.

Two claims are exercised:
  1. metric-space: when the user's best option was excluded by a
     domain/task filter, a model-soup entry (union of domains,
     interpolated metrics) beats the in-domain incumbent's score;
  2. weight-space: souping two same-config checkpoints produces a model
     whose loss on a blend of their training distributions is no worse
     than the worst parent (the model-soups premise, checked on real
     reduced JAX models trained in-process).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result
from repro.core.merging import ModelMerger, soup
from repro.core.mres import MRES
from repro.core.preferences import TaskSignature, UserPreferences
from repro.core.routing import RoutingEngine


def _entry(name, acc, lat, cost, domains, family="dense", n_params=100):
    from benchmarks.common import synthetic_entry
    return synthetic_entry(name, accuracy=acc, latency_ms=lat, cost=cost,
                           task_types=("summarization",), domains=domains,
                           family=family, n_params=n_params)


def run(verbose: bool = True, train_steps: int = 60):
    # ---- claim 1: metric-space soup beats filtered incumbent ----
    mres = MRES()
    mres.register(_entry("legal-weak", 0.4, 50, 1.0, ("legal",)))
    mres.register(_entry("general-strong", 0.95, 40, 1.0, ("general",)))
    eng = RoutingEngine(mres)
    sig = TaskSignature(task_type="summarization", domain="legal",
                        complexity=0.6)
    prefs = UserPreferences(weights={m: 0.5 for m in
                                     ("accuracy", "speed", "cheapness",
                                      "helpfulness", "harmlessness",
                                      "honesty", "steerability",
                                      "creativity")})
    before = eng.route(prefs, sig)
    merger = ModelMerger(mres, score_threshold=10.0)
    entry = merger.maybe_merge(prefs, sig, before.score)
    after = eng.route(prefs, sig)
    metric_gain = after.score - before.score
    if verbose:
        print(f"  metric-space: {before.model} ({before.score:.3f}) -> "
              f"{after.model} ({after.score:.3f}), gain {metric_gain:+.3f}")

    # ---- claim 2: weight-space soup on real reduced models ----
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.models import model as M
    from repro.training.optimizer import init_opt_state
    from repro.training.steps import make_train_step

    cfg = get_smoke("llama3.2-1b")
    rng = np.random.default_rng(0)

    def make_dist(seed):
        """A simple learnable distribution: bigram chains mod vocab."""
        r = np.random.default_rng(seed)
        base = r.integers(2, cfg.vocab_size - 1, 64)

        def sample(B, L):
            starts = r.integers(0, 64, B)
            rows = [(base[(s + np.arange(L)) % 64]) for s in starts]
            return np.stack(rows).astype(np.int32)
        return sample

    def train_on(sample, seed):
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(cfg))
        for _ in range(train_steps):
            toks = sample(8, 32)
            batch = {"tokens": jnp.asarray(toks),
                     "labels": jnp.asarray(np.roll(toks, -1, 1))}
            params, opt, metrics = step(params, opt, batch)
        return params

    def eval_loss(params, sample):
        toks = sample(16, 32)
        batch = {"tokens": jnp.asarray(toks),
                 "labels": jnp.asarray(np.roll(toks, -1, 1))}
        _, (lm, _) = M.loss_fn(params, cfg, batch)
        return float(lm)

    dist_a, dist_b = make_dist(1), make_dist(2)
    # IMPORTANT: same init (seed) — model soups need a shared basin
    pa = train_on(dist_a, seed=7)
    pb = train_on(dist_b, seed=7)
    ps = soup([pa, pb], [0.5, 0.5])

    def blend(B, L):
        half = B // 2
        return np.concatenate([dist_a(half, L), dist_b(B - half, L)])

    la, lb, ls = (eval_loss(p, blend) for p in (pa, pb, ps))
    if verbose:
        print(f"  weight-space: blend loss parentA={la:.3f} "
              f"parentB={lb:.3f} soup={ls:.3f}")

    out = {"metric_space": {"before": before.score, "after": after.score,
                            "gain": metric_gain,
                            "soup_entry": entry.name if entry else None},
           "weight_space": {"parent_a": la, "parent_b": lb, "soup": ls}}
    save_result("merging", out)
    assert entry is not None and metric_gain > 0
    assert ls <= max(la, lb) + 0.05, "soup must not be worse than the " \
                                     "worst parent on the blend"
    return ("merging", 0.0,
            f"metric gain {metric_gain:+.3f}; "
            f"soup blend loss {ls:.3f} vs parents {la:.3f}/{lb:.3f}")


if __name__ == "__main__":
    run()
