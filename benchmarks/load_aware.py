"""Benchmark: load- and SLO-aware routing vs. load-blind routing under
a bursty hot-model traffic episode.

The failure mode this measures: the statically best-scoring model
("hot" — top accuracy, lowest latency metrics) has only a few decode
slots.  A load-blind router sends the entire burst there; its queue
grows without bound and p99 latency blows through the SLO even though
the catalog's alternates have idle capacity the whole time.

Three policies through the SAME discrete-event serving simulator
(``repro.data.workload.ServingSimulator``), same arrival trace:

  * ``blind``      — static preference routing, every request admitted
    to its routed model (load_weight = 0, no deadline logic);
  * ``load``       — the routing blend penalizes saturated candidates
    at ``load_weight`` via the live ``LoadTracker`` (no shedding);
  * ``load+slo``   — load-aware scoring PLUS deadline admission:
    requests whose estimated wait+service misses ``deadline_ms`` are
    rerouted to their best-fitting candidate or shed
    (``plan_admission``).

Asserts (the PR's acceptance criteria):
  * load-aware beats load-blind by >= 2x on SLO-miss rate (or p99);
  * routing quality stays within tolerance of the load-blind policy
    (the penalty diverts traffic to near-peers, not to junk);
  * route_many with the load term stays within the overhead bound of
    the load-blind path at serving batch sizes.

``--smoke`` runs a seconds-scale episode for CI with the same
assertions (looser overhead guard for shared-runner noise).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import save_result, synthetic_entry
from repro.core.mres import MRES
from repro.core.preferences import TaskSignature
from repro.core.routing import RoutingEngine
from repro.data.workload import (ServingSimulator, TrafficScenario, meta_of,
                                 poisson_arrivals, quality_of)
from repro.serving.load import LoadTracker, plan_admission

# (name, accuracy, latency_ms, cost, slots): the hot model dominates
# every static axis but owns the fewest decode slots; the alternates
# are near-peers with headroom; "weak" is the quality-tolerance canary
# (a router that sheds load onto it would fail the tolerance assert).
CATALOG: Tuple[Tuple[str, float, float, float, int], ...] = (
    ("hot",  0.95,  40.0, 2.0,  4),
    ("alt-a", 0.88, 60.0, 1.5,  8),
    ("alt-b", 0.86, 80.0, 1.0,  8),
    ("alt-c", 0.82, 50.0, 0.8,  8),
    ("weak", 0.55,  30.0, 0.2, 16),
)


def _build_catalog() -> MRES:
    m = MRES()
    m.register_many([
        synthetic_entry(name, accuracy=acc, latency_ms=lat, cost=cost,
                        task_types=("chat",), domains=("general",),
                        generalist=True)
        for name, acc, lat, cost, _ in CATALOG])
    return m


def _episode(sc: TrafficScenario, *, policy: str,
             load_weight: float = 1.0, prefs: str = "accuracy-first",
             service_scale: float = 1.0) -> Dict:
    """One policy through one arrival trace; returns the evidence row."""
    mres = _build_catalog()
    names = [c[0] for c in CATALOG]
    col = {m: j for j, m in enumerate(names)}
    metas = [meta_of(e) for e in mres.entries]
    service_s = [c[2] / 1e3 * service_scale for c in CATALOG]
    capacity = [c[4] for c in CATALOG]

    tracker: Optional[LoadTracker] = None
    if policy != "blind":
        tracker = LoadTracker(len(names), tau_s=sc.deadline_ms / 2e3,
                              default_service_s=float(np.mean(service_s)))
    eng = RoutingEngine(mres, knn_k=len(names), load=tracker,
                        load_weight=load_weight if tracker else 0.0)
    sim = ServingSimulator(service_s, capacity, tracker=tracker)

    rng = np.random.default_rng(sc.seed + 17)
    sigs = [TaskSignature(task_type="chat", domain="general",
                          complexity=float(rng.random()))
            for _ in range(64)]                       # cycled query pool
    chosen_sig: List[TaskSignature] = []

    def route(i: int, t: float) -> Tuple[int, str]:
        sig = sigs[i % len(sigs)]
        chosen_sig.append(sig)
        d = eng.route_many([prefs], [sig])[0]
        if policy == "load+slo":
            m, kind, _ = plan_admission(d, tracker, col, sc.deadline_ms)
            return col[m], kind
        return col[d.model], "admitted"

    res = sim.run(poisson_arrivals(sc), route, deadline_ms=sc.deadline_ms)
    served = ~res["shed"]
    qual = np.array([quality_of(metas[m], s) for m, s in
                     zip(res["model"], chosen_sig)])
    # per-model traffic counts EXECUTED requests only — a shed request
    # records its least-bad candidate but that model served nothing
    by_model = {names[j]: int(((res["model"] == j) & served).sum())
                for j in range(len(names))}
    return {
        "policy": policy,
        "requests": int(res["model"].size),
        "p50_s": res["p50_s"], "p99_s": res["p99_s"],
        "slo_miss_rate": res["slo_miss_rate"],
        "shed_rate": float(res["shed"].mean()),
        "reroute_rate": float(res["rerouted"].mean()),
        "mean_quality": float(qual[served].mean()),
        "by_model": by_model,
    }


def run_burst(*, duration_s: float = 20.0, base_rate: float = 40.0,
              burst_rate: float = 260.0, deadline_ms: float = 400.0,
              quality_tol: float = 0.10, min_gain: float = 2.0,
              verbose: bool = True) -> Dict:
    sc = TrafficScenario(duration_s=duration_s, base_rate=base_rate,
                         burst_rate=burst_rate, burst_start=0.25,
                         burst_len=0.35, deadline_ms=deadline_ms, seed=5)
    rows = [_episode(sc, policy=p) for p in ("blind", "load", "load+slo")]
    by = {r["policy"]: r for r in rows}
    if verbose:
        for r in rows:
            print(f"  {r['policy']:>8}: p50={r['p50_s']*1e3:7.1f}ms  "
                  f"p99={r['p99_s']*1e3:8.1f}ms  "
                  f"slo_miss={r['slo_miss_rate']*100:5.1f}%  "
                  f"shed={r['shed_rate']*100:4.1f}%  "
                  f"quality={r['mean_quality']:.3f}  {r['by_model']}")
    blind, aware = by["blind"], by["load+slo"]
    eps = 1e-9
    miss_gain = blind["slo_miss_rate"] / max(aware["slo_miss_rate"], eps)
    p99_gain = blind["p99_s"] / max(aware["p99_s"], eps)
    # acceptance: >= 2x lower SLO-miss rate (or p99) on the burst
    assert miss_gain >= min_gain or p99_gain >= min_gain, \
        (miss_gain, p99_gain, by)
    assert aware["mean_quality"] >= blind["mean_quality"] - quality_tol, by
    # the pure-load policy must already help (routing term alone)
    assert by["load"]["slo_miss_rate"] <= blind["slo_miss_rate"] + eps, by
    return {"scenario": {"duration_s": duration_s, "base_rate": base_rate,
                         "burst_rate": burst_rate,
                         "deadline_ms": deadline_ms},
            "catalog": [dict(zip(("name", "accuracy", "latency_ms",
                                  "cost", "slots"), c)) for c in CATALOG],
            "episodes": rows,
            "miss_gain": miss_gain, "p99_gain": p99_gain}


def _best_of(f, trials: int, inner: int) -> float:
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(inner):
            f()
        times.append((time.perf_counter() - t0) / inner)
    return min(times)


def run_overhead(catalog_n: int = 128, b: int = 256, repeats: int = 8,
                 max_ratio: float = 2.0, verbose: bool = True) -> Dict:
    """route_many with the load term vs. without, at serving batch
    sizes: the (N,) penalty snapshot + candidate gather must stay a
    small fraction of the routing pass (measured ~1.0-1.1x; the guard
    leaves headroom for scheduler noise on shared boxes)."""
    from benchmarks.router_scale import _random_queries, _synthetic_catalog
    mres = _synthetic_catalog(catalog_n)
    mres.embeddings()
    prefs, sigs = _random_queries(b)
    eng_off = RoutingEngine(mres, knn_k=8)
    tracker = LoadTracker(catalog_n)
    tracker.admit_many(np.arange(catalog_n).repeat(3))   # non-trivial state
    eng_on = RoutingEngine(mres, knn_k=8, load=tracker, load_weight=1.0)
    eng_off.route_many(prefs, sigs)                      # warm-up
    eng_on.route_many(prefs, sigs)
    t_off = _best_of(lambda: eng_off.route_many(prefs, sigs),
                     trials=repeats, inner=3) / b * 1e6
    t_on = _best_of(lambda: eng_on.route_many(prefs, sigs),
                    trials=repeats, inner=3) / b * 1e6
    ratio = t_on / t_off
    if verbose:
        print(f"  route_many N={catalog_n} B={b}: "
              f"blind={t_off:6.1f}us/q  load-aware={t_on:6.1f}us/q  "
              f"ratio={ratio:4.2f}x")
    assert ratio <= max_ratio, (t_off, t_on)
    return {"catalog": catalog_n, "batch": b, "blind_us": t_off,
            "load_aware_us": t_on, "ratio": ratio}


def run(*, duration_s: float = 20.0, base_rate: float = 40.0,
        burst_rate: float = 260.0, overhead_max_ratio: float = 2.0,
        verbose: bool = True):
    burst = run_burst(duration_s=duration_s, base_rate=base_rate,
                      burst_rate=burst_rate, verbose=verbose)
    ovh = run_overhead(max_ratio=overhead_max_ratio, verbose=verbose)
    save_result("load_aware", {"burst": burst, "overhead": ovh})
    by = {r["policy"]: r for r in burst["episodes"]}
    return ("load_aware", ovh["load_aware_us"],
            f"slo_miss {by['blind']['slo_miss_rate']*100:.1f}% -> "
            f"{by['load+slo']['slo_miss_rate']*100:.1f}% "
            f"({burst['miss_gain']:.1f}x lower), p99 "
            f"{by['blind']['p99_s']*1e3:.0f}ms -> "
            f"{by['load+slo']['p99_s']*1e3:.0f}ms on hot-model burst; "
            f"load term {ovh['ratio']:.2f}x route_many")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale episode for CI; same >=2x "
                    "SLO-miss/p99 assertion, looser overhead guard for "
                    "shared-runner noise")
    args = ap.parse_args(argv)
    if args.smoke:
        run(duration_s=8.0, base_rate=30.0, burst_rate=200.0,
            overhead_max_ratio=3.0)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
