"""Benchmark: the semantic response cache on repeat-heavy traffic.

The MetaLLM / RouteLLM serving observation: the dominant cost win is
avoiding expensive model calls entirely.  This benchmark replays a
Zipf-distributed query log (``repro.data.workload.ZipfReplayScenario``
— a small head of queries dominates traffic) through two serving
engines over the SAME runnable catalog:

  * ``nocache`` — every request pays analyze -> route -> admit ->
    generate on a real (reduced) JAX runner;
  * ``cache``   — ``SemanticCache`` consulted first; validated
    responses written back via the observe loop, so the head of the
    distribution short-circuits the whole pipeline after its first
    appearance.

Asserts (the PR's acceptance criteria):
  * the episode reaches >= ``min_hit_rate`` (50%) cache hits;
  * the cache-hit path is >= ``min_speedup`` (10x) cheaper end-to-end
    than route+generate, measured on the same engine (a fully-warm
    all-hit replay vs. the no-cache episode);
  * hits replay the exact stored tokens (correctness, not just speed).

``--smoke`` runs a seconds-scale episode for CI with the same
assertions.  Results land in results/bench/cache_hit.json.

Note on the reported episode times: the cached episode's wall clock
includes one-off XLA recompiles for every DISTINCT miss-group batch
shape (misses arrive in irregular group sizes; the no-cache baseline
generates at one fixed shape), a CPU-interpreter artifact — which is
why the asserted comparison is warm hit path vs. the no-cache
route+generate path, both measured shape-stable.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import cached_analyzer, save_result, synthetic_entry
from repro.cache import SemanticCache
from repro.core.mres import MRES
from repro.core.orchestrator import OptiRoute
from repro.core.telemetry import Telemetry
from repro.data.workload import (ZipfReplayScenario, meta_of, quality_of,
                                 zipf_replay)
from repro.serving.engine import Request, ServingEngine
from repro.serving.runner import ModelRunner

# (name, accuracy, latency_ms, cost): a small spread so routing is
# non-trivial; every entry shares one reduced runner (the benchmark
# times the serving path, not four separate parameter sets)
CATALOG: Tuple[Tuple[str, float, float, float], ...] = (
    ("gen-accurate", 0.92, 120.0, 4.0),
    ("gen-balanced", 0.80, 60.0, 1.5),
    ("gen-cheap", 0.65, 30.0, 0.4),
)


def _build_catalog() -> MRES:
    from repro.configs import get_smoke
    runner = ModelRunner(get_smoke("llama3.2-1b"), seed=0)
    m = MRES()
    entries = []
    for name, acc, lat, cost in CATALOG:
        e = synthetic_entry(name, accuracy=acc, latency_ms=lat, cost=cost,
                            task_types=("chat", "summarization", "code"),
                            domains=("general", "software"),
                            generalist=True)
        e.runner = runner
        entries.append(e)
    m.register_many(entries)
    return m


def _make_engine(mres: MRES, analyzer, with_cache: bool,
                 threshold: float, capacity: int) -> ServingEngine:
    cache = SemanticCache(capacity=capacity, threshold=threshold,
                          min_quality=0.3) if with_cache else None
    router = OptiRoute(mres, analyzer, telemetry=Telemetry(), cache=cache)
    return ServingEngine(router)


def _replay(eng: ServingEngine, pool, order, *, batch: int,
            max_new: int = 4) -> Tuple[float, List]:
    """Run the replay in submit+observe batches; returns (wall_s, log)."""
    metas = {e.name: meta_of(e) for e in eng.router.mres.entries}
    out: List = []
    t0 = time.perf_counter()
    for lo in range(0, len(order), batch):
        idx = order[lo:lo + batch]
        reqs = [Request(text=pool[j].text, prefs="balanced",
                        id=int(lo + i), max_new=max_new)
                for i, j in enumerate(idx)]
        resps = eng.submit(reqs)
        # close the loop with ground-truth quality: validated responses
        # become cache entries via the observe write-back
        quals = [quality_of(metas[r.model], pool[j].sig)
                 for r, j in zip(resps, idx)]
        eng.observe(resps, quals)
        out.extend(resps)
    return time.perf_counter() - t0, out


def run(*, n_unique: int = 48, n_requests: int = 384, batch: int = 32,
        threshold: float = 0.95, min_hit_rate: float = 0.5,
        min_speedup: float = 10.0, verbose: bool = True) -> Tuple:
    sc = ZipfReplayScenario(n_unique=n_unique, n_requests=n_requests,
                            zipf_a=1.1, seed=7, task_type="chat",
                            domain="general")
    pool, order = zipf_replay(sc)
    mres = _build_catalog()
    analyzer, _ = cached_analyzer()

    # --- no-cache baseline: every request routes + generates ---------
    eng0 = _make_engine(mres, analyzer, False, threshold, n_requests)
    _replay(eng0, pool, order[:batch], batch=batch)          # jit warm-up
    eng0.log.clear()
    t_nocache, log0 = _replay(eng0, pool, order, batch=batch)
    miss_us = t_nocache / len(order) * 1e6

    # --- cached episode: write-back warms the head as it replays -----
    eng1 = _make_engine(mres, analyzer, True, threshold, n_requests)
    t_cache, log1 = _replay(eng1, pool, order, batch=batch)
    cache = eng1.cache
    hit_rate = sum(r.cache_hit for r in log1) / len(log1)
    funnel = eng1.router.telemetry.cache_funnel()

    # --- pure hit path: the SAME episode fully warm ------------------
    t_warm, log2 = _replay(eng1, pool, order, batch=batch)
    warm_hits = sum(r.cache_hit for r in log2) / len(log2)
    hit_us = t_warm / len(order) * 1e6
    speedup = miss_us / hit_us

    # correctness: every hit replays EXACTLY a validated stored
    # response (a near-duplicate may legitimately receive its semantic
    # neighbor's answer — that is the cache's trade-off — but never
    # tokens the quality loop did not vouch for)
    stored = {tuple(np.asarray(resp).tolist())
              for resp, ok in zip(cache.responses, cache.valid)
              if ok and resp is not None}
    checked = 0
    for r in log2:
        if r.cache_hit and r.tokens is not None:
            assert tuple(np.asarray(r.tokens).tolist()) in stored
            checked += 1
    assert checked > 0

    if verbose:
        print(f"  nocache: {t_nocache:6.2f}s ({miss_us:8.1f} us/req)  "
              f"cache episode: {t_cache:6.2f}s (hit {hit_rate*100:.1f}%)  "
              f"warm: {t_warm:6.2f}s ({hit_us:8.1f} us/req, "
              f"hit {warm_hits*100:.1f}%)")
        print(f"  hit-path speedup: {speedup:.1f}x   funnel: {funnel}")
    # acceptance: >= 50% hits on the Zipf episode, hit path >= 10x
    # cheaper end-to-end than route+generate
    assert hit_rate >= min_hit_rate, (hit_rate, funnel)
    assert warm_hits >= 0.95, warm_hits
    assert speedup >= min_speedup, (miss_us, hit_us, speedup)

    payload = {
        "scenario": {"n_unique": sc.n_unique, "n_requests": sc.n_requests,
                     "zipf_a": sc.zipf_a, "batch": batch,
                     "threshold": threshold},
        "catalog": [dict(zip(("name", "accuracy", "latency_ms", "cost"),
                             c)) for c in CATALOG],
        "nocache_us_per_req": miss_us,
        "cache_episode_s": t_cache,
        "hit_us_per_req": hit_us,
        "hit_rate": hit_rate,
        "warm_hit_rate": warm_hits,
        "speedup": speedup,
        "tokens_checked": checked,
        "cache_funnel": funnel,
        "cache_stats": cache.stats(),
    }
    save_result("cache_hit", payload)
    return ("cache_hit", hit_us,
            f"hit path {speedup:.0f}x cheaper than route+generate "
            f"({miss_us:.0f} -> {hit_us:.0f} us/req) at "
            f"{hit_rate*100:.0f}% episode hit rate")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale episode for CI; same >=50% "
                    "hit-rate and >=10x hit-path assertions")
    args = ap.parse_args(argv)
    if args.smoke:
        run(n_unique=24, n_requests=160, batch=32)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
