"""Benchmark 2 (paper §3 modes): batch mode amortizes the analyzer.

A homogeneous batch (the paper's target case) is routed two ways:
  * interactive — every query analyzed + routed;
  * batch       — ~2% sampled, one aggregate route for the whole batch.

Reported: analyzer calls / wall time per query, and routing agreement
(fraction of queries whose interactive decision equals the batch
decision) — agreement is the quality cost of amortization.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_analyzer, save_result
from repro.core.orchestrator import OptiRoute
from repro.data.workload import make_workload
from repro.serving.catalog import build_catalog


def run(batch_size: int = 200, seed: int = 0, verbose: bool = True):
    analyzer, metrics = cached_analyzer()
    mres = build_catalog(smoke_runners=False)
    router = OptiRoute(mres, analyzer, batch_sample_frac=0.02)

    # homogeneous batch: one task type/domain, complexity spread
    wl = make_workload(batch_size, seed=seed, task_type="summarization",
                       domain="finance")
    texts = [r.text for r in wl]
    prefs = "cost-effective"

    # warm the jit caches of both paths (steady-state amortization claim)
    router.route(texts[0], prefs)
    router.route_batch(texts, prefs, seed=seed + 1)

    t0 = time.perf_counter()
    inter = [router.route(t, prefs) for t in texts]
    t_inter = time.perf_counter() - t0

    t0 = time.perf_counter()
    decision, sigs, stats = router.route_batch(texts, prefs, seed=seed)
    t_batch = time.perf_counter() - t0

    inter_models = [rq.decision.model for rq in inter]
    agreement = float(np.mean([m == decision.model for m in inter_models]))
    # quality parity: batch mode routes the whole batch to a model able
    # to handle the HARDEST sampled query (max-complexity aggregation),
    # so identity agreement underestimates it — measure the fraction of
    # queries where the batch model's ground-truth quality is within
    # 0.05 of the per-query interactive choice
    from repro.data.workload import quality_of
    entries = {e.name: e for e in mres.entries}

    def meta(e):
        return {"accuracy": e.raw_metrics["accuracy"],
                "task_types": e.task_types, "domains": e.domains}

    parity = float(np.mean([
        quality_of(meta(entries[decision.model]), r.sig)
        >= quality_of(meta(entries[m]), r.sig) - 0.05
        for r, m in zip(wl, inter_models)]))
    out = {
        "batch_size": batch_size,
        "analyzer_metrics": metrics,
        "interactive": {
            "analyzer_calls": batch_size,
            "wall_s_total": t_inter,
            "wall_ms_per_query": t_inter / batch_size * 1e3,
        },
        "batch": {
            "analyzer_calls": stats["sampled"],
            "wall_s_total": t_batch,
            "wall_ms_per_query": t_batch / batch_size * 1e3,
            "model": decision.model,
        },
        "derived": {
            "analyzer_amortization": batch_size / stats["sampled"],
            "speedup": t_inter / t_batch,
            "routing_agreement": agreement,
            "quality_parity": parity,
        },
    }
    if verbose:
        print(f"  interactive: {batch_size} analyzer calls, "
              f"{out['interactive']['wall_ms_per_query']:.2f} ms/q")
        print(f"  batch:       {stats['sampled']} analyzer calls, "
              f"{out['batch']['wall_ms_per_query']:.3f} ms/q "
              f"-> {decision.model}")
        print(f"  agreement:   {agreement:.1%} identity, "
              f"{parity:.1%} quality-parity, "
              f"speedup {out['derived']['speedup']:.1f}x")
    save_result("batch_mode", out)
    assert out["derived"]["speedup"] > 5, "batch mode must amortize"
    assert parity > 0.7, "batch model must hold quality for the batch"
    return ("batch_mode", out["batch"]["wall_ms_per_query"] * 1e3,
            f"{out['derived']['speedup']:.0f}x speedup, "
            f"{agreement:.0%} identity / {parity:.0%} quality-parity")


if __name__ == "__main__":
    run()
