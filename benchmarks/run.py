"""Benchmark harness: one experiment per paper claim (DESIGN.md §6).

  PYTHONPATH=src:. python -m benchmarks.run [--only name] [--smoke]

Prints a ``name,us_per_call,derived`` CSV summary (plus per-benchmark
detail above it) and writes JSON payloads to results/bench/.

``--smoke`` runs the seconds-scale CI variants of every benchmark that
has one (routing throughput, adaptive regret, load-aware SLO, semantic
cache hit path) — the CI slow job's entry point.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (ablations, adaptive, analyzer_pruning, batch_mode,
                        cache_hit, feedback, load_aware, merging,
                        obs_overhead, roofline, router_scale, routing_win,
                        soak)

ALL = {
    "routing_win": routing_win.run,
    "batch_mode": batch_mode.run,
    "feedback": feedback.run,
    "adaptive": adaptive.run,
    "load_aware": load_aware.run,
    "cache_hit": cache_hit.run,
    "router_scale": router_scale.run,
    "obs_overhead": obs_overhead.run,
    "analyzer_pruning": analyzer_pruning.run,
    "merging": merging.run,
    "ablations": ablations.run,
    "roofline": roofline.run,
    "soak": soak.run,
}

# benchmarks with a seconds-scale CI mode (each main accepts --smoke)
SMOKE = {
    "router_scale": router_scale.main,
    "adaptive": adaptive.main,
    "load_aware": load_aware.main,
    "cache_hit": cache_hit.main,
    "obs_overhead": obs_overhead.main,
    "analyzer_pruning": analyzer_pruning.main,
    "soak": soak.main,
}


def _run_smoke(names) -> int:
    failed = []
    for name in names:
        print(f"[bench-smoke] {name} ...", flush=True)
        t0 = time.time()
        try:
            rc = SMOKE[name](["--smoke"])
            if rc:
                failed.append(name)
        except Exception:                      # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
        print(f"[bench-smoke] {name} done in {time.time() - t0:.1f}s\n",
              flush=True)
    if failed:
        print(f"\nFAILED: {failed}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    choices=list(ALL))
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variants (subset of "
                    f"{sorted(SMOKE)})")
    args = ap.parse_args(argv)
    if args.smoke:
        names = args.only or list(SMOKE)
        missing = [n for n in names if n not in SMOKE]
        if missing:            # refuse a silent green no-op
            ap.error(f"no --smoke variant for {missing}; "
                     f"available: {sorted(SMOKE)}")
        return _run_smoke(names)
    names = args.only or list(ALL)

    rows = []
    failed = []
    for name in names:
        print(f"[bench] {name} ...", flush=True)
        t0 = time.time()
        try:
            rows.append(ALL[name]())
        except Exception:                      # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
            rows.append((name, 0.0, "FAILED"))
        print(f"[bench] {name} done in {time.time() - t0:.1f}s\n",
              flush=True)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if failed:
        print(f"\nFAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
