"""Benchmark: online adaptive routing (the ``repro.adaptive`` bandit
layer) vs. the static preference router under non-stationary traffic.

Three sections:

1. Regret under drift — the ``model-degrade`` scenario: the catalog's
   accuracy leader silently loses most of its true quality mid-episode
   while its catalog metrics stay stale.  The static router keeps
   routing to it; the bandit-blended router observes shaped rewards
   (quality minus cost/latency penalties) and re-routes.  Reports
   cumulative regret vs. the per-query oracle, plus recovery time, and
   asserts the bandit beats BOTH the static router (lower regret) and
   uniform-random choice (higher cumulative reward).

2. Kernel parity — Pallas ``bandit_update`` (interpret mode) against
   the ``kernels/ref.py`` oracle on the benchmark's shapes.

3. Throughput — batched route+learn (``route_many`` with the adaptive
   blend + posterior update) must stay within 2x of the static
   ``route_many`` path at serving batch sizes.

``--smoke`` runs a seconds-scale version of all three for CI.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import save_result, synthetic_entry
from repro.adaptive import LinearBandit, RewardConfig, RewardShaper
from repro.core.mres import MRES
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import DOMAINS, TaskSignature
from repro.core.telemetry import Telemetry
from repro.data.workload import (DriftScenario, NonStationaryWorkload,
                                 meta_of)


class SigAnalyzer:
    """Analyzer stand-in fed the workload's ground-truth signatures
    (the benchmark measures the ROUTER's adaptivity, not the analyzer)."""

    def __init__(self):
        self.sigs: List[TaskSignature] = []

    def analyze_batch(self, texts):
        assert len(texts) == len(self.sigs)
        return list(self.sigs)

    def analyze(self, text):
        return self.sigs[0]


def _drift_catalog(n_models: int = 10, seed: int = 0) -> MRES:
    """Chat catalog with an accuracy spread and varied cost/latency;
    every model passes the hierarchical filters so adaptivity (not
    filtering) decides the winner."""
    rng = np.random.default_rng(seed)
    m = MRES()
    m.register_many([
        synthetic_entry(
            f"m{i}", accuracy=0.35 + 0.5 * i / max(n_models - 1, 1),
            latency_ms=float(rng.uniform(30, 300)),
            cost=float(rng.uniform(0.5, 8.0)),
            task_types=("chat",), domains=tuple(DOMAINS),
            generalist=True,
            helpfulness=float(rng.uniform(0.3, 0.9)),
            harmlessness=float(rng.uniform(0.3, 0.9)),
            honesty=float(rng.uniform(0.3, 0.9)))
        for i in range(n_models)])
    return m


def _episode(wl: NonStationaryWorkload, mres: MRES,
             shaper: RewardShaper, *, policy: str, prefs: str,
             adaptive_weight: float, alpha: float, forget: float,
             seed: int = 0) -> Dict:
    """Run one routing policy through the scenario; return the reward /
    regret trajectory.  ``policy`` in {static, linucb, thompson,
    random}."""
    sc = wl.sc
    names = wl.names
    n = len(names)
    pen = shaper.penalty_row()                      # (N,) shaped oracle
    rng = np.random.default_rng(seed + 99)
    an = SigAnalyzer()
    bandit: Optional[LinearBandit] = None
    router: Optional[OptiRoute] = None
    if policy != "random":
        if policy in ("linucb", "thompson"):
            bandit = LinearBandit(n, policy=policy, alpha=alpha,
                                  forget=forget, seed=seed)
        router = OptiRoute(mres, an, knn_k=n, telemetry=Telemetry(),
                           adaptive=bandit,
                           adaptive_weight=(adaptive_weight
                                            if bandit is not None else 0.0),
                           reward_shaper=shaper)
    reward_t = np.zeros(sc.n_steps)
    regret_t = np.zeros(sc.n_steps)
    chosen_log: List[List[str]] = []
    for t in range(sc.n_steps):
        batch = wl.batch(t)
        sigs = [q.sig for q in batch]
        if policy == "random":
            chosen = rng.integers(0, n, len(batch))
            models = [names[j] for j in chosen]
        else:
            an.sigs = sigs
            rqs = router.route_all([q.text for q in batch], prefs)
            models = [rq.decision.model for rq in rqs]
            chosen = np.array([wl._col[m] for m in models])
        # one quality table per step: realized qualities are a gather
        # of the same matrix the oracle accounting uses
        Q = wl.quality_matrix(t, sigs)
        qual = Q[np.arange(len(batch)), chosen]
        if bandit is not None:
            router.observe(rqs, qualities=qual)
        # shaped-reward oracle accounting (same reward the bandit sees)
        Qs = Q - pen[None, :]
        realized = qual - pen[chosen]
        reward_t[t] = realized.sum()
        regret_t[t] = (Qs.max(axis=1) - realized).sum()
        chosen_log.append(models)
    # recovery: steps after the shift until the degraded model stops
    # winning the batch majority
    recovery = None
    deg = wl.degraded_model
    if deg is not None:
        for t in range(wl.shift_step, sc.n_steps):
            top = max(set(chosen_log[t]), key=chosen_log[t].count)
            if top != deg:
                recovery = t - wl.shift_step
                break
    return {"policy": policy,
            "cum_reward": float(reward_t.sum()),
            "cum_regret": float(regret_t.sum()),
            "regret_series": np.cumsum(regret_t).tolist(),
            "recovery_steps": recovery}


def run_regret(*, n_models: int = 10, steps: int = 80, batch: int = 16,
               adaptive_weight: float = 2.0, alpha: float = 0.5,
               forget: float = 0.96, with_thompson: bool = True,
               verbose: bool = True) -> Dict:
    mres = _drift_catalog(n_models)
    shaper = RewardShaper(mres, RewardConfig(cost_weight=0.15,
                                             latency_weight=0.1))
    metas = [meta_of(e) for e in mres.entries]
    # degrade the model the STATIC router prefers: its catalog metrics
    # go stale mid-episode while it keeps winning the static blend —
    # exactly the failure mode an online learner must route around
    sc = DriftScenario(kind="model-degrade", n_steps=steps, batch=batch,
                       task_type="chat", shift_frac=0.4, seed=7)
    probe_wl = NonStationaryWorkload(metas, sc)
    an = SigAnalyzer()
    probe = OptiRoute(mres, an, knn_k=n_models)
    pb = probe_wl.batch(0)
    an.sigs = [q.sig for q in pb]
    picked = [rq.decision.model
              for rq in probe.route_all([q.text for q in pb],
                                        "accuracy-first")]
    sc = DriftScenario(kind="model-degrade", n_steps=steps, batch=batch,
                       task_type="chat", shift_frac=0.4, seed=7,
                       degrade_model=max(set(picked), key=picked.count))
    wl = NonStationaryWorkload(metas, sc)
    policies = ["static", "linucb", "random"]
    if with_thompson:
        policies.insert(2, "thompson")
    rows = [_episode(wl, mres, shaper, policy=p, prefs="accuracy-first",
                     adaptive_weight=adaptive_weight, alpha=alpha,
                     forget=forget, seed=11) for p in policies]
    by = {r["policy"]: r for r in rows}
    if verbose:
        for r in rows:
            print(f"  {r['policy']:>9}: cum_reward={r['cum_reward']:8.1f}  "
                  f"cum_regret={r['cum_regret']:8.1f}  "
                  f"recovery={r['recovery_steps']}")
    # the adaptive claims (acceptance criteria)
    assert by["linucb"]["cum_regret"] < by["static"]["cum_regret"], by
    assert by["linucb"]["cum_reward"] > by["random"]["cum_reward"], by
    return {"scenario": "model-degrade", "steps": steps, "batch": batch,
            "degraded": wl.degraded_model, "shift_step": wl.shift_step,
            "episodes": rows}


def run_parity(verbose: bool = True) -> None:
    import jax.numpy as jnp

    from repro.kernels import ops as K
    from repro.kernels import ref as R
    rng = np.random.default_rng(5)
    Bu, Bs, N, D = 32, 24, 150, 9
    x_up = rng.random((Bu, D)).astype(np.float32)
    w = np.zeros((Bu, N), np.float32)
    w[np.arange(Bu), rng.integers(0, N, Bu)] = 1.0
    r = rng.random(Bu).astype(np.float32)
    xs = rng.random((Bs, D)).astype(np.float32)
    theta = rng.standard_normal((N, D)).astype(np.float32)
    L = rng.standard_normal((N, D, D)).astype(np.float32) * 0.1
    ainv = np.einsum("nde,nfe->ndf", L, L) + np.eye(D, dtype=np.float32)
    got = K.bandit_update(x_up, w, r, xs, theta, ainv, 0.8)
    want = R.bandit_update(*(jnp.asarray(a) for a in
                             (x_up, w, r, xs, theta, ainv)), 0.8)
    for g, wnt, tol in zip(got, want, (1e-5, 1e-5, 1e-4)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wnt),
                                   rtol=tol, atol=tol)
    if verbose:
        print("  pallas bandit_update == ref oracle (interpret mode)")


def _best_of(f, trials: int, inner: int) -> float:
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(inner):
            f()
        times.append((time.perf_counter() - t0) / inner)
    return min(times)


def run_throughput(catalog_n: int = 128, b: int = 256, repeats: int = 10,
                   max_ratio: float = 2.0, verbose: bool = True) -> Dict:
    """Batched route+learn vs. static route_many (must stay within 2x;
    the CI smoke uses a looser guard-rail for shared-runner noise)."""
    from benchmarks.router_scale import _random_queries, _synthetic_catalog
    from repro.core.routing import RoutingEngine
    mres = _synthetic_catalog(catalog_n)
    mres.embeddings()
    prefs, sigs = _random_queries(b)
    eng_s = RoutingEngine(mres, knn_k=8)
    bandit = LinearBandit(catalog_n, policy="linucb", alpha=0.5)
    eng_a = RoutingEngine(mres, knn_k=8, adaptive=bandit,
                          adaptive_weight=1.0)
    names = mres.snapshot()[1]
    col = {m: j for j, m in enumerate(names)}
    rng = np.random.default_rng(3)
    rewards = rng.random(b).astype(np.float32)

    def adaptive_step():
        ds = eng_a.route_many(prefs, sigs)
        X = np.stack([d.task_vector for d in ds])
        chosen = np.array([col[d.model] for d in ds])
        bandit.update(X, chosen, rewards)

    eng_s.route_many(prefs, sigs)            # warm-up both paths
    adaptive_step()
    t_static = _best_of(lambda: eng_s.route_many(prefs, sigs),
                        trials=repeats, inner=3) / b * 1e6
    t_adapt = _best_of(adaptive_step, trials=repeats, inner=3) / b * 1e6
    ratio = t_adapt / t_static
    if verbose:
        print(f"  route+learn N={catalog_n} B={b}: "
              f"static={t_static:6.1f}us/q  adaptive={t_adapt:6.1f}us/q  "
              f"ratio={ratio:4.2f}x")
    assert ratio <= max_ratio, (t_static, t_adapt)
    return {"catalog": catalog_n, "batch": b, "static_us": t_static,
            "adaptive_us": t_adapt, "ratio": ratio}


def run(*, steps: int = 80, batch: int = 16, with_thompson: bool = True,
        throughput_b: int = 256, throughput_max_ratio: float = 2.0,
        verbose: bool = True):
    regret = run_regret(steps=steps, batch=batch,
                        with_thompson=with_thompson, verbose=verbose)
    run_parity(verbose=verbose)
    thr = run_throughput(b=throughput_b, max_ratio=throughput_max_ratio,
                         verbose=verbose)
    save_result("adaptive", {"regret": regret, "throughput": thr})
    by = {r["policy"]: r for r in regret["episodes"]}
    ratio = by["static"]["cum_regret"] / max(by["linucb"]["cum_regret"],
                                             1e-9)
    return ("adaptive", thr["adaptive_us"],
            f"bandit regret {by['linucb']['cum_regret']:.0f} vs static "
            f"{by['static']['cum_regret']:.0f} ({ratio:.1f}x lower) on "
            f"model-degrade; recovery {by['linucb']['recovery_steps']} "
            f"steps; route+learn {thr['ratio']:.2f}x static")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for CI (small B/steps; still "
                    "asserts bandit > random reward and bandit < static "
                    "regret, kernel parity and the 2x throughput bound)")
    args = ap.parse_args(argv)
    if args.smoke:
        # 3x guard-rail: shared CI runners add ~unbounded timing noise;
        # the real <=2x claim is asserted by the full (quiet-box) run
        run(steps=30, batch=8, with_thompson=False,
            throughput_max_ratio=3.0)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
