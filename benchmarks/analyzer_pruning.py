"""Benchmark 5 (paper §3.2): the analyzer's latency optimizations —
long-query pruning fidelity AND the fused tokens->decision program.

Two parts:

1. Pruning fidelity (``run``, paper's claim): long queries pruned to
   first-n + last-n + sampled-middle words keep task-type/domain
   agreement with the unpruned forward while bounding latency.

2. Fused analyze->route sweep (``bench_analyze_fused``, ``--smoke``):
   end-to-end tokens->decision, the SINGLE fused device program
   (``route_tokens_batch``) vs two staged comparators on the same
   tokens/catalog — the PRE-FUSION pipeline (the seed's
   ``analyze_batch`` epilogue + eager ``route_many``; the 2x gate)
   and the current restaged ``analyze_tokens`` -> ``route_many`` —
   interleaved sustained-median rounds.  ASSERTED:
     * decision parity (same models, or scores within 1e-4),
     * exactly ONE device dispatch per fused batch, ZERO recompiles
       after warmup (route_step_stats accounting),
     * fused >= 2x faster than the pre-fusion path at B=256 (that
       path pays two extra softmax host syncs and a per-row Python
       loop; the fused program folds everything into the one dispatch
       it already makes), and strictly faster than the restaged path.
   Also measures the int8-quantized analyzer through the same fused
   program (reported, drift-bounded — not a speed gate on CPU).
   Writes results/bench/analyze_fused.json — the CI artifact.

  PYTHONPATH=src:. python -m benchmarks.analyzer_pruning [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import cached_analyzer, save_result
from repro.core.analyzer import (AnalyzerConfig, TaskAnalyzer,
                                 prune_text, quantize_int8)
from repro.data.workload import _FILLER as _FILL
from repro.data.workload import make_workload


def _inflate(text: str, target_words: int, rng) -> str:
    """Pad a query's middle with filler to the target length, keeping the
    task description at the edges (the paper's long-query shape)."""
    words = text.split()
    need = target_words - len(words)
    if need <= 0:
        return text
    blob = list(rng.choice(_FILL, need))
    cut = max(len(words) // 2, 1)
    return " ".join(words[:cut] + blob + words[cut:])


# ----------------------------------------------------------------------
# part 1: pruning fidelity (unchanged paper claim)
# ----------------------------------------------------------------------

def run(n: int = 120, lengths=(64, 256, 1024, 2048), seed: int = 0,
        verbose: bool = True):
    analyzer, _ = cached_analyzer()
    rng = np.random.default_rng(seed)
    wl = make_workload(n, seed=seed)
    base_sigs = analyzer.analyze_batch([r.text for r in wl])

    rows = []
    for L in lengths:
        texts = [_inflate(r.text, L, rng) for r in wl]
        # pruned path (production default); warm jit before timing
        analyzer.analyze_batch(texts)
        t0 = time.perf_counter()
        pr_sigs = analyzer.analyze_batch(texts)
        t_pruned = (time.perf_counter() - t0) / n * 1e3
        # unpruned path: same encoder with the position table tiled to
        # cover the raw length (latency comparison only)
        raw_cfg = AnalyzerConfig(max_len=min(L + 8, 2048),
                                 prune_head=10**9, prune_tail=0, prune_mid=0)
        toks = analyzer.tok.encode_batch(texts, raw_cfg.max_len)
        import jax.numpy as jnp
        from repro.core.analyzer import analyzer_forward
        raw_params = dict(analyzer.params)
        reps = -(-raw_cfg.max_len // analyzer.cfg.max_len)
        raw_params["pos"] = jnp.tile(analyzer.params["pos"], (reps, 1))
        fwd = jax.jit(lambda p, t: analyzer_forward(p, raw_cfg, t))
        fwd(raw_params, jnp.asarray(toks))          # compile outside timing
        t0 = time.perf_counter()
        jax.block_until_ready(fwd(raw_params, jnp.asarray(toks)))
        t_raw = (time.perf_counter() - t0) / n * 1e3

        tt_agree = float(np.mean([p.task_type == b.task_type
                                  for p, b in zip(pr_sigs, base_sigs)]))
        dm_agree = float(np.mean([p.domain == b.domain
                                  for p, b in zip(pr_sigs, base_sigs)]))
        tt_true = float(np.mean([p.task_type == r.sig.task_type
                                 for p, r in zip(pr_sigs, wl)]))
        rows.append({"words": L, "pruned_ms_per_q": t_pruned,
                     "raw_ms_per_q": t_raw, "tt_agree": tt_agree,
                     "dm_agree": dm_agree, "tt_acc_vs_truth": tt_true})
        if verbose:
            print(f"  {L:>5} words: pruned {t_pruned:6.2f} ms/q vs raw "
                  f"{t_raw:7.2f} ms/q | tt-agree {tt_agree:.1%} "
                  f"dm-agree {dm_agree:.1%} tt-acc {tt_true:.1%}")

    save_result("analyzer_pruning", {"rows": rows})
    last = rows[-1]
    assert last["tt_agree"] > 0.9, "pruning must preserve task-type"
    assert last["pruned_ms_per_q"] < last["raw_ms_per_q"], \
        "pruning must be faster on long queries"
    bench_analyze_fused(verbose=verbose)
    return ("analyzer_pruning", last["pruned_ms_per_q"] * 1e3,
            f"@2k words: {last['raw_ms_per_q']/last['pruned_ms_per_q']:.1f}x "
            f"faster, tt-agree {last['tt_agree']:.0%}")


# ----------------------------------------------------------------------
# part 2: fused tokens->decision vs the staged pipeline
# ----------------------------------------------------------------------

# the fused program must beat the pre-fusion staged pipeline by at
# least this at B=256 (host-sync + Python-loop elimination)
MIN_SPEEDUP = 2.0
# int8 analyzer may flip near-boundary decisions; complexity drift vs
# fp32 stays inside the quantization error budget
MAX_INT8_DRIFT = 0.15


def _sustained_median(fn, seconds: float):
    """Median per-call wall time of the second half of a timed run —
    sustained steady-state cost (see benchmarks.router_scale) — plus
    the number of calls made (for dispatch accounting)."""
    ts = []
    end = time.perf_counter() + seconds
    while time.perf_counter() < end:
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    half = sorted(ts[len(ts) // 2:])
    return half[len(half) // 2], len(ts)


def _pre_fusion_sigs(an, toks):
    """The seed's ``analyze_batch`` epilogue, reproduced faithfully as
    the benchmark baseline: raw-logit forward, full-bucket softmax as
    two extra host round-trips, then a per-row Python loop of numpy
    argmax/max calls building each TaskSignature.  This is the
    pipeline the fused program replaced (``_fwd`` still exists for
    train/evaluate, so the comparator runs the SAME encoder weights).
    """
    import jax.numpy as jnp

    from repro.core.preferences import (DOMAINS, TASK_TYPES,
                                        TaskSignature)
    n = toks.shape[0]
    bucket = 1 << max(n - 1, 0).bit_length()
    tp = toks
    if bucket != n:
        tp = np.concatenate([toks, np.zeros((bucket - n, toks.shape[1]),
                                            toks.dtype)])
    tt, dm, cx = an._fwd(an.params, jnp.asarray(tp))
    tt_p = np.asarray(jax.nn.softmax(tt, axis=-1))
    dm_p = np.asarray(jax.nn.softmax(dm, axis=-1))
    cx = np.asarray(cx)
    out = []
    for i in range(n):
        conf = float(min(tt_p[i].max(), dm_p[i].max()))
        out.append(TaskSignature(
            task_type=TASK_TYPES[int(tt_p[i].argmax())],
            domain=DOMAINS[int(dm_p[i].argmax())],
            complexity=float(np.clip(cx[i], 0.0, 1.0)),
            confidence=conf))
    return out


def bench_analyze_fused(catalog_n: int = 128, batches=(32, 256),
                        rounds: int = 3, seconds: float = 0.6,
                        verbose: bool = True) -> dict:
    """Tokens->decision: the single fused ``route_tokens_batch``
    dispatch vs two staged comparators on the same tokens/catalog —
    the PRE-FUSION pipeline (seed epilogue + eager ``route_many``,
    the 2x gate) and the current restaged ``analyze_tokens`` ->
    ``route_many`` (reported; fused must still be strictly faster).

    The encoder is deliberately tiny: the sweep measures the
    ORCHESTRATION cost the fusion removes (dispatch count, host
    syncs, per-row Python), not encoder FLOPs — the win must survive
    on a CPU box where nothing is accelerator-bound."""
    from benchmarks.router_scale import _synthetic_catalog
    from repro.core.routing import RoutingEngine
    from repro.kernels import ops as K

    an = TaskAnalyzer(AnalyzerConfig(
        vocab_size=512, d_model=16, n_layers=1, n_heads=2, d_ff=16,
        max_len=8), seed=0)
    mres = _synthetic_catalog(catalog_n, seed=3)
    mres.embeddings()
    eng = RoutingEngine(mres, knn_k=8)
    prefs = "balanced"

    rows = []
    for b in batches:
        texts = [r.text for r in make_workload(b, seed=b)]
        # tokenize ONCE outside the timed region: the program under
        # test starts at token ids; all three comparators would pay
        # the identical host-side encode (reported for context)
        t0 = time.perf_counter()
        toks = an.encode_batch(texts)
        encode_ms = (time.perf_counter() - t0) * 1e3

        def staged_pre():
            return eng.route_many(prefs, _pre_fusion_sigs(an, toks))

        def staged_now():
            return eng.route_many(prefs, an.analyze_tokens(toks))

        def fused():
            return eng.route_tokens_batch(an.params, an.cfg, toks,
                                          prefs).models()

        # warm every jit bucket, then gate on parity: the fused
        # program must make the same decisions before it may be faster
        dp, dn = staged_pre(), staged_now()
        fb = eng.route_tokens_batch(an.params, an.cfg, toks, prefs)
        for name, ds in (("pre", dp), ("now", dn)):
            assert fb.models() == [d.model for d in ds] or np.allclose(
                fb.score, [d.score for d in ds], atol=1e-4), \
                f"fused/staged-{name} decision divergence at B={b}"

        warm = K.route_step_stats()
        tp, tn, tf = [], [], []
        n_pre = n_now = n_fused = 0
        for _ in range(rounds):                    # interleaved rounds
            ms, nc = _sustained_median(staged_pre, seconds)
            tp.append(ms); n_pre += nc
            ms, nc = _sustained_median(staged_now, seconds)
            tn.append(ms); n_now += nc
            ms, nc = _sustained_median(fused, seconds)
            tf.append(ms); n_fused += nc
        stats = K.route_step_stats()
        # zero recompiles across the sweep; dispatch deltas pin the
        # program counts exactly — every comparator routes through ONE
        # route_step program per batch, the fused one ALSO covers the
        # analyzer (both counter families bump on its single dispatch)
        assert stats["route_step_compiles"] == warm["route_step_compiles"]
        assert stats["analyze_step_compiles"] == \
            warm["analyze_step_compiles"], "fused sweep recompiled"
        assert stats["route_step_dispatches"] == \
            warm["route_step_dispatches"] + n_pre + n_now + n_fused, \
            "fused path made more than one dispatch per batch"
        assert stats["analyze_step_dispatches"] == \
            warm["analyze_step_dispatches"] + n_now + n_fused

        pre_ms = sorted(tp)[rounds // 2] * 1e3
        now_ms = sorted(tn)[rounds // 2] * 1e3
        fused_ms = sorted(tf)[rounds // 2] * 1e3
        speedup = pre_ms / fused_ms
        rows.append({"batch": b, "staged_pre_ms": pre_ms,
                     "staged_now_ms": now_ms, "fused_ms": fused_ms,
                     "speedup_vs_pre": speedup,
                     "speedup_vs_now": now_ms / fused_ms,
                     "encode_ms": encode_ms,
                     "fused_dispatches": n_fused, "recompiles": 0})
        if verbose:
            print(f"  tokens->decision B={b:>4}: "
                  f"staged-pre {pre_ms:6.2f} ms  "
                  f"staged-now {now_ms:6.2f} ms  "
                  f"fused {fused_ms:6.2f} ms  {speedup:4.1f}x  "
                  f"(+{encode_ms:.2f} ms encode, {n_fused} fused "
                  f"batches, 1 dispatch each, 0 recompiles)")

    # int8 analyzer through the same fused program: report latency and
    # bound the signature drift vs fp32 (decision flips near ties are
    # legitimate; complexity drift is not)
    b = batches[-1]
    texts = [r.text for r in make_workload(b, seed=b)]
    qp = quantize_int8(an.params)
    toks = an.encode_batch(texts)
    fb32 = eng.route_tokens_batch(an.params, an.cfg, toks, prefs)
    fb8 = eng.route_tokens_batch(qp, an.cfg, toks, prefs)
    drift = float(np.max(np.abs(fb8.cx - fb32.cx)))
    agree = float(np.mean([a == c for a, c in zip(fb8.models(),
                                                  fb32.models())]))
    assert drift <= MAX_INT8_DRIFT, f"int8 complexity drift {drift}"
    tq, _ = _sustained_median(
        lambda: eng.route_tokens_batch(qp, an.cfg, toks, prefs), seconds)
    quant = {"batch": b, "fused_int8_ms": tq * 1e3,
             "cx_drift_vs_fp32": drift, "model_agreement": agree}
    if verbose:
        print(f"  int8 fused  B={b:>4}: {tq * 1e3:7.2f} ms  "
              f"cx-drift {drift:.3f}  model-agree {agree:.1%}")

    last = rows[-1]
    assert last["batch"] == 256 and \
        last["speedup_vs_pre"] >= MIN_SPEEDUP, (
        f"fused analyze->route only {last['speedup_vs_pre']:.2f}x vs "
        f"the pre-fusion staged path at B={last['batch']} "
        f"(floor {MIN_SPEEDUP}x)")
    assert last["speedup_vs_now"] > 1.0, (
        "fused path slower than the restaged analyze_tokens -> "
        "route_many pipeline")
    out = {"catalog": catalog_n, "rows": rows, "quant": quant,
           "min_speedup": MIN_SPEEDUP}
    save_result("analyze_fused", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant (fused sweep only)")
    args = ap.parse_args(argv)
    if args.smoke:
        bench_analyze_fused(catalog_n=128, batches=(32, 256),
                            rounds=3, seconds=0.3)
        return 0
    name, us, derived = run()
    print(f"{name}: {us:.2f}us/q  {derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
