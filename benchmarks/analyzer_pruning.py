"""Benchmark 5 (paper §3.2): long-query pruning keeps analyzer fidelity
while bounding latency.

The paper prunes long queries to first-n + last-n + sampled-middle words
because "the task description usually lives at the edges".  We measure,
on synthetic long queries (up to ~2k words of context blob around an
edge task description):
  * prediction agreement (task type / domain) pruned vs unpruned-truth,
  * analyzer wall latency vs raw query length, pruned and unpruned.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import cached_analyzer, save_result
from repro.core.analyzer import AnalyzerConfig, prune_text
from repro.data.workload import _FILLER as _FILL
from repro.data.workload import make_workload


def _inflate(text: str, target_words: int, rng) -> str:
    """Pad a query's middle with filler to the target length, keeping the
    task description at the edges (the paper's long-query shape)."""
    words = text.split()
    need = target_words - len(words)
    if need <= 0:
        return text
    blob = list(rng.choice(_FILL, need))
    cut = max(len(words) // 2, 1)
    return " ".join(words[:cut] + blob + words[cut:])


def run(n: int = 120, lengths=(64, 256, 1024, 2048), seed: int = 0,
        verbose: bool = True):
    analyzer, _ = cached_analyzer()
    rng = np.random.default_rng(seed)
    wl = make_workload(n, seed=seed)
    base_sigs = analyzer.analyze_batch([r.text for r in wl])

    rows = []
    for L in lengths:
        texts = [_inflate(r.text, L, rng) for r in wl]
        # pruned path (production default); warm jit before timing
        analyzer.analyze_batch(texts)
        t0 = time.perf_counter()
        pr_sigs = analyzer.analyze_batch(texts)
        t_pruned = (time.perf_counter() - t0) / n * 1e3
        # unpruned path: same encoder with the position table tiled to
        # cover the raw length (latency comparison only)
        raw_cfg = AnalyzerConfig(max_len=min(L + 8, 2048),
                                 prune_head=10**9, prune_tail=0, prune_mid=0)
        toks = analyzer.tok.encode_batch(texts, raw_cfg.max_len)
        import jax.numpy as jnp
        from repro.core.analyzer import analyzer_forward
        raw_params = dict(analyzer.params)
        reps = -(-raw_cfg.max_len // analyzer.cfg.max_len)
        raw_params["pos"] = jnp.tile(analyzer.params["pos"], (reps, 1))
        fwd = jax.jit(lambda p, t: analyzer_forward(p, raw_cfg, t))
        fwd(raw_params, jnp.asarray(toks))          # compile outside timing
        t0 = time.perf_counter()
        jax.block_until_ready(fwd(raw_params, jnp.asarray(toks)))
        t_raw = (time.perf_counter() - t0) / n * 1e3

        tt_agree = float(np.mean([p.task_type == b.task_type
                                  for p, b in zip(pr_sigs, base_sigs)]))
        dm_agree = float(np.mean([p.domain == b.domain
                                  for p, b in zip(pr_sigs, base_sigs)]))
        tt_true = float(np.mean([p.task_type == r.sig.task_type
                                 for p, r in zip(pr_sigs, wl)]))
        rows.append({"words": L, "pruned_ms_per_q": t_pruned,
                     "raw_ms_per_q": t_raw, "tt_agree": tt_agree,
                     "dm_agree": dm_agree, "tt_acc_vs_truth": tt_true})
        if verbose:
            print(f"  {L:>5} words: pruned {t_pruned:6.2f} ms/q vs raw "
                  f"{t_raw:7.2f} ms/q | tt-agree {tt_agree:.1%} "
                  f"dm-agree {dm_agree:.1%} tt-acc {tt_true:.1%}")

    save_result("analyzer_pruning", {"rows": rows})
    last = rows[-1]
    assert last["tt_agree"] > 0.9, "pruning must preserve task-type"
    assert last["pruned_ms_per_q"] < last["raw_ms_per_q"], \
        "pruning must be faster on long queries"
    return ("analyzer_pruning", last["pruned_ms_per_q"] * 1e3,
            f"@2k words: {last['raw_ms_per_q']/last['pruned_ms_per_q']:.1f}x "
            f"faster, tt-agree {last['tt_agree']:.0%}")


if __name__ == "__main__":
    run()
