"""Shared benchmark utilities: cached trained analyzer, result I/O."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

REPO = pathlib.Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "bench"
ANALYZER_CKPT = REPO / "results" / "analyzer.npz"


def save_result(name: str, payload: Dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=2,
                                                     default=str))


def cached_analyzer(steps: int = 250, force: bool = False):
    """Train the Task Analyzer once; reuse the checkpoint afterwards."""
    from repro.checkpoint import load, save
    from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
    cfg = AnalyzerConfig()
    an = TaskAnalyzer(cfg)
    if ANALYZER_CKPT.exists() and not force:
        params, meta = load(str(ANALYZER_CKPT))
        an.params = params
        return an, meta.get("metrics", {})
    metrics = an.train(n_samples=4096, steps=steps)
    save(str(ANALYZER_CKPT), an.params, {"metrics": metrics})
    return an, metrics


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def __call__(self) -> float:
        return time.perf_counter() - self.t0


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"


def synthetic_entry(name, *, accuracy=0.5, latency_ms=100.0, cost=1.0,
                    task_types=("chat",), domains=("general",),
                    generalist=False, family="dense", n_params=0, **ethics):
    """A fully-populated MRES entry for synthetic catalogs."""
    from repro.core.mres import ModelEntry
    raw = {
        "accuracy": accuracy, "latency_ms": latency_ms,
        "cost_per_mtok": cost,
        "helpfulness": ethics.get("helpfulness", 0.5),
        "harmlessness": ethics.get("harmlessness", 0.5),
        "honesty": ethics.get("honesty", 0.5),
        "steerability": ethics.get("steerability", 0.5),
        "creativity": ethics.get("creativity", 0.5),
    }
    return ModelEntry(name=name, raw_metrics=raw, task_types=task_types,
                      domains=domains, generalist=generalist,
                      family=family, n_params=n_params)
