"""Quickstart: route three queries with different preference profiles.

  PYTHONPATH=src python examples/quickstart.py

Shows the full paper pipeline on the 10-architecture catalog (no model
execution — see serve_routed.py for that): user preferences -> Task
Analyzer json -> kNN + hierarchical filter + weighted scoring ->
RoutingDecision, then a thumbs-down feedback update.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
from repro.core.orchestrator import OptiRoute
from repro.serving.catalog import build_catalog

QUERIES = [
    ("cost-effective",
     "find the sentiment of the passage the quarterly portfolio report "
     "shows hedging gains during review"),
    ("accuracy-first",
     "solve this step by step however the paradox in the nested clause "
     "is subtle prove that the liability statute holds for all cases"),
    ("latency-first",
     "hello can you help me with travel cooking ideas"),
]


def main():
    print("== building the 10-architecture MRES catalog ==")
    mres = build_catalog()          # metrics derived from dry-run rooflines
    for e in mres.entries:
        m = e.raw_metrics
        print(f"  {e.name:<28} acc={m['accuracy']:.2f} "
              f"lat={m['latency_ms']:.4f}ms cost=${m['cost_per_mtok']:.4f}/Mtok")

    print("\n== training the task analyzer (miniature; one-off) ==")
    analyzer = TaskAnalyzer(AnalyzerConfig(d_model=64, n_layers=1, d_ff=128))
    metrics = analyzer.train(n_samples=1024, steps=120)
    print(f"  {metrics}")

    router = OptiRoute(mres, analyzer)
    print("\n== routing ==")
    last = None
    for profile, text in QUERIES:
        rq = router.route(text, profile)
        print(f"\n  profile={profile}")
        print(f"  query:    {text[:64]}...")
        print(f"  analyzer: {analyzer.to_json(rq.sig)}")
        d = rq.decision
        print(f"  decision: {d.model} (score {d.score:.3f}, "
              f"similarity {d.similarity:.3f}"
              f"{', fallback ' + d.fallback_kind if d.used_fallback else ''})")
        print(f"  stages:   {d.stage_sizes}")
        print(f"  runner-up: {d.candidates[1] if len(d.candidates) > 1 else '—'}")
        last = rq

    print("\n== feedback ==")
    bias = router.give_feedback(last, thumbs_up=False)
    print(f"  thumbs-down on {last.decision.model}: cluster bias -> {bias}")
    rq2 = router.route(QUERIES[-1][1], QUERIES[-1][0])
    print(f"  re-route after feedback: {rq2.decision.model}")


if __name__ == "__main__":
    main()
