"""End-to-end driver: serve a batched request stream through OptiRoute
with REAL (reduced) JAX models executing the routed requests.

  PYTHONPATH=src python examples/serve_routed.py [--requests 16]

This is the paper-kind end-to-end example (serving): requests with
mixed preference profiles arrive, each is analyzed + routed, requests
that landed on the same model run as ONE batched generate on that
model's runner (dense / MoE / SSM / hybrid reduced configs), thumbs
feedback is recorded, and the engine prints the cost/latency ledger.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import PROFILES
from repro.data.workload import make_workload, quality_of
from repro.serving.catalog import build_catalog
from repro.serving.engine import Request, ServingEngine

RUNNER_ARCHS = ["llama3.2-1b", "qwen3-moe-30b-a3b", "mamba2-1.3b",
                "hymba-1.5b", "gemma2-2b"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--mode", choices=("interactive", "batch"),
                    default="interactive")
    args = ap.parse_args(argv)

    print(f"== catalog with live reduced runners: {RUNNER_ARCHS} ==")
    mres = build_catalog(smoke_runners=True, archs=RUNNER_ARCHS)

    analyzer = TaskAnalyzer(AnalyzerConfig(d_model=64, n_layers=1, d_ff=128))
    print("== training analyzer ==")
    print("  ", analyzer.train(n_samples=1024, steps=120))

    router = OptiRoute(mres, analyzer)
    engine = ServingEngine(router)

    profiles = list(PROFILES)
    wl = make_workload(args.requests, seed=7)
    reqs = [Request(text=r.text, prefs=profiles[i % len(profiles)], id=r.id,
                    max_new=args.max_new) for i, r in enumerate(wl)]

    print(f"\n== serving {len(reqs)} requests ({args.mode}) ==")
    resps = engine.submit(reqs, mode=args.mode)
    for r, rec in zip(resps, wl):
        entry = mres.entry(r.model)
        q = quality_of({"accuracy": entry.raw_metrics["accuracy"],
                        "task_types": entry.task_types,
                        "domains": entry.domains}, rec.sig)
        up = q > 0.55
        engine.feedback(r, thumbs_up=up)
        print(f"  #{r.request.id:>3} [{r.request.prefs:<17}] "
              f"{r.sig.task_type}/{r.sig.domain} -> {r.model:<22} "
              f"tokens={r.tokens.tolist() if r.tokens is not None else None} "
              f"{'+1' if up else '-1'}")

    s = engine.summary()
    print("\n== ledger ==")
    print(f"  requests:         {s['requests']}")
    print(f"  per-model counts: {s['models']}")
    print(f"  simulated chip-s: {s['sim_latency_s']:.4f}")
    print(f"  route overhead:   {s['route_s']*1e3:.1f} ms total")
    print(f"  analyzer:         {s['analyzer_s']*1e3:.1f} ms total")


if __name__ == "__main__":
    main()
