"""End-to-end driver: train a ~100M-parameter model for a few hundred
steps on synthetic LM data and show the loss dropping.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses the training launcher with a custom llama-family config sized to
~100M parameters (d_model=512, 12 layers, 8k vocab), the pure-JAX AdamW
optimizer, pjit sharding on the host mesh, and checkpointing.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args(argv)

    first, last = train_main([
        "--arch", "llama3.2-1b",
        "--d-model", "640", "--n-layers", "16", "--d-ff", "2560",
        "--vocab", "16384",
        "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--lr", "3e-4", "--warmup", "40",
        "--ckpt-dir", "results/ckpt_100m", "--ckpt-every", "100",
    ])
    assert last < first * 0.7, "loss must drop by >30% over the run"
    print(f"OK: loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
