"""Example: §5 model-merging fallback with real weight soups.

  PYTHONPATH=src python examples/merge_models.py

Trains two same-config reduced checkpoints on different synthetic data
distributions (from a shared init — the model-soups requirement), then:
  1. registers them in an MRES with complementary domain tags,
  2. routes a query whose best option was filtered out by domain,
  3. shows the ModelMerger synthesizing the soup entry (averaged
     weights via ModelRunner.merged_with) and winning the re-route.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.merging import ModelMerger
from repro.core.mres import MRES, ModelEntry
from repro.core.preferences import TaskSignature, UserPreferences
from repro.core.routing import RoutingEngine
from repro.models import model as M
from repro.serving.runner import ModelRunner
from repro.training.optimizer import init_opt_state
from repro.training.steps import make_train_step


def train_runner(cfg, data_seed, steps=40, init_seed=7):
    rng = np.random.default_rng(data_seed)
    base = rng.integers(2, cfg.vocab_size - 1, 64)
    params = M.init_params(jax.random.PRNGKey(init_seed), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg))
    for _ in range(steps):
        starts = rng.integers(0, 64, 8)
        toks = np.stack([base[(s + np.arange(33)) % 64] for s in starts])
        batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        params, opt, _ = step(params, opt, batch)
    return ModelRunner(cfg, params=params)


def entry(name, runner, acc, domains):
    return ModelEntry(
        name=name,
        raw_metrics=dict(accuracy=acc, latency_ms=50.0, cost_per_mtok=1.0,
                         helpfulness=0.5, harmlessness=0.5, honesty=0.5,
                         steerability=0.5, creativity=0.5),
        task_types=("summarization",), domains=domains,
        family="dense", n_params=runner.cfg.n_params(), runner=runner)


def main():
    cfg = get_smoke("llama3.2-1b")
    print("== training two same-init checkpoints on different data ==")
    r_legal = train_runner(cfg, data_seed=1)
    r_general = train_runner(cfg, data_seed=2)

    mres = MRES()
    mres.register(entry("ckpt-legal", r_legal, 0.45, ("legal",)))
    mres.register(entry("ckpt-general", r_general, 0.95, ("general",)))
    eng = RoutingEngine(mres)
    sig = TaskSignature(task_type="summarization", domain="legal",
                        complexity=0.6)
    prefs = UserPreferences(weights={"accuracy": 0.9})

    before = eng.route(prefs, sig)
    print(f"\nincumbent (domain=legal filters out the strong model): "
          f"{before.model} score={before.score:.3f}")

    merger = ModelMerger(mres, score_threshold=10.0)
    soup_entry = merger.maybe_merge(prefs, sig, before.score)
    assert soup_entry is not None
    print(f"soup created: {soup_entry.name} domains={soup_entry.domains}")
    assert soup_entry.runner is not None, "real weight soup expected"

    after = eng.route(prefs, sig)
    print(f"re-route: {after.model} score={after.score:.3f} "
          f"(gain {after.score - before.score:+.3f})")

    toks = np.arange(8, dtype=np.int32)[None] + 2
    gen = soup_entry.runner.generate(toks, max_new=4)
    print(f"soup runner generates: {gen.tokens.tolist()}")


if __name__ == "__main__":
    main()
