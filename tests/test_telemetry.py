"""Telemetry ledger: rolling-window QPS, per-model aggregates,
fallback-funnel stats and thumbs attribution."""
import threading
import time

import numpy as np
import pytest

from repro.core.routing import FALLBACK_LADDER
from repro.core.telemetry import RouteEvent, Telemetry


def _ev(ts, model="m0", fallback="", route_s=0.0, analyzer_s=0.0,
        cost=0.0):
    return RouteEvent(ts=ts, model=model, task_type="chat",
                      domain="general", complexity=0.5,
                      fallback=fallback, analyzer_s=analyzer_s,
                      route_s=route_s, sim_cost=cost)


def test_qps_rolling_window():
    tel = Telemetry(window_s=10.0)
    base = 1000.0
    for i in range(40):                  # 4 events/s for 10s
        tel.record(_ev(base + i * 0.25))
    # window (now - 10, now]: 39 of 40 events (ts == now-10 excluded)
    assert tel.qps(now=base + 10.0) == pytest.approx(3.9)
    # events age out of the window: ts in (1005, 1009.75] -> 19 events
    assert tel.qps(now=base + 15.0) == pytest.approx(1.9)
    assert tel.qps(now=base + 100.0) == 0.0


def test_qps_empty():
    assert Telemetry().qps() == 0.0


def test_per_model_aggregates():
    tel = Telemetry()
    for _ in range(3):
        tel.record(_ev(1.0, "a", route_s=0.01, cost=2.0))
    tel.record(_ev(1.0, "b", fallback="generalist", route_s=0.02,
                   cost=5.0))
    tel.attach_thumbs("a", True)
    tel.attach_thumbs("a", False)
    agg = tel.per_model()
    assert agg["a"]["requests"] == 3
    assert agg["a"]["cost"] == pytest.approx(6.0)
    assert agg["a"]["route_s"] == pytest.approx(0.03)
    assert agg["a"]["fallback_rate"] == 0.0
    assert agg["a"]["thumbs_up"] == 1 and agg["a"]["thumbs_down"] == 1
    assert agg["a"]["satisfaction"] == pytest.approx(0.5)
    assert agg["b"]["fallback_rate"] == 1.0
    assert agg["b"]["satisfaction"] is None


def test_attach_thumbs_targets_latest_unrated():
    tel = Telemetry()
    tel.record(_ev(1.0, "a"))
    tel.record(_ev(2.0, "a"))
    tel.attach_thumbs("a", False)
    with tel._lock:
        assert tel._events[0].thumbs is None
        assert tel._events[1].thumbs is False


def test_fallback_funnel_counts_ladder_stages():
    tel = Telemetry()
    mix = {"": 5, "widened-knn": 2, "generalist": 3, "any": 1}
    for kind, n in mix.items():
        assert kind in FALLBACK_LADDER
        for _ in range(n):
            tel.record(_ev(1.0, fallback=kind))
    assert tel.fallback_funnel() == mix
    assert tel.fallback_rate() == pytest.approx(6 / 11)
    s = tel.summary()
    assert s["fallback_funnel"] == mix
    assert s["events"] == 11


def test_per_model_latency_percentiles():
    """per_model reports the tail, not just means: p50 <= p99 and both
    bracket the per-model distribution."""
    tel = Telemetry()
    for i in range(200):
        tel.record(_ev(1.0, "a", route_s=(i + 1) / 1000.0))
    tel.record(_ev(1.0, "b", route_s=0.5))
    agg = tel.per_model()
    a = agg["a"]
    assert a["latency_p50_s"] <= a["latency_p99_s"]
    assert a["latency_p50_s"] == pytest.approx(0.1005, rel=0.01)
    assert a["latency_p99_s"] >= 0.19
    # single-event model: both percentiles collapse to the one sample
    assert agg["b"]["latency_p50_s"] == agg["b"]["latency_p99_s"] == 0.5
    for m in agg.values():
        assert m["latency_p50_s"] <= m["latency_p99_s"]


def test_admission_funnel():
    tel = Telemetry()
    assert tel.admission_funnel() == {}
    tel.record_admission("admitted", count=5)
    tel.record_admission("rerouted")
    tel.record_admission("shed", count=2)
    tel.record_admission("rerouted")
    assert tel.admission_funnel() == {"admitted": 5, "rerouted": 2,
                                      "shed": 2}
    assert tel.summary()["admission_funnel"]["shed"] == 2


def test_percentiles_zero_and_one_sample_edges():
    """Percentile views on empty / single-sample ledgers: no NaNs, no
    crashes, p50 == p99 on one sample."""
    tel = Telemetry()
    assert tel.per_model() == {}                 # 0 events: empty, no error
    assert tel.latency_percentiles() == {"p50": 0.0, "p90": 0.0,
                                         "p99": 0.0}
    tel.record(_ev(1.0, "solo", route_s=0.07, analyzer_s=0.03))
    agg = tel.per_model()                        # 1 sample: collapse
    assert agg["solo"]["latency_p50_s"] == pytest.approx(0.1)
    assert agg["solo"]["latency_p99_s"] == pytest.approx(0.1)
    p = tel.latency_percentiles()
    assert p["p50"] == p["p99"] == pytest.approx(0.1)


def test_engine_summary_percentile_edges():
    """ServingEngine.summary per-model p50/p99 with 0 and 1 served
    requests (0 -> {} summary; 1 -> collapsed percentiles)."""
    from repro.core.orchestrator import OptiRoute
    from repro.serving.engine import Request, ServingEngine
    from tests.test_routing_batch import StubAnalyzer, random_catalog
    eng = ServingEngine(OptiRoute(random_catalog(6, seed=2),
                                  StubAnalyzer(), telemetry=Telemetry()))
    assert eng.summary() == {}                   # empty engine
    out = eng.submit([Request(text="q", prefs="balanced", id=0)])
    s = eng.summary()
    stats = s["latency"][out[0].model]
    assert stats["p50_s"] == stats["p99_s"]      # one sample collapses
    assert s["cache_hits"] == 0


def test_funnel_key_stability_across_empty_engines():
    """Funnels on empty/fresh engines: admission_funnel is {} until an
    outcome lands (and only ever grows ADMISSION_KINDS keys);
    cache_funnel ALWAYS exposes the full stable CACHE_KINDS key set,
    zeroed, so dashboards can key in without existence checks."""
    from repro.cache import CACHE_KINDS
    from repro.serving.load import ADMISSION_KINDS
    for tel in (Telemetry(), Telemetry()):       # any fresh instance
        assert tel.admission_funnel() == {}
        assert list(tel.cache_funnel()) == list(CACHE_KINDS)
        assert all(v == 0 for v in tel.cache_funnel().values())
        s = tel.summary()
        assert list(s["cache_funnel"]) == list(CACHE_KINDS)
        assert s["admission_funnel"] == {}
    tel = Telemetry()
    tel.record_admission("shed")
    tel.record_cache("hit", count=3)
    assert set(tel.admission_funnel()) <= set(ADMISSION_KINDS)
    funnel = tel.cache_funnel()
    assert list(funnel) == list(CACHE_KINDS)     # keys stable after writes
    assert funnel["hit"] == 3 and funnel["miss"] == 0


def test_latency_percentiles():
    tel = Telemetry()
    for i in range(100):
        tel.record(_ev(1.0, route_s=(i + 1) / 1000.0, analyzer_s=0.0))
    p = tel.latency_percentiles()
    assert p["p50"] == pytest.approx(0.0505, rel=0.01)
    assert p["p99"] > p["p90"] > p["p50"]
    assert Telemetry().latency_percentiles() == {
        "p50": 0.0, "p90": 0.0, "p99": 0.0}


def test_concurrent_records():
    tel = Telemetry()
    errs = []

    def worker(i):
        try:
            for j in range(300):
                tel.record(_ev(float(j), f"m{i % 3}",
                               fallback="any" if j % 7 == 0 else ""))
        except Exception as e:                 # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    s = tel.summary()
    assert s["events"] == 1800
    assert sum(s["fallback_funnel"].values()) == 1800
    assert sum(a["requests"] for a in s["per_model"].values()) == 1800


def test_soak_memory_stays_bounded():
    """100k events: raw retention and the QPS deque stay at their caps
    while every reported aggregate still covers ALL events — the ledger
    never trades correctness for its fixed memory footprint."""
    tel = Telemetry(max_events=1024)
    n = 100_000
    for i in range(n):
        tel.record(_ev(float(i) / 100.0, f"m{i % 4}",
                       fallback="any" if i % 10 == 0 else "",
                       route_s=0.001 * (i % 50 + 1), cost=0.5))
    assert len(tel._events) <= 1024              # ring capped
    assert len(tel._qps_ts) <= tel._qps_ts.maxlen
    s = tel.summary()
    assert s["events"] == n                      # aggregates see all
    assert sum(s["fallback_funnel"].values()) == n
    assert s["fallback_funnel"]["any"] == n // 10
    assert sum(a["requests"] for a in s["per_model"].values()) == n
    assert s["latency_totals"]["count"] == n
    assert s["cost_totals"]["sum"] == pytest.approx(0.5 * n)
    p = s["latency_percentiles"]
    assert 0.001 <= p["p50"] <= p["p99"] <= 0.051


def test_attach_thumbs_scales_with_feedback_not_history():
    """Thumbs attach via per-model pending stacks: rating against a
    100k-event history costs the same as against a tiny one (the old
    implementation re-scanned the whole event list per attach)."""
    def attach_cost(history: int, ratings: int) -> float:
        tel = Telemetry()
        for i in range(history):
            tel.record(_ev(float(i), "hot"))
        t0 = time.perf_counter()
        for _ in range(ratings):
            tel.record(_ev(0.0, "hot"))
            tel.attach_thumbs("hot", True)
        return time.perf_counter() - t0

    small = attach_cost(10, 300)
    big = attach_cost(100_000, 300)
    # O(n)-per-attach would make `big` ~10000x `small`; allow wide
    # CI noise but catch any history-proportional regression
    assert big <= max(small * 20, 0.05), (small, big)
    # correctness on a long history: still targets the most recent
    # unrated event for the model
    tel = Telemetry()
    for i in range(5000):
        tel.record(_ev(float(i), "a"))
    tel.record(_ev(9999.0, "a"))
    tel.attach_thumbs("a", False)
    with tel._lock:
        assert tel._events[-1].thumbs is False
        assert tel._events[-2].thumbs is None
    assert tel.per_model()["a"]["thumbs_down"] == 1
    tel.attach_thumbs("missing-model", True)     # no pending: no-op


def test_summary_is_one_consistent_snapshot():
    """summary() under concurrent record(): every snapshot's funnels,
    per-model counts and histogram totals agree with its own event
    count — a half-applied record can never leak into a view."""
    tel = Telemetry()
    stop = threading.Event()
    errs = []

    def writer(k):
        try:
            j = 0
            while not stop.is_set():
                tel.record(_ev(float(j), f"m{k}",
                               fallback="any" if j % 5 == 0 else "",
                               route_s=0.002, cost=1.0))
                j += 1
        except Exception as e:                 # noqa: BLE001
            errs.append(e)

    def reader():
        try:
            for _ in range(300):
                s = tel.summary()
                n = s["events"]
                assert sum(s["fallback_funnel"].values()) == n
                assert sum(a["requests"]
                           for a in s["per_model"].values()) == n
                assert s["latency_totals"]["count"] == n
                assert s["cost_totals"]["count"] == n
                expect_fb = sum(v for k_, v in s["fallback_funnel"].items()
                                if k_)
                assert s["fallback_rate"] * max(n, 1) == \
                    pytest.approx(expect_fb)
        except Exception as e:                 # noqa: BLE001
            errs.append(e)

    writers = [threading.Thread(target=writer, args=(k,))
               for k in range(3)]
    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in writers + readers:
        t.start()
    for t in readers:
        t.join()
    stop.set()
    for t in writers:
        t.join()
    assert not errs, errs


def test_sharding_counters():
    t = Telemetry()
    assert t.sharding_stats() == {"silent_replications": 0}
    t.record_sharding(silent_replications=3)
    t.record_sharding(silent_replications=1)
    assert t.sharding_stats()["silent_replications"] == 4
    assert t.summary()["sharding"]["silent_replications"] == 4
