"""FeedbackStore persistence (save/load JSON round-trip) and
thread-safety under concurrent updates."""
import json
import threading

import numpy as np
import pytest

from repro.core.feedback import FeedbackStore, cluster_of
from repro.core.preferences import DOMAINS, TASK_TYPES, TaskSignature

MODELS = [f"m{i}" for i in range(6)]


def _populated_store(n_events: int = 80, seed: int = 0) -> FeedbackStore:
    fb = FeedbackStore()
    rng = np.random.default_rng(seed)
    for _ in range(n_events):
        sig = TaskSignature(task_type=str(rng.choice(TASK_TYPES)),
                            domain=str(rng.choice(DOMAINS)),
                            complexity=float(rng.random()))
        fb.record(sig, str(rng.choice(MODELS)), bool(rng.random() < 0.6))
    return fb


def _sigs(n: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    return [TaskSignature(task_type=str(rng.choice(TASK_TYPES)),
                          domain=str(rng.choice(DOMAINS)),
                          complexity=float(rng.random())) for _ in range(n)]


def test_save_load_round_trip(tmp_path):
    fb = _populated_store()
    path = str(tmp_path / "fb.json")
    fb.save(path)
    fresh = FeedbackStore()
    fresh.load(path)
    sigs = _sigs(30)
    np.testing.assert_array_equal(fresh.bias_batch(sigs, MODELS),
                                  fb.bias_batch(sigs, MODELS))
    assert fresh._count == fb._count
    # EMA continues from the restored bias identically
    sig = sigs[0]
    assert fresh.record(sig, "m0", True) == fb.record(sig, "m0", True)


def test_load_replaces_existing_state(tmp_path):
    """Loading a snapshot must not splice stale in-memory entries in."""
    fb = _populated_store(seed=3)
    path = str(tmp_path / "fb.json")
    fb.save(path)
    dirty = _populated_store(seed=4)      # different clusters/biases
    dirty.load(path)
    sigs = _sigs(30)
    np.testing.assert_array_equal(dirty.bias_batch(sigs, MODELS),
                                  fb.bias_batch(sigs, MODELS))


def test_save_is_atomic_no_partial_file(tmp_path):
    """save overwrites via rename: the target is always valid JSON and
    no temp droppings stay behind."""
    fb = _populated_store()
    path = tmp_path / "fb.json"
    fb.save(str(path))
    fb.record(TaskSignature(), "m0", True)
    fb.save(str(path))                    # overwrite in place
    data = json.loads(path.read_text())
    assert isinstance(data, list) and data
    assert [p.name for p in tmp_path.iterdir()] == ["fb.json"]


def test_cluster_keys_survive_json(tmp_path):
    """Cluster tuples (str, str, int) round-trip exactly."""
    fb = FeedbackStore()
    sig = TaskSignature(task_type="code", domain="software",
                        complexity=0.9)
    fb.record(sig, "m1", True)
    path = str(tmp_path / "fb.json")
    fb.save(path)
    fresh = FeedbackStore()
    fresh.load(path)
    assert (cluster_of(sig), "m1") in fresh._bias


def test_concurrent_records_are_all_counted():
    fb = FeedbackStore()
    sig_pool = _sigs(5, seed=9)
    n_threads, per_thread = 8, 200
    errs = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(per_thread):
                b = fb.record(sig_pool[int(rng.integers(5))],
                              str(rng.choice(MODELS)),
                              bool(rng.random() < 0.5))
                assert -1.0 <= b <= 1.0
        except Exception as e:                     # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(fb.events()) == n_threads * per_thread
    assert sum(fb._count.values()) == n_threads * per_thread


def test_concurrent_save_load_record(tmp_path):
    """Persistence racing live updates never corrupts the file."""
    fb = _populated_store()
    path = str(tmp_path / "fb.json")
    fb.save(path)
    stop = threading.Event()
    errs = []

    def recorder():
        rng = np.random.default_rng(1)
        while not stop.is_set():
            fb.record(TaskSignature(), str(rng.choice(MODELS)), True)

    def saver():
        try:
            for _ in range(50):
                fb.save(path)
                with open(path) as f:
                    json.load(f)              # always complete JSON
        except Exception as e:                 # noqa: BLE001
            errs.append(e)

    rec = threading.Thread(target=recorder)
    sv = threading.Thread(target=saver)
    rec.start()
    sv.start()
    sv.join()
    stop.set()
    rec.join()
    assert not errs
    fresh = FeedbackStore()
    fresh.load(path)                           # final file loads clean
