"""Tests: flash-decode kernel mode, telemetry ledger, checkpoint-backed
runners."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.preferences import TaskSignature
from repro.core.telemetry import RouteEvent, Telemetry
from repro.kernels import ops as K
from repro.serving.runner import ModelRunner

RNG = np.random.default_rng(1)


# ----------------------------------------------------------------------
# flash-decode (per-sequence valid lengths)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("C,blk", [(64, 32), (100, 32), (33, 16)])
def test_flash_decode_matches_masked_reference(C, blk):
    B, Hq, Hkv, hd = 3, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, 1, Hq, hd)), jnp.float32)
    kc = jnp.asarray(RNG.standard_normal((B, C, Hkv, hd)), jnp.float32)
    vc = jnp.asarray(RNG.standard_normal((B, C, Hkv, hd)), jnp.float32)
    pos = jnp.asarray([0, C // 2, C - 1], jnp.int32)
    out = K.flash_decode(q, kc, vc, pos, blk_k=blk)
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("blkgd,bmkd->bkglm", qf, kc) / math.sqrt(hd)
    valid = jnp.arange(C)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
    want = jnp.einsum("bkglm,bmkd->blkgd", jax.nn.softmax(s, -1),
                      vc).reshape(B, 1, Hq, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_default_valid_unchanged():
    """Without kv_valid the kernel behaves exactly as before."""
    from repro.kernels import ref as R
    B, L, H, hd = 2, 70, 2, 32
    q = jnp.asarray(RNG.standard_normal((B, L, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, L, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, L, H, hd)), jnp.float32)
    o1 = K.flash_attention(q, k, v, blk_q=32, blk_k=32)
    o2 = R.mha_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# telemetry
# ----------------------------------------------------------------------

def _event(model, fallback="", cost=1.0, ts=0.0):
    return RouteEvent(ts=ts, model=model, task_type="chat",
                      domain="general", complexity=0.5, fallback=fallback,
                      analyzer_s=0.001, route_s=0.0002, sim_cost=cost)


def test_telemetry_aggregates():
    t = Telemetry(window_s=10)
    t.record(_event("a", ts=100.0))
    t.record(_event("a", fallback="generalist", ts=101.0))
    t.record(_event("b", cost=5.0, ts=102.0))
    t.attach_thumbs("a", True)
    t.attach_thumbs("b", False)
    agg = t.per_model()
    assert agg["a"]["requests"] == 2
    assert agg["a"]["fallback_rate"] == pytest.approx(0.5)
    assert agg["a"]["satisfaction"] == 1.0
    assert agg["b"]["satisfaction"] == 0.0
    assert t.fallback_rate() == pytest.approx(1 / 3)
    assert t.qps(now=105.0) == pytest.approx(3 / 10)
    assert t.qps(now=200.0) == 0.0
    p = t.latency_percentiles()
    assert p["p50"] == pytest.approx(0.0012, rel=1e-3)


def test_telemetry_wired_into_orchestrator():
    from repro.core.analyzer import AnalyzerConfig, TaskAnalyzer
    from repro.core.orchestrator import OptiRoute
    from repro.serving.catalog import build_catalog
    mres = build_catalog(archs=["llama3.2-1b", "mamba2-1.3b"])
    an = TaskAnalyzer(AnalyzerConfig(d_model=32, n_layers=1, d_ff=64,
                                     max_len=32))
    tel = Telemetry()
    router = OptiRoute(mres, an, telemetry=tel)
    rq = router.route("hello can you help me with travel", "balanced")
    router.give_feedback(rq, thumbs_up=True)
    s = tel.summary()
    assert s["events"] == 1
    assert rq.decision.model in s["per_model"]


# ----------------------------------------------------------------------
# checkpoint-backed runners
# ----------------------------------------------------------------------

def test_runner_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke("llama3.2-1b")
    r1 = ModelRunner(cfg, seed=3)
    p = str(tmp_path / "m.npz")
    r1.save_checkpoint(p, {"note": "test"})
    r2 = ModelRunner.from_checkpoint(cfg, p)
    assert r2.meta["config"] == cfg.name
    toks = (np.arange(8, dtype=np.int32) + 2)[None]
    g1 = r1.generate(toks, max_new=2)
    g2 = r2.generate(toks, max_new=2)
    np.testing.assert_array_equal(g1.tokens, g2.tokens)
    np.testing.assert_allclose(g1.logits_last, g2.logits_last,
                               rtol=1e-5, atol=1e-5)
