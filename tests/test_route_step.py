"""Fused single-dispatch routing step: kernel/oracle parity, staged
differential, shape buckets (zero steady-state recompiles), top-k merge
rewrite, and the array-first RoutingBatch laziness contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adaptive.bandit import LinearBandit
from repro.core.feedback import FeedbackStore
from repro.core.preferences import (DOMAINS, METRICS, TASK_TYPES,
                                    TaskSignature, UserPreferences)
from repro.core.routing import RoutingEngine, _topk_two_level
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.kernels.router_topk import merge_topk
from repro.serving.load import LoadTracker
from tests.test_routing_batch import random_catalog, random_queries

RNG = np.random.default_rng(7)


# ----------------------------------------------------------------------
# the rewritten top-k merge (shared by router_topk and route_step)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 3, 5, 8, 13])
def test_merge_topk_matches_full_sort(k):
    """Merging two sorted carries == top-k of their concatenation,
    including non-power-of-two k and duplicate values."""
    rng = np.random.default_rng(k)
    for _ in range(5):
        a = -np.sort(-rng.integers(0, 6, (4, k)).astype(np.float32))
        b = -np.sort(-rng.integers(0, 6, (4, k)).astype(np.float32))
        ai = rng.integers(0, 100, (4, k)).astype(np.int32)
        bi = rng.integers(100, 200, (4, k)).astype(np.int32)
        v, i = merge_topk(jnp.asarray(a), jnp.asarray(ai),
                          jnp.asarray(b), jnp.asarray(bi))
        want = -np.sort(-np.concatenate([a, b], axis=1), axis=1)[:, :k]
        np.testing.assert_array_equal(np.asarray(v), want)
        # every returned index carries its own value (no element was
        # duplicated or dropped through the exchanges)
        both_v = np.concatenate([a, b], axis=1)
        both_i = np.concatenate([ai, bi], axis=1)
        for q in range(4):
            pairs = list(zip(both_i[q].tolist(), both_v[q].tolist()))
            for iv, vv in zip(np.asarray(i)[q], np.asarray(v)[q]):
                assert (int(iv), float(vv)) in pairs
                pairs.remove((int(iv), float(vv)))


def test_merge_topk_with_neginf_padding():
    a = np.array([[3.0, 1.0, -np.inf]], np.float32)
    b = np.array([[2.0, -np.inf, -np.inf]], np.float32)
    ai = np.array([[0, 1, -1]], np.int32)
    bi = np.array([[9, -1, -1]], np.int32)
    v, i = merge_topk(jnp.asarray(a), jnp.asarray(ai),
                      jnp.asarray(b), jnp.asarray(bi))
    np.testing.assert_array_equal(np.asarray(v)[0], [3.0, 2.0, 1.0])
    np.testing.assert_array_equal(np.asarray(i)[0], [0, 9, 1])


# ----------------------------------------------------------------------
# ops.route_step vs the pure-jnp oracle
# ----------------------------------------------------------------------

def _random_problem(B, N, seed, *, with_fb=True, with_ad=True,
                    with_load=True):
    rng = np.random.default_rng(seed)
    M = len(METRICS)
    nt, nd = len(TASK_TYPES), len(DOMAINS)
    emb = rng.random((N, M)).astype(np.float32)
    tt = np.vstack([rng.random((nt, N)) < 0.4, np.ones((1, N), bool)])
    dm = np.vstack([rng.random((nd, N)) < 0.5, np.ones((1, N), bool)])
    gmask = rng.random(N) < 0.2
    T = rng.random((B, M)).astype(np.float32)
    W = rng.random((B, M)).astype(np.float32)
    ti = rng.integers(0, nt + 1, B).astype(np.int32)
    di = rng.integers(0, nd + 1, B).astype(np.int32)
    kw = {}
    if with_fb:
        kw["fb"] = (rng.random((B, N)) - 0.5).astype(np.float32)
        kw["fb_weight"] = 0.5
    if with_ad:
        Dc = M + 1
        kw["theta"] = rng.standard_normal((N, Dc)).astype(np.float32) * 0.1
        L = rng.standard_normal((N, Dc, Dc)).astype(np.float32) * 0.05
        kw["ainv"] = np.einsum("nde,nfe->ndf", L, L) \
            + 0.5 * np.eye(Dc, dtype=np.float32)
        kw["alpha"] = 0.8
        kw["ad_weight"] = 0.6
    if with_load:
        kw["lpen"] = (rng.random(N) * 0.3).astype(np.float32)
    return (emb, tt, dm, gmask, T, W, ti, di), kw


def _ref_kwargs(kw):
    return {k2: (jnp.asarray(v) if isinstance(v, np.ndarray) else v)
            for k2, v in kw.items()}


@pytest.mark.parametrize("B,N,k,flags", [
    (1, 5, 3, (True, True, True)),      # B=1, tiny catalog
    (9, 130, 8, (True, False, True)),   # N just past one lane block
    (16, 257, 4, (False, True, False)),  # off-by-one catalog
    (33, 96, 2, (False, False, False)),  # blend-free, non-pow2 batch
])
def test_route_step_matches_ref(B, N, k, flags):
    args, kw = _random_problem(B, N, seed=B * 1000 + N,
                               with_fb=flags[0], with_ad=flags[1],
                               with_load=flags[2])
    r = min(max(5, k), N)
    got = K.route_step(*args, k=k, r=r, **kw)
    want = R.route_step(*(jnp.asarray(a) for a in args), k, r,
                        **_ref_kwargs(kw))
    for key in ("model_idx", "stage", "cand_idx", "n_filtered",
                "n_candidates"):
        np.testing.assert_array_equal(got[key], np.asarray(want[key]),
                                      err_msg=key)
    for key in ("score", "similarity", "cand_score"):
        np.testing.assert_allclose(got[key], np.asarray(want[key]),
                                   rtol=2e-5, atol=2e-5, err_msg=key)


def test_route_step_pallas_path_matches_jnp():
    """use_pallas=True (interpret-mode kernel kNN inside the fused
    program) is decision-identical to the jnp top-k path."""
    args, kw = _random_problem(11, 150, seed=3)
    got_j = K.route_step(*args, k=6, r=6, **kw, use_pallas=False)
    got_p = K.route_step(*args, k=6, r=6, **kw, use_pallas=True)
    np.testing.assert_array_equal(got_j["model_idx"], got_p["model_idx"])
    np.testing.assert_array_equal(got_j["stage"], got_p["stage"])
    np.testing.assert_allclose(got_j["score"], got_p["score"],
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# fused route_many vs the staged numpy reference path
# ----------------------------------------------------------------------

def _assert_decisions_match(fused, staged, *, tol=1e-4):
    assert len(fused) == len(staged)
    for a, b in zip(fused, staged):
        assert a.fallback_kind == b.fallback_kind
        assert a.used_fallback == b.used_fallback
        assert a.stage_sizes == b.stage_sizes
        if a.model == b.model:
            assert a.score == pytest.approx(b.score, abs=tol)
        else:       # fp tie at the top: the picks must tie in score
            assert a.score == pytest.approx(b.score, abs=tol)
        assert a.similarity == pytest.approx(b.similarity, abs=tol)
        assert len(a.candidates) == len(b.candidates)
        for (_, sa), (_, sb) in zip(a.candidates, b.candidates):
            assert sa == pytest.approx(sb, abs=tol)


def _full_engine(n=64, seed=0, *, with_fb=True, with_ad=True,
                 with_load=True):
    mres = random_catalog(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    fb = None
    if with_fb:
        fb = FeedbackStore()
        for _ in range(50):
            fb.record(TaskSignature(
                task_type=str(rng.choice(TASK_TYPES)),
                domain=str(rng.choice(DOMAINS)),
                complexity=float(rng.random())),
                f"m{int(rng.integers(n))}", bool(rng.random() < 0.5))
    ad = None
    if with_ad:
        ad = LinearBandit(n, seed=seed)
        for _ in range(4):
            X = rng.random((16, len(METRICS))).astype(np.float32)
            ad.update(X, rng.integers(0, n, 16),
                      rng.random(16).astype(np.float32))
    load = None
    if with_load:
        load = LoadTracker(n)
        for j in rng.integers(0, n, 3 * n):
            load.admit(int(j))
    return RoutingEngine(mres, fb, knn_k=8,
                         adaptive=ad, adaptive_weight=0.7 if ad else 0.0,
                         load=load, load_weight=0.5 if load else 0.0)


@pytest.mark.parametrize("b", [1, 13])
@pytest.mark.parametrize("flags", [(False, False, False),
                                   (True, True, True)])
def test_fused_matches_staged_full_blend(b, flags):
    eng = _full_engine(64, seed=b, with_fb=flags[0], with_ad=flags[1],
                       with_load=flags[2])
    prefs, sigs = random_queries(b, seed=b + 5)
    fused = eng.route_many_batch(prefs, sigs).decisions()
    staged = eng.route_many_staged(prefs, sigs)
    _assert_decisions_match(fused, staged)


def test_fused_matches_staged_fallback_heavy():
    """A catalog with narrow coverage forces every fallback rung."""
    from tests.conftest import make_entry
    from repro.core.mres import MRES
    m = MRES()
    m.register(make_entry("gen", task_types=("chat",), generalist=True))
    m.register(make_entry("coder", task_types=("code",),
                          domains=("software",)))
    m.register(make_entry("fin", task_types=("classification",),
                          domains=("finance",)))
    eng = RoutingEngine(m, knn_k=4)
    sigs = [TaskSignature(task_type="vqa", domain="healthcare"),
            TaskSignature(task_type="code", domain="software"),
            TaskSignature(task_type="code", domain="legal"),
            TaskSignature(task_type="chat", domain="general",
                          confidence=0.1)]
    fused = eng.route_many_batch("balanced", sigs).decisions()
    staged = eng.route_many_staged("balanced", sigs)
    _assert_decisions_match(fused, staged)
    assert fused[0].used_fallback


def test_thompson_policy_falls_back_to_staged():
    """A Thompson bandit samples host RNG per score — the engine must
    refuse to fuse and stay on the staged path."""
    mres = random_catalog(16, seed=2)
    ad = LinearBandit(16, policy="thompson", seed=0)
    eng = RoutingEngine(mres, adaptive=ad, adaptive_weight=0.5)
    assert not eng._fused_ok()
    prefs, sigs = random_queries(4, seed=2)
    out = eng.route_many(prefs, sigs)          # staged, but functional
    assert len(out) == 4


# ----------------------------------------------------------------------
# shape buckets: zero steady-state recompiles, one dispatch per batch
# ----------------------------------------------------------------------

def test_zero_recompiles_across_mixed_batch_sizes():
    mres = random_catalog(48, seed=9)
    eng = RoutingEngine(mres, knn_k=8)
    # warm up every power-of-two bucket the replay will touch
    for b in (1, 9, 17, 33):
        prefs, sigs = random_queries(b, seed=b)
        eng.route_many(prefs, sigs)
    warm = K.route_step_stats()
    replay = (3, 1, 12, 30, 8, 21, 5, 16, 2)
    for i, b in enumerate(replay):
        prefs, sigs = random_queries(b, seed=100 + i)
        eng.route_many(prefs, sigs)
    stats = K.route_step_stats()
    assert stats["route_step_compiles"] == warm["route_step_compiles"], \
        "mixed batch sizes recompiled after warmup"
    # exactly ONE device dispatch per routed batch
    assert stats["route_step_dispatches"] \
        == warm["route_step_dispatches"] + len(replay)


def test_empty_batch_on_empty_catalog_matches_staged():
    """route_many([], []) returns [] even on an EMPTY catalog — the
    fused wrapper must keep the staged path's check order (B == 0
    before the empty-catalog raise)."""
    from repro.core.mres import MRES
    eng = RoutingEngine(MRES())
    assert eng.route_many([], []) == []
    assert eng.route_many_staged([], []) == []
    # a NON-empty batch against an empty catalog raises (RuntimeError
    # from the catalog check, or ValueError from the empty-catalog
    # normalize inside snapshot() — the pre-existing behavior)
    with pytest.raises((RuntimeError, ValueError)):
        eng.route_many([UserPreferences()], [TaskSignature()])


def test_catalog_growth_within_bucket_does_not_recompile():
    """Registering models within one 128-padded capacity bucket must
    reuse the cached executable (liveness lives in the mask table and
    traced arrays, not in the jit's static key)."""
    mres = random_catalog(40, seed=11)
    eng = RoutingEngine(mres, knn_k=8)
    prefs, sigs = random_queries(6, seed=11)
    eng.route_many(prefs, sigs)                    # warm 40-model state
    from tests.conftest import make_entry
    mres.register(make_entry("grown", task_types=("chat",),
                             generalist=True))     # 41 <= 128 bucket
    warm = K.route_step_stats()
    out = eng.route_many(prefs, sigs)
    assert len(out) == 6
    stats = K.route_step_stats()
    assert stats["route_step_compiles"] == warm["route_step_compiles"]


def test_bucket_helpers():
    assert [K.q_bucket(b) for b in (1, 7, 8, 9, 64, 65)] == \
        [8, 8, 8, 16, 64, 128]
    assert [K.n_bucket(n) for n in (1, 128, 129, 4096)] == \
        [128, 128, 256, 4096]


def test_cache_lookup_bucketed_zero_recompiles():
    from repro.cache.semantic import SemanticCache
    cache = SemanticCache(capacity=64, use_kernel=True, kernel_min_n=1,
                          threshold=0.9)
    prefs = UserPreferences()
    texts = [f"query number {i}" for i in range(8)]
    keys = cache.keys_for([prefs] * 8, texts)
    fps = cache.fingerprints([prefs] * 8)
    for i in range(8):
        cache.put(keys[i], int(fps[i]), "m0", np.arange(4), 0.9)
    for b in (1, 5, 8):                               # warm the buckets
        cache.lookup(keys[:b], fps[:b])
    warm = K.route_step_stats()
    for b in (2, 7, 3, 6, 1, 8):
        hit, slot, sim = cache.lookup(keys[:b], fps[:b])
        assert hit.all()
    stats = K.route_step_stats()
    assert stats["topk_compiles"] == warm["topk_compiles"]
    assert stats["topk_dispatches"] == warm["topk_dispatches"] + 6


# ----------------------------------------------------------------------
# RoutingBatch: array-first contract + lazy materialization
# ----------------------------------------------------------------------

def test_routing_batch_lazy_materialization():
    eng = RoutingEngine(random_catalog(32, seed=4), knn_k=8)
    prefs, sigs = random_queries(6, seed=4)
    batch = eng.route_many_batch(prefs, sigs)
    assert len(batch) == 6
    assert all(d is None for d in batch._cache), \
        "decisions materialized eagerly"
    models = batch.models()               # array-only view
    assert all(d is None for d in batch._cache)
    d3 = batch.decision(3)
    assert d3.model == models[3]
    assert batch._cache[3] is d3 and batch._cache[0] is None
    assert batch.decision(3) is d3        # memoized
    # full materialization equals the object API
    assert [d.model for d in batch.decisions()] == models


def test_routed_query_lazy_decision():
    from repro.core.orchestrator import OptiRoute
    from tests.test_routing_batch import StubAnalyzer
    router = OptiRoute(random_catalog(24, seed=6), StubAnalyzer())
    rqs = router.route_all([f"q{i}" for i in range(5)], "balanced")
    assert all(rq._decision is None for rq in rqs), \
        "route_all materialized decisions on the hot path"
    assert rqs[0].model in {e.name for e in router.mres.entries}
    assert rqs[0].fallback_kind == ""
    assert rqs[0]._decision is None       # cheap accessors stay lazy
    d = rqs[0].decision
    assert d.model == rqs[0].model        # materializes on demand


# ----------------------------------------------------------------------
# satellite regression: _topk_two_level must not mutate its input
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 300])   # chunk-aligned and not
def test_topk_two_level_does_not_mutate_input(n):
    rng = np.random.default_rng(n)
    ms = rng.random((5, n)).astype(np.float32)
    before = ms.copy()
    vals, idx = _topk_two_level(ms, k=4)
    np.testing.assert_array_equal(ms, before)
    # and it still returns the right answer
    want = -np.sort(-ms, axis=1)[:, :4]
    np.testing.assert_allclose(vals, want, atol=0)
