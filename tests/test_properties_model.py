"""Hypothesis property tests at the model/kernel layer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.configs.base import ModelConfig
from repro.models import layers as L

SLOW = settings(max_examples=12, deadline=None,
                suppress_health_check=[HealthCheck.too_slow,
                                       HealthCheck.data_too_large])


def _cfg(window, blk):
    return ModelConfig(
        name="prop", arch_type="dense", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=32, vocab_size=128, sliding_window=window,
        attn_impl="blocked", attn_block_q=blk,
        param_dtype="float32", compute_dtype="float32").validate()


@SLOW
@given(L_=st.integers(3, 80), window=st.sampled_from([0, 5, 16, 64]),
       blk=st.sampled_from([4, 16, 32]), seed=st.integers(0, 4),
       causal=st.booleans())
def test_blocked_attention_equals_naive_mask(L_, window, blk, seed, causal):
    """blocked(q,k,v) == masked-softmax reference for any (L, W, blk)."""
    if not causal:
        window = 0
    cfg = _cfg(window, blk)
    rng = np.random.default_rng(seed)
    p = L.init_attention(jax.random.PRNGKey(seed), cfg)
    x = jnp.asarray(rng.standard_normal((2, L_, 32)) * 0.3, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(L_, dtype=jnp.int32)[None], (2, L_))
    out_b, (k, v) = L.attention_blocked(p, cfg, x, pos, causal=causal)
    # reference: explicit masked softmax
    q = L._split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    s = L.gqa_scores(q, k).astype(jnp.float32)
    iq = jnp.arange(L_)[:, None]
    ik = jnp.arange(L_)[None, :]
    mask = jnp.ones((L_, L_), bool)
    if causal:
        mask &= ik <= iq
        if window:
            mask &= ik > iq - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    want = L.gqa_values(pr, v).reshape(2, L_, cfg.q_dim) @ p["wo"]
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@SLOW
@given(st.integers(0, 6), st.integers(1, 6), st.integers(4, 64))
def test_kv_quantization_roundtrip_bounded(seed, heads, hd):
    """int8 KV quantize/dequant relative error bounded by 1/127 per row."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, heads, hd)) *
                    rng.uniform(0.01, 50), jnp.float32)
    q, s = L.quantize_kv(x)
    back = q.astype(jnp.float32) * s
    rowmax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= rowmax / 127.0 + 1e-6).all()
    assert q.dtype == jnp.int8


@SLOW
@given(st.integers(2, 40), st.integers(0, 5))
def test_moe_gate_weights_normalized_and_sparse(T, seed):
    """moe_gate output: top-k rows sum to 1, exactly k nonzero."""
    rng = np.random.default_rng(seed)
    E, k = 8, 3
    logits = jnp.asarray(rng.standard_normal((T, E)), jnp.float32)
    gates, aux = L.moe_gate(logits, k)
    g = np.asarray(gates)
    np.testing.assert_allclose(g.sum(-1), 1.0, rtol=1e-4)
    assert ((g > 0).sum(-1) == k).all()
    assert float(aux) >= 1.0 - 1e-3   # load-balance lower bound


@SLOW
@given(st.integers(1, 200), st.integers(0, 3))
def test_prune_never_longer_and_idempotent(n_words, seed):
    from repro.core.analyzer import AnalyzerConfig, prune_text
    cfg = AnalyzerConfig(prune_head=10, prune_tail=5, prune_mid=3)
    text = " ".join(f"w{i}" for i in range(n_words))
    once = prune_text(cfg, text, seed)
    assert len(once.split()) <= max(n_words, 18)
    assert len(once.split()) <= 18 or n_words <= 18
    assert prune_text(cfg, once, seed) == once     # idempotent
