"""Asyncio serving front-end: token buckets, tenant policies, the
clock-agnostic MicroBatcher (aggregation windows + weighted-fair
deficit-round-robin dequeue), the AsyncServingEngine end-to-end path,
and a thread hammer on the synchronous engine's submit."""
import asyncio
import threading
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.mres import MRES
from repro.core.orchestrator import OptiRoute
from repro.core.telemetry import Telemetry
from repro.serving.async_engine import (REJECT_BACKLOG, REJECT_RATE,
                                        AsyncServingEngine, MicroBatcher,
                                        TenantPolicy, TokenBucket)
from repro.serving.engine import Request, ServingEngine
from repro.serving.load import LoadTracker
from tests.conftest import make_entry
from tests.test_routing_batch import StubAnalyzer


class FakeRunner:
    """Zero-weight runner: (B, max_new) token zeros, B * service_s sim
    latency (the engine divides by B -> service_s per request)."""

    cfg = SimpleNamespace(vocab_size=256)

    def __init__(self, service_s=0.001):
        self.service_s = float(service_s)

    def generate(self, toks, max_new=8):
        B = int(np.asarray(toks).shape[0])
        return SimpleNamespace(tokens=np.zeros((B, max_new), np.int32),
                               sim_latency_s=self.service_s * B)


def _engine(tel=None, n=3):
    m = MRES()
    for i in range(n):
        e = make_entry(f"m{i}", accuracy=0.9 - 0.05 * i,
                       latency_ms=50.0 + 10 * i, cost=1.0 + i,
                       generalist=True)
        e.runner = FakeRunner()
        m.register(e)
    tracker = LoadTracker(n, default_service_s=0.01)
    router = OptiRoute(m, StubAnalyzer(), knn_k=n, telemetry=tel,
                       load=tracker, load_weight=1.0)
    return ServingEngine(router), tracker


def _req(i, tenant="acme", **kw):
    return Request(text=f"request {i}", prefs="balanced", id=i,
                   max_new=2, tenant=tenant, **kw)


# ----------------------------------------------------------------------
# token bucket / tenant policy
# ----------------------------------------------------------------------

def test_token_bucket_refill_and_cap():
    tb = TokenBucket(rate=2.0, burst=2.0)
    assert tb.try_take(0.0) and tb.try_take(0.0)
    assert not tb.try_take(0.0)          # bucket empty
    assert tb.try_take(0.5)              # 0.5s * 2/s = 1 token back
    assert not tb.try_take(0.5)
    # a long idle stretch refills to the burst ceiling, not beyond
    assert tb.try_take(100.0) and tb.try_take(100.0)
    assert not tb.try_take(100.0)


def test_tenant_policy_defaults_and_validation():
    assert TenantPolicy().make_bucket() is None       # unlimited
    b = TenantPolicy(rate=3.0).make_bucket()
    assert (b.rate, b.burst) == (3.0, 6.0)            # burst = 2*rate
    assert TenantPolicy(rate=0.2).make_bucket().burst == 1.0
    assert TenantPolicy(rate=5.0, burst=1.0).make_bucket().burst == 1.0
    with pytest.raises(AssertionError):
        TenantPolicy(weight=0.0).validate()
    with pytest.raises(AssertionError):
        TenantPolicy(rate=-1.0).validate()
    with pytest.raises(AssertionError):
        TenantPolicy(max_backlog=0).validate()


# ----------------------------------------------------------------------
# micro-batcher: windows + weighted-fair dequeue (deterministic clock)
# ----------------------------------------------------------------------

def test_microbatcher_window_clock():
    mb = MicroBatcher(max_batch=4, max_wait_s=0.01)
    assert not mb.due(0.0) and mb.next_deadline(0.0) is None
    assert mb.offer("a", "x0", 0.0) == "queued"
    assert not mb.due(0.005)             # window still open
    assert mb.next_deadline(0.005) == pytest.approx(0.01)
    assert mb.due(0.01)                  # oldest item aged out
    # filling the batch makes the window due immediately
    for j in range(3):
        mb.offer("a", f"x{j + 1}", 0.002)
    assert mb.due(0.002)
    assert mb.next_deadline(0.002) == 0.002
    assert mb.take(0.002) == ["x0", "x1", "x2", "x3"]
    assert mb.pending() == 0 and mb.backlog() == {"a": 0}


def test_microbatcher_drr_weight_proportions():
    mb = MicroBatcher(max_batch=16, policies={
        "acme": TenantPolicy(weight=3.0), "globex": TenantPolicy()})
    for j in range(10):
        mb.offer("acme", ("acme", j), 0.0)
        mb.offer("globex", ("globex", j), 0.0)
    out = mb.take(0.0, limit=8)
    by = {"acme": 0, "globex": 0}
    for t, _ in out:
        by[t] += 1
    assert by == {"acme": 6, "globex": 2}    # 3:1 weights
    # FIFO within each tenant
    assert [j for t, j in out if t == "acme"] == list(range(6))


def test_microbatcher_deficit_resets_on_empty_queue():
    mb = MicroBatcher(max_batch=8,
                      policies={"slow": TenantPolicy(weight=0.4)})
    mb.offer("slow", "s0", 0.0)
    assert mb.take(0.0) == ["s0"]        # multiple passes accrue deficit
    # the emptied queue must not bank leftover credit
    assert mb._deficit["slow"] == 0.0
    mb.offer("slow", "s1", 1.0)
    assert mb.take(1.0) == ["s1"]


def test_microbatcher_intake_rejections_and_stats():
    mb = MicroBatcher(max_batch=8, policies={
        "flood": TenantPolicy(rate=1.0, burst=1.0),
        "bursty": TenantPolicy(max_backlog=2)})
    assert mb.offer("flood", "f0", 0.0) == "queued"
    assert mb.offer("flood", "f1", 0.0) == REJECT_RATE
    assert mb.offer("flood", "f2", 1.0) == "queued"   # refilled
    assert [mb.offer("bursty", f"b{j}", 0.0) for j in range(3)] \
        == ["queued", "queued", REJECT_BACKLOG]
    assert mb.stats["flood"] == {"offered": 3, "queued": 2,
                                 "rate_limited": 1, "backlog_shed": 0}
    assert mb.stats["bursty"]["backlog_shed"] == 1
    assert mb.pending() == 4             # rejected items never buffered


# ----------------------------------------------------------------------
# async engine end-to-end (asyncio.run; no pytest-asyncio dependency)
# ----------------------------------------------------------------------

def test_async_engine_serves_windows_and_sheds_flood():
    tel = Telemetry()
    eng, tracker = _engine(tel=tel)
    aeng = AsyncServingEngine(
        eng, max_batch=4, max_wait_ms=5,
        policies={"flood": TenantPolicy(rate=1.0, burst=1.0)})

    async def drive():
        async with aeng:
            # deadline-carrying requests land their verdict in the
            # telemetry funnel (SLO-less traffic is engine-log only)
            good = [aeng.submit(_req(i, deadline_ms=10_000.0))
                    for i in range(10)]
            bad = [aeng.submit(_req(100 + i, tenant="flood"))
                   for i in range(5)]
            return await asyncio.gather(*good, *bad)

    resps = asyncio.run(drive())
    good, bad = resps[:10], resps[10:]
    assert all(r.admission == "admitted" and not r.error for r in good)
    assert [r.request.id for r in good] == list(range(10))
    sheds = [r for r in bad if r.admission == "shed"]
    assert len(sheds) == 4 and all(r.error == REJECT_RATE for r in sheds)
    assert sum(1 for r in bad if r.admission == "admitted") == 1
    # window accounting: every accepted request flushed, bounded windows
    assert sum(aeng.windows) == 11
    assert all(1 <= w <= 4 for w in aeng.windows)
    assert len(eng.log) == 15            # sheds land in the log too
    # tracker nets to zero; per-tenant funnel attributes the sheds
    q, f, _, _ = tracker.snapshot()
    assert (q == 0).all() and (f == 0).all()
    by = tel.admission_by_tenant()
    assert by["acme"]["admitted"] == 10
    assert by["flood"]["shed"] == 4
    assert tel.summary()["counters"]["intake_rate_limited"] == 4


def test_async_engine_stop_drains_backlog():
    eng, _ = _engine()
    aeng = AsyncServingEngine(eng, max_batch=32, max_wait_ms=10_000)

    async def drive():
        async with aeng:
            tasks = [asyncio.ensure_future(aeng.submit(_req(i)))
                     for i in range(3)]
            await asyncio.sleep(0)       # let every submit enqueue
            # exit drains: the 10s window must NOT hold the futures
        return await asyncio.gather(*tasks)

    resps = asyncio.run(drive())
    assert [r.request.id for r in resps] == [0, 1, 2]
    assert all(r.served for r in resps)


def test_async_engine_requires_start():
    eng, _ = _engine()
    aeng = AsyncServingEngine(eng)

    async def drive():
        with pytest.raises(RuntimeError, match="not started"):
            await aeng.submit(_req(0))

    asyncio.run(drive())


# ----------------------------------------------------------------------
# thread hammer on the synchronous submit path
# ----------------------------------------------------------------------

def test_submit_concurrent_thread_hammer():
    tel = Telemetry()
    eng, tracker = _engine(tel=tel)
    errs = []

    def work(tid):
        try:
            for k in range(5):
                reqs = [_req(tid * 100 + k * 10 + j, tenant=f"t{tid}",
                             deadline_ms=10_000.0) for j in range(3)]
                resps = eng.submit(reqs)
                assert len(resps) == 3
                assert all(r.served for r in resps)
        except Exception as e:                     # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    q, f, _, _ = tracker.snapshot()
    assert (q == 0).all() and (f == 0).all()       # no leaked lifecycle
    assert len(eng.log) == 4 * 5 * 3
    s = eng.summary()
    assert s["requests"] == 60
    funnel = tel.admission_funnel()
    assert sum(funnel.values()) == 60
    assert funnel.get("failed", 0) == 0 and funnel.get("shed", 0) == 0
    by = tel.admission_by_tenant()
    assert {t: sum(k.values()) for t, k in by.items()} \
        == {f"t{i}": 15 for i in range(4)}
