"""Batched routing path: batch/single parity, kernel/numpy parity,
empty-input handling and telemetry timing (the route_many refactor)."""
import time

import numpy as np
import pytest

from repro.core.feedback import FeedbackStore
from repro.core.mres import MRES
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import (DOMAINS, METRICS, TASK_TYPES,
                                    TaskSignature, UserPreferences)
from repro.core.routing import RoutingEngine
from tests.conftest import make_entry


def random_catalog(n: int, seed: int = 0) -> MRES:
    rng = np.random.default_rng(seed)
    m = MRES()
    m.register_many([
        make_entry(
            f"m{i}",
            accuracy=float(rng.random()),
            latency_ms=float(rng.random() * 500 + 1),
            cost=float(rng.random() * 20 + 0.1),
            helpfulness=float(rng.random()),
            harmlessness=float(rng.random()),
            honesty=float(rng.random()),
            task_types=tuple(rng.choice(TASK_TYPES,
                                        size=int(rng.integers(1, 4)),
                                        replace=False)),
            domains=tuple(rng.choice(DOMAINS, size=int(rng.integers(1, 3)),
                                     replace=False)),
            generalist=bool(rng.random() < 0.3))
        for i in range(n)])
    return m


def random_queries(b: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    sigs = [TaskSignature(task_type=str(rng.choice(TASK_TYPES)),
                          domain=str(rng.choice(DOMAINS)),
                          complexity=float(rng.random()),
                          confidence=float(rng.random())) for _ in range(b)]
    prefs = [UserPreferences(weights={m: float(rng.random())
                                      for m in METRICS}) for _ in range(b)]
    return prefs, sigs


class StubAnalyzer:
    """Deterministic analyzer stand-in (orchestrator tests only)."""

    def analyze_batch(self, texts):
        return [TaskSignature(task_type="chat", domain="general",
                              complexity=0.4) for _ in texts]

    def analyze(self, text):
        return self.analyze_batch([text])[0]


# ----------------------------------------------------------------------
# batch/single parity
# ----------------------------------------------------------------------

@pytest.mark.parametrize("b", [1, 7, 64])
def test_route_many_matches_single_route(b):
    m = random_catalog(48, seed=3)
    fb = FeedbackStore()
    rng = np.random.default_rng(9)
    for _ in range(40):          # populate some feedback clusters
        fb.record(TaskSignature(task_type=str(rng.choice(TASK_TYPES)),
                                domain=str(rng.choice(DOMAINS)),
                                complexity=float(rng.random())),
                  f"m{int(rng.integers(48))}", bool(rng.random() < 0.5))
    eng = RoutingEngine(m, fb, knn_k=8)
    prefs, sigs = random_queries(b, seed=b)
    batch = eng.route_many(prefs, sigs)
    assert len(batch) == b
    for d_b, p, s in zip(batch, prefs, sigs):
        d_1 = eng.route(p, s)
        assert d_b.model == d_1.model
        assert d_b.fallback_kind == d_1.fallback_kind
        assert d_b.score == pytest.approx(d_1.score, abs=1e-6)
        # similarity comes out of a (B, N) f32 matmul whose BLAS
        # accumulation order varies with B — compare at f32 precision
        assert d_b.similarity == pytest.approx(d_1.similarity, abs=1e-5)
        assert [n for n, _ in d_b.candidates] == [n for n, _ in d_1.candidates]


def test_route_many_broadcasts_single_prefs():
    m = random_catalog(16, seed=5)
    eng = RoutingEngine(m)
    _, sigs = random_queries(9, seed=5)
    batch = eng.route_many("balanced", sigs)
    assert len(batch) == 9
    for d, s in zip(batch, sigs):
        assert d.model == eng.route("balanced", s).model


def test_route_many_kernel_matches_numpy_path():
    """Interpret-mode Pallas kernel path == numpy path, incl. masks."""
    m = random_catalog(96, seed=7)
    prefs, sigs = random_queries(13, seed=7)
    eng_np = RoutingEngine(m, knn_k=8, use_kernel=False)
    eng_k = RoutingEngine(m, knn_k=8, use_kernel=True)
    eng_k._kernel_min_n = 0
    d_np = eng_np.route_many(prefs, sigs)
    d_k = eng_k.route_many(prefs, sigs)
    for a, b in zip(d_np, d_k):
        assert a.model == b.model
        assert a.fallback_kind == b.fallback_kind
        assert a.score == pytest.approx(b.score, abs=1e-6)


def test_route_many_fallback_ladder_engages():
    """A catalog with no match for the signature walks the ladder."""
    m = MRES()
    m.register(make_entry("gen", task_types=("chat",), generalist=True))
    m.register(make_entry("coder", task_types=("code",),
                          domains=("software",)))
    eng = RoutingEngine(m)
    d, = eng.route_many("balanced", [TaskSignature(task_type="vqa",
                                                   domain="healthcare")])
    assert d.used_fallback and d.fallback_kind == "generalist"
    assert d.model == "gen"


def test_route_many_empty_batch():
    eng = RoutingEngine(random_catalog(4))
    assert eng.route_many([], []) == []


def test_route_many_mismatched_lengths():
    eng = RoutingEngine(random_catalog(4))
    with pytest.raises(ValueError):
        eng.route_many([UserPreferences()], [TaskSignature(),
                                             TaskSignature()])


# ----------------------------------------------------------------------
# feedback bias_batch
# ----------------------------------------------------------------------

def test_bias_batch_matches_per_sig_bias():
    fb = FeedbackStore()
    rng = np.random.default_rng(11)
    names = [f"m{i}" for i in range(12)]
    sigs = [TaskSignature(task_type=str(rng.choice(TASK_TYPES)),
                          domain=str(rng.choice(DOMAINS)),
                          complexity=float(rng.random())) for _ in range(20)]
    for _ in range(60):
        fb.record(sigs[int(rng.integers(20))],
                  names[int(rng.integers(12))], bool(rng.random() < 0.5))
    mat = fb.bias_batch(sigs, names)
    assert mat.shape == (20, 12)
    for i, s in enumerate(sigs):
        np.testing.assert_allclose(mat[i], fb.bias(s, names), atol=0)


# ----------------------------------------------------------------------
# orchestrator / serving wiring
# ----------------------------------------------------------------------

def test_route_batch_rejects_empty_input():
    router = OptiRoute(random_catalog(4), StubAnalyzer())
    with pytest.raises(ValueError):
        router.route_batch([], "balanced")


def test_route_all_matches_interactive_route():
    router = OptiRoute(random_catalog(24, seed=2), StubAnalyzer())
    texts = [f"query {i}" for i in range(10)]
    all_rq = router.route_all(texts, "cost-effective")
    assert [rq.decision.model for rq in all_rq] == \
        [router.route(t, "cost-effective").decision.model for t in texts]
    assert router.route_all([], "balanced") == []


def test_route_timing_covers_merge_path():
    """route_s must include the merge attempt + re-route (telemetry)."""
    router = OptiRoute(random_catalog(8), StubAnalyzer())

    class SlowMerger:
        score_threshold = float("inf")   # always triggers the merge path

        def maybe_merge(self, prefs, sig, score):
            time.sleep(0.05)
            return None

    router.merger = SlowMerger()
    rq = router.route("hello", "balanced")
    assert rq.route_s >= 0.05


def test_serving_submit_empty_and_grouping():
    from repro.serving.engine import Request, ServingEngine
    router = OptiRoute(random_catalog(24, seed=4), StubAnalyzer())
    engine = ServingEngine(router)
    assert engine.submit([]) == []
    reqs = [Request(text=f"q{i}", prefs="balanced", id=i) for i in range(6)]
    out = engine.submit(reqs)
    assert len(out) == 6
    # one routing pass, identical prefs + sigs -> identical model
    assert len({r.model for r in out}) == 1
    assert engine.summary()["requests"] == 6
