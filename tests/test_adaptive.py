"""Adaptive routing subsystem: bandit learning, Pallas bandit_update
parity, the route_many adaptive blend, the orchestrator/serving reward
loop, and the non-stationary workload scenarios."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adaptive import LinearBandit, RewardConfig, RewardShaper
from repro.core.mres import MRES
from repro.core.orchestrator import OptiRoute
from repro.core.preferences import (DOMAINS, N_METRICS, TaskSignature,
                                    resolve)
from repro.core.routing import RoutingEngine
from repro.data.workload import (DRIFT_KINDS, DriftScenario,
                                 NonStationaryWorkload, meta_of,
                                 quality_of)
from repro.kernels import ops as K
from repro.kernels import ref as R
from tests.conftest import make_entry

RNG = np.random.default_rng(7)


class StubAnalyzer:
    def __init__(self, sig=None):
        self.sig = sig or TaskSignature(task_type="chat", domain="general",
                                        complexity=0.4)

    def analyze_batch(self, texts):
        return [self.sig for _ in texts]

    def analyze(self, text):
        return self.sig


def flat_catalog(n, **kw):
    """n chat generalists with an accuracy spread, all domains tagged."""
    m = MRES()
    m.register_many([
        make_entry(f"m{i}", accuracy=0.3 + 0.6 * i / max(n - 1, 1),
                   domains=tuple(DOMAINS), generalist=True, **kw)
        for i in range(n)])
    return m


# ----------------------------------------------------------------------
# LinearBandit
# ----------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["linucb", "thompson"])
def test_bandit_learns_best_arm(policy):
    """On a stationary problem both policies beat uniform-random."""
    rng = np.random.default_rng(3)
    N = 4
    base = np.linspace(0.1, 0.9, N)
    b = LinearBandit(N, policy=policy, seed=1)
    tot = rand = 0.0
    for _ in range(120):
        X = rng.random((8, N_METRICS)).astype(np.float32)
        s = b.scores(X)
        chosen = s.argmax(axis=1)
        r = base[chosen] + 0.05 * rng.standard_normal(8)
        b.update(X, chosen, r.astype(np.float32))
        tot += base[chosen].sum()
        rand += base[rng.integers(0, N, 8)].sum()
    assert tot > rand * 1.2


def test_bandit_update_matches_per_sample_loop():
    """One batched update == the sum of per-sample rank-1 updates."""
    b1 = LinearBandit(6, seed=0)
    b2 = LinearBandit(6, seed=0)
    X = RNG.random((16, N_METRICS)).astype(np.float32)
    chosen = RNG.integers(0, 6, 16)
    r = RNG.random(16).astype(np.float32)
    b1.update(X, chosen, r)
    for i in range(16):
        b2.update(X[i:i + 1], chosen[i:i + 1], r[i:i + 1])
    np.testing.assert_allclose(b1.A, b2.A, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(b1.b, b2.b, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(b1.counts, b2.counts)


def test_bandit_linucb_scores_closed_form():
    """scores == x.theta + alpha sqrt(x^T Ainv x) from the raw stats."""
    b = LinearBandit(3, alpha=0.7, seed=0)
    X = RNG.random((10, N_METRICS)).astype(np.float32)
    b.update(X, RNG.integers(0, 3, 10), RNG.random(10).astype(np.float32))
    q = RNG.random((2, N_METRICS)).astype(np.float32)
    got = b.scores(q)
    ctx = np.concatenate([q, np.ones((2, 1), np.float32)], axis=1)
    ainv = np.linalg.inv(b.A)
    theta = np.einsum("nde,ne->nd", ainv, b.b)
    want = ctx @ theta.T + 0.7 * np.sqrt(
        np.einsum("bd,nde,be->bn", ctx, ainv, ctx))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_bandit_forgetting_tracks_drift():
    """With forget < 1 the posterior follows a reward flip; without it
    the stale evidence dominates far longer."""
    def run(forget):
        b = LinearBandit(2, forget=forget, alpha=0.0, seed=0)
        X = np.full((8, N_METRICS), 0.5, np.float32)
        for phase, best in ((0, 0), (1, 1)):
            for _ in range(40):
                chosen = np.array([best] * 8)
                r = np.full(8, 0.9, np.float32)
                b.update(X, chosen, r)
                other = np.array([1 - best] * 8)
                b.update(X, other, np.full(8, 0.1, np.float32))
        return b.predict(X[:1])[0]
    est = run(0.9)
    assert est[1] > est[0]            # flipped to the new best arm
    # per-arm estimates stay near the post-flip rewards
    assert abs(est[1] - 0.9) < 0.25 and abs(est[0] - 0.1) < 0.25


def test_bandit_scores_at_matches_full_columns():
    b = LinearBandit(8, alpha=0.6, seed=0)
    X = RNG.random((10, N_METRICS)).astype(np.float32)
    b.update(X, RNG.integers(0, 8, 10), RNG.random(10).astype(np.float32))
    q = RNG.random((4, N_METRICS)).astype(np.float32)
    cols = np.array([6, 1, 3])
    np.testing.assert_allclose(b.scores_at(q, cols), b.scores(q)[:, cols],
                               rtol=1e-6, atol=1e-6)


def test_bandit_ensure_grows():
    b = LinearBandit(3, seed=0)
    X = RNG.random((4, N_METRICS)).astype(np.float32)
    b.update(X, np.array([0, 1, 2, 0]), np.ones(4, np.float32))
    b.ensure(5)
    assert b.n_models == 5 and b.A.shape[0] == 5
    assert b.counts[3] == 0 and b.counts[0] == 2
    assert b.scores(X).shape == (4, 5)


# ----------------------------------------------------------------------
# Pallas bandit_update kernel vs ref / numpy class
# ----------------------------------------------------------------------

@pytest.mark.parametrize("Bu,Bs,N,D", [
    (16, 8, 12, 9), (64, 32, 300, 9), (1, 1, 5, 4), (0, 3, 7, 9),
])
def test_bandit_update_kernel_matches_ref(Bu, Bs, N, D):
    x_up = RNG.random((Bu, D)).astype(np.float32)
    w = np.zeros((Bu, N), np.float32)
    if Bu:
        w[np.arange(Bu), RNG.integers(0, N, Bu)] = 1.0
    r = RNG.random(Bu).astype(np.float32)
    xs = RNG.random((Bs, D)).astype(np.float32)
    theta = RNG.standard_normal((N, D)).astype(np.float32)
    L = RNG.standard_normal((N, D, D)).astype(np.float32) * 0.1
    ainv = np.einsum("nde,nfe->ndf", L, L) + np.eye(D, dtype=np.float32)
    alpha = 0.8
    dA1, db1, u1 = K.bandit_update(x_up, w, r, xs, theta, ainv, alpha)
    dA2, db2, u2 = R.bandit_update(
        jnp.asarray(x_up), jnp.asarray(w), jnp.asarray(r), jnp.asarray(xs),
        jnp.asarray(theta), jnp.asarray(ainv), alpha)
    np.testing.assert_allclose(np.asarray(dA1), np.asarray(dA2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(db1), np.asarray(db2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2),
                               rtol=1e-4, atol=1e-4)


def test_bandit_kernel_class_matches_numpy_class():
    """update_and_score: kernel-backed bandit == numpy bandit."""
    b_np = LinearBandit(300, policy="linucb", seed=2)
    b_k = LinearBandit(300, policy="linucb", seed=2,
                       use_kernel=True, kernel_min_n=0)
    for _ in range(3):
        X = RNG.random((24, N_METRICS)).astype(np.float32)
        ch = RNG.integers(0, 300, 24)
        r = RNG.random(24).astype(np.float32)
        Xs = RNG.random((12, N_METRICS)).astype(np.float32)
        s1 = b_np.update_and_score(X, ch, r, Xs)
        s2 = b_k.update_and_score(X, ch, r, Xs)
        np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(b_np.A, b_k.A, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b_np.b, b_k.b, rtol=1e-4, atol=1e-4)


def test_bandit_kernel_update_matches_numpy_update():
    """The serving learning step (observe -> update) hits the Pallas
    kernel when use_kernel is on and stays numerically identical."""
    b_np = LinearBandit(200, policy="linucb", forget=0.95, seed=4)
    b_k = LinearBandit(200, policy="linucb", forget=0.95, seed=4,
                       use_kernel=True, kernel_min_n=0)
    for _ in range(3):
        X = RNG.random((16, N_METRICS)).astype(np.float32)
        ch = RNG.integers(0, 200, 16)
        r = RNG.random(16).astype(np.float32)
        b_np.update(X, ch, r)
        b_k.update(X, ch, r)
    np.testing.assert_allclose(b_np.A, b_k.A, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b_np.b, b_k.b, rtol=1e-4, atol=1e-4)
    q = RNG.random((4, N_METRICS)).astype(np.float32)
    np.testing.assert_allclose(b_np.scores(q), b_k.scores(q),
                               rtol=1e-3, atol=1e-3)


def test_bandit_kernel_empty_batch_applies_no_forgetting():
    """An empty outcome batch must not decay the posterior on either
    path (regression: the kernel path used to forget on B=0)."""
    kw = dict(policy="linucb", forget=0.9, seed=1)
    b_np = LinearBandit(10, **kw)
    b_k = LinearBandit(10, use_kernel=True, kernel_min_n=0, **kw)
    X = RNG.random((8, N_METRICS)).astype(np.float32)
    ch = RNG.integers(0, 10, 8)
    r = RNG.random(8).astype(np.float32)
    for b in (b_np, b_k):
        b.update(X, ch, r)
    empty = np.zeros((0, N_METRICS), np.float32)
    Xs = RNG.random((4, N_METRICS)).astype(np.float32)
    s_np = b_np.update_and_score(empty, np.zeros(0, np.int64),
                                 np.zeros(0, np.float32), Xs)
    s_k = b_k.update_and_score(empty, np.zeros(0, np.int64),
                               np.zeros(0, np.float32), Xs)
    np.testing.assert_allclose(s_np, s_k, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b_np.A, b_k.A, rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------------------
# reward shaping
# ----------------------------------------------------------------------

def test_reward_shaper_penalizes_cost_and_latency():
    m = MRES()
    m.register(make_entry("cheap-fast", cost=0.1, latency_ms=5))
    m.register(make_entry("pricey-slow", cost=10.0, latency_ms=500))
    sh = RewardShaper(m, RewardConfig(cost_weight=0.2, latency_weight=0.1))
    r = sh.shape([0.8, 0.8], np.array([0, 1]))
    assert r[0] == pytest.approx(0.8)          # extremes: zero penalty
    assert r[1] == pytest.approx(0.8 - 0.3)    # full cost+latency penalty
    # refresh picks up catalog growth
    m.register(make_entry("mid", cost=5.0, latency_ms=250))
    assert sh.shape([0.5], np.array([2]))[0] < 0.5


# ----------------------------------------------------------------------
# routing blend + orchestrator loop
# ----------------------------------------------------------------------

def test_adaptive_blend_overrides_static_choice():
    """A bandit trained to favor a mid-tier model flips route_many."""
    m = flat_catalog(6)
    static = RoutingEngine(m, knn_k=6)
    sig = TaskSignature(task_type="chat", domain="general", complexity=0.2)
    d0 = static.route("balanced", sig)
    bandit = LinearBandit(6, alpha=0.0, seed=0)
    target = 2
    assert d0.model != f"m{target}"
    X = np.tile(static.task_vector(resolve("balanced"), sig), (40, 1))
    chosen = np.full(40, target)
    bandit.update(X, chosen, np.full(40, 1.0, np.float32))
    for j in range(6):
        if j != target:
            bandit.update(X[:10], np.full(10, j), np.zeros(10, np.float32))
    adaptive = RoutingEngine(m, knn_k=6, adaptive=bandit,
                             adaptive_weight=4.0)
    d1 = adaptive.route("balanced", sig)
    assert d1.model == f"m{target}"
    # weight 0 keeps the static decision
    off = RoutingEngine(m, knn_k=6, adaptive=bandit, adaptive_weight=0.0)
    assert off.route("balanced", sig).model == d0.model


def test_orchestrator_reward_fn_closes_loop():
    """route_all with a reward_fn converges onto the rewarded model."""
    m = flat_catalog(6)
    bandit = LinearBandit(6, seed=0)
    router = OptiRoute(m, StubAnalyzer(), adaptive=bandit,
                       adaptive_weight=2.0, reward_shaper=RewardShaper(m),
                       reward_fn=lambda rq: 0.9 if rq.decision.model == "m1"
                       else 0.1)
    for step in range(25):
        rqs = router.route_all([f"q{step}{i}" for i in range(6)], "balanced")
    assert {rq.decision.model for rq in rqs} == {"m1"}
    assert bandit.counts.sum() == 25 * 6


def test_orchestrator_observe_explicit_qualities():
    m = flat_catalog(4)
    bandit = LinearBandit(4, seed=0)
    router = OptiRoute(m, StubAnalyzer(), adaptive=bandit,
                       adaptive_weight=1.0)
    rqs = router.route_all(["a", "b", "c"], "balanced")
    assert bandit.counts.sum() == 0        # no reward_fn -> no auto loop
    rewards = router.observe(rqs, qualities=[0.5, 0.6, 0.7])
    assert rewards is not None and rewards.shape == (3,)
    assert bandit.counts.sum() == 3
    # no bandit attached -> observe is a no-op
    assert OptiRoute(m, StubAnalyzer()).observe(rqs, [0.1, 0.2, 0.3]) is None


def test_serving_engine_observe_feeds_bandit():
    from repro.serving.engine import Request, ServingEngine
    m = flat_catalog(4)
    bandit = LinearBandit(4, seed=0)
    router = OptiRoute(m, StubAnalyzer(), adaptive=bandit,
                       adaptive_weight=1.0, reward_shaper=RewardShaper(m))
    eng = ServingEngine(router)
    out = eng.submit([Request(text=f"q{i}", prefs="balanced", id=i)
                      for i in range(5)])
    assert all(r.rq is not None for r in out)
    eng.observe(out, [0.8] * 5)
    assert bandit.counts.sum() == 5


def test_observe_never_double_counts():
    """reward_fn auto-observe + explicit post-generation observe must
    fold each outcome in exactly once."""
    from repro.serving.engine import Request, ServingEngine
    m = flat_catalog(4)
    bandit = LinearBandit(4, seed=0)
    router = OptiRoute(m, StubAnalyzer(), adaptive=bandit,
                       adaptive_weight=1.0, reward_fn=lambda rq: 0.5)
    eng = ServingEngine(router)
    out = eng.submit([Request(text=f"q{i}", prefs="balanced", id=i)
                      for i in range(5)])
    assert bandit.counts.sum() == 5        # auto-observed in route_all
    assert eng.observe(out, [0.9] * 5) is None
    assert router.observe([r.rq for r in out]) is None
    assert bandit.counts.sum() == 5        # still once per query
    # misaligned observations are an error, not silent truncation
    with pytest.raises(ValueError, match="one-to-one"):
        eng.observe(out, [0.9] * 4)


# ----------------------------------------------------------------------
# non-stationary workload
# ----------------------------------------------------------------------

@pytest.mark.parametrize("kind", DRIFT_KINDS)
def test_drift_workload_deterministic_and_consistent(kind):
    meta = [{"name": f"m{i}", "accuracy": 0.3 + 0.1 * i,
             "task_types": ("chat",), "domains": tuple(DOMAINS)}
            for i in range(5)]
    wl = NonStationaryWorkload(
        meta, DriftScenario(kind=kind, n_steps=12, batch=4, seed=5))
    assert [q.text for q in wl.batch(3)] == [q.text for q in wl.batch(3)]
    sigs = [q.sig for q in wl.batch(3)]
    Q = wl.quality_matrix(3, sigs)
    assert Q.shape == (4, 5)
    for bi, s in enumerate(sigs):
        for j in range(5):
            assert Q[bi, j] == pytest.approx(wl.quality(3, f"m{j}", s))


def test_model_degrade_flips_best_model():
    meta = [{"name": f"m{i}", "accuracy": 0.3 + 0.15 * i,
             "task_types": ("chat",), "domains": tuple(DOMAINS)}
            for i in range(4)]
    wl = NonStationaryWorkload(meta, DriftScenario(
        kind="model-degrade", n_steps=10, batch=2, shift_frac=0.5,
        degrade_delta=0.6, task_type="chat"))
    assert wl.degraded_model == "m3"
    sig = wl.batch(0)[0].sig
    before = wl.quality(0, "m3", sig)
    after = wl.quality(9, "m3", sig)
    assert after < before
    # the static table is untouched for other models
    assert wl.quality(9, "m1", sig) == pytest.approx(
        wl.quality(0, "m1", sig))


def test_domain_shift_changes_mix():
    meta = [{"name": "m0", "accuracy": 0.5, "task_types": ("chat",),
             "domains": ("general",)}]
    wl = NonStationaryWorkload(meta, DriftScenario(
        kind="domain-shift", n_steps=10, batch=6, shift_frac=0.5,
        domain_a="general", domain_b="legal", task_type="chat"))
    assert {q.sig.domain for q in wl.batch(1)} == {"general"}
    assert {q.sig.domain for q in wl.batch(8)} == {"legal"}


def test_bandit_recovers_after_degrade():
    """End-to-end: the blended router abandons a degraded model."""
    m = flat_catalog(6)
    metas = [meta_of(e) for e in m.entries]
    an = StubAnalyzer()
    static = OptiRoute(m, an, knn_k=6)
    probe = static.route_all(["probe"] * 4, "accuracy-first")
    fav = probe[0].decision.model
    wl = NonStationaryWorkload(metas, DriftScenario(
        kind="model-degrade", n_steps=30, batch=6, shift_frac=0.34,
        degrade_model=fav, degrade_delta=0.7, task_type="chat", seed=2))
    bandit = LinearBandit(6, alpha=0.5, forget=0.95, seed=0)
    router = OptiRoute(m, an, knn_k=6, adaptive=bandit,
                       adaptive_weight=2.0)
    last = None
    for t in range(30):
        batch = wl.batch(t)
        an.sig = batch[0].sig       # stub: one sig per batch
        rqs = router.route_all([q.text for q in batch], "accuracy-first")
        router.observe(rqs, [wl.quality(t, rq.decision.model, rq.sig)
                             for rq in rqs])
        last = [rq.decision.model for rq in rqs]
    assert fav not in last          # routed around the degraded favorite
