"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels import ref as R

RNG = np.random.default_rng(42)


# ----------------------------------------------------------------------
# router_topk
# ----------------------------------------------------------------------

@pytest.mark.parametrize("N,D,Q,k", [
    (100, 8, 1, 4), (1000, 8, 5, 8), (513, 8, 3, 8),
    (2048, 16, 8, 16), (37, 8, 2, 4),
])
def test_router_topk_matches_ref(N, D, Q, k):
    emb = RNG.random((N, D)).astype(np.float32)
    q = RNG.random((Q, D)).astype(np.float32)
    v1, i1 = K.router_topk(emb, q, k)
    v2, i2 = R.router_topk(jnp.asarray(emb), jnp.asarray(q), k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)
    # idx may differ on exact ties; scores at the returned idx must match
    sims = np.asarray(R.router_topk(jnp.asarray(emb), jnp.asarray(q), N)[0])
    for qi in range(Q):
        got = np.asarray(v1[qi])
        np.testing.assert_allclose(np.sort(got)[::-1], got, rtol=0, atol=0)


@pytest.mark.parametrize("frac_masked", [0.0, 0.5, 0.95])
def test_router_topk_mask_and_weights(frac_masked):
    N, D, Q, k = 640, 8, 4, 8
    emb = RNG.random((N, D)).astype(np.float32)
    q = RNG.random((Q, D)).astype(np.float32)
    mask = RNG.random(N) >= frac_masked
    w = (RNG.random(D) + 0.05).astype(np.float32)
    v1, i1 = K.router_topk(emb, q, k, mask=mask, weights=w)
    v2, i2 = R.router_topk(jnp.asarray(emb), jnp.asarray(q), k,
                           mask=jnp.asarray(mask), weights=jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)
    # no masked row may appear among finite-valued results
    i1 = np.asarray(i1)
    finite = np.isfinite(np.asarray(v1))
    assert mask[i1[finite]].all()


@pytest.mark.parametrize("min_score", [-0.5, 0.1, 0.35, 2.0])
def test_router_topk_min_score_matches_ref(min_score):
    """The fused score floor (the semantic cache's similarity
    threshold) prunes identically on kernel and oracle."""
    N, D, Q, k = 384, 16, 6, 8
    emb = RNG.standard_normal((N, D)).astype(np.float32)
    q = RNG.standard_normal((Q, D)).astype(np.float32)
    mask = RNG.random((Q, N)) < 0.8
    bias = (RNG.random(N) * 0.1).astype(np.float32)
    v1, i1 = K.router_topk(emb, q, k, mask=mask, row_bias=bias,
                           min_score=min_score)
    v2, i2 = R.router_topk(jnp.asarray(emb), jnp.asarray(q), k,
                           mask=jnp.asarray(mask),
                           row_bias=jnp.asarray(bias),
                           min_score=min_score)
    v1 = np.asarray(v1)
    np.testing.assert_allclose(v1, np.asarray(v2), rtol=1e-5, atol=1e-6)
    finite = np.isfinite(v1)
    assert (v1[finite] >= min_score - 1e-6).all()
    # sub-threshold and masked rows surface exactly as -inf, and an
    # impossible floor empties the result entirely
    if min_score >= 2.0:
        assert not finite.any()
    # disabled floor == no floor
    v3, _ = K.router_topk(emb, q, k, mask=mask, row_bias=bias,
                          min_score=None)
    v4, _ = K.router_topk(emb, q, k, mask=mask, row_bias=bias)
    np.testing.assert_array_equal(np.asarray(v3), np.asarray(v4))


def test_router_topk_all_masked():
    N, D = 256, 8
    emb = RNG.random((N, D)).astype(np.float32)
    q = RNG.random((2, D)).astype(np.float32)
    v, i = K.router_topk(emb, q, 4, mask=np.zeros(N, bool))
    assert not np.isfinite(np.asarray(v)).any()


@pytest.mark.parametrize("N,D,Q,k", [
    (130, 8, 1, 4),     # B=1, N not a multiple of any block size
    (512, 8, 1, 8),     # B=1, block-aligned catalog
    (5, 8, 2, 8),       # k >= N: the tail must surface as -inf
    (3, 8, 1, 3),       # k == N == tiny
    (257, 16, 9, 16),   # off-by-one catalog, Q not a blk_q multiple
    (1000, 8, 5, 1000), # k == N, large
])
def test_router_topk_nonaligned_shapes(N, D, Q, k):
    """Regression sweep: shapes OFF the 128-lane/block happy path —
    padding, B=1, and k >= N must all match the oracle exactly."""
    emb = RNG.random((N, D)).astype(np.float32)
    q = RNG.random((Q, D)).astype(np.float32)
    mask = RNG.random(N) >= 0.3
    v1, i1 = K.router_topk(emb, q, k, mask=mask)
    v2, i2 = R.router_topk(jnp.asarray(emb), jnp.asarray(q), k,
                           mask=jnp.asarray(mask))
    v1, v2 = np.asarray(v1), np.asarray(v2)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-6)
    # both backends surface exactly the same number of real candidates,
    # and finite entries never point at masked or padded rows
    fin = np.isfinite(v1)
    assert (fin == np.isfinite(v2)).all()
    i1 = np.asarray(i1)
    assert (i1[fin] < N).all() and mask[i1[fin]].all()


def test_router_topk_row_bias_matches_ref():
    """The fused per-row score bias (load-aware routing) vs. oracle,
    including its interaction with the filter mask: masked rows stay
    -inf no matter how large the bias."""
    N, D, Q, k = 300, 8, 5, 8
    emb = RNG.random((N, D)).astype(np.float32)
    q = RNG.random((Q, D)).astype(np.float32)
    mask = RNG.random(N) >= 0.4
    bias = (RNG.random(N) * -2.0).astype(np.float32)
    bias[~mask] = 100.0                  # must NOT resurrect masked rows
    v1, i1 = K.router_topk(emb, q, k, mask=mask, row_bias=bias)
    v2, i2 = R.router_topk(jnp.asarray(emb), jnp.asarray(q), k,
                           mask=jnp.asarray(mask),
                           row_bias=jnp.asarray(bias))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)
    fin = np.isfinite(np.asarray(v1))
    assert mask[np.asarray(i1)[fin]].all()


@pytest.mark.parametrize("Bu,Bs,N,D", [
    (1, 1, 1, 3),       # every axis at its minimum
    (7, 5, 130, 9),     # N just past one 128 block
    (32, 24, 150, 9),   # the adaptive benchmark's shape
    (3, 2, 257, 5),     # off-by-one catalog
])
def test_bandit_update_nonaligned_shapes(Bu, Bs, N, D):
    """Pallas bandit_update vs. oracle on non-lane-aligned shapes
    (B=1, N=1, N not a multiple of the block size)."""
    rng = np.random.default_rng(Bu * 100 + N)
    x_up = rng.random((Bu, D)).astype(np.float32)
    w = np.zeros((Bu, N), np.float32)
    w[np.arange(Bu), rng.integers(0, N, Bu)] = 1.0
    r = rng.random(Bu).astype(np.float32)
    xs = rng.random((Bs, D)).astype(np.float32)
    theta = rng.standard_normal((N, D)).astype(np.float32)
    L = rng.standard_normal((N, D, D)).astype(np.float32) * 0.1
    ainv = np.einsum("nde,nfe->ndf", L, L) + np.eye(D, dtype=np.float32)
    got = K.bandit_update(x_up, w, r, xs, theta, ainv, 0.8)
    want = R.bandit_update(*(jnp.asarray(a) for a in
                             (x_up, w, r, xs, theta, ainv)), 0.8)
    for g, wnt, tol in zip(got, want, (1e-5, 1e-5, 1e-4)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wnt),
                                   rtol=tol, atol=tol)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,Lq,Lk,Hq,Hkv,hd", [
    (1, 64, 64, 2, 2, 32),      # MHA, block-aligned
    (2, 100, 100, 4, 2, 64),    # GQA, ragged lengths
    (1, 1, 300, 8, 2, 64),      # decode-style single query
    (2, 128, 128, 4, 1, 128),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Lq, Lk, Hq, Hkv, hd, dtype):
    q = jnp.asarray(RNG.standard_normal((B, Lq, Hq, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Lk, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Lk, Hkv, hd)), dtype)
    o1 = K.flash_attention(q, k, v, blk_q=32, blk_k=32)
    o2 = R.mha_attention(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window,cap,causal", [
    (16, 0.0, True), (0, 30.0, True), (7, 50.0, True), (0, 0.0, False),
])
def test_flash_attention_window_softcap(window, cap, causal):
    B, L, Hq, Hkv, hd = 2, 90, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, L, Hq, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, L, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, L, Hkv, hd)), jnp.float32)
    o1 = K.flash_attention(q, k, v, causal=causal, window=window,
                           softcap=cap, blk_q=32, blk_k=32)
    o2 = R.mha_attention(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-4, atol=3e-4)


# ----------------------------------------------------------------------
# ssd_scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("Bb,L,H,P,N,chunk", [
    (1, 32, 2, 16, 8, 16), (2, 75, 3, 32, 16, 16),
    (1, 128, 4, 64, 128, 64), (2, 17, 1, 8, 4, 8),
])
def test_ssd_scan_sweep(Bb, L, H, P, N, chunk):
    x = jnp.asarray(RNG.standard_normal((Bb, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((Bb, L, H)) * 0.5, jnp.float32)
    A = jnp.asarray(-np.exp(RNG.standard_normal(H)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((Bb, L, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((Bb, L, N)), jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((Bb, H, P, N)), jnp.float32)
    y1, hf1 = K.ssd_scan(x, dt, A, Bm, Cm, h0, chunk=chunk)
    y2, hf2 = R.ssd_scan(x, dt, A, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf2),
                               rtol=3e-4, atol=3e-4)


def test_ssd_scan_state_chaining():
    """Scanning two halves with carried state == one full scan."""
    Bb, L, H, P, N = 1, 64, 2, 16, 8
    x = jnp.asarray(RNG.standard_normal((Bb, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((Bb, L, H)) * 0.3, jnp.float32)
    A = jnp.asarray(-np.exp(RNG.standard_normal(H)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((Bb, L, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((Bb, L, N)), jnp.float32)
    y_full, h_full = K.ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    h = None
    ys = []
    for s in (slice(0, 32), slice(32, 64)):
        y, h = K.ssd_scan(x[:, s], dt[:, s], A, Bm[:, s], Cm[:, s], h,
                          chunk=16)
        ys.append(y)
    np.testing.assert_allclose(np.concatenate([np.asarray(y) for y in ys], 1),
                               np.asarray(y_full), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=3e-4, atol=3e-4)


# ----------------------------------------------------------------------
# moe_gating
# ----------------------------------------------------------------------

@pytest.mark.parametrize("T,E,k,blk", [
    (64, 8, 2, 16), (100, 32, 4, 32), (7, 16, 1, 8), (256, 128, 8, 64),
])
def test_moe_gating_sweep(T, E, k, blk):
    lg = jnp.asarray(RNG.standard_normal((T, E)), jnp.float32)
    v1, i1, a1 = K.moe_gating(lg, k, blk_t=blk)
    v2, i2, a2 = R.moe_gating(lg, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)
    # gates renormalized
    np.testing.assert_allclose(np.asarray(v1).sum(-1), 1.0, rtol=1e-4)
